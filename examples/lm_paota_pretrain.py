"""PAOTA applied to transformer LM pre-training (datacenter mode): the
paper's semi-async aggregation as the distribution layer for a causal LM.

Runs the SAME paota train step the dry-run lowers — K simulated clients
(data-parallel groups) each take M local SGD steps on their own token
stream, then the AirComp weighted noisy aggregation merges them; straggler
masks rotate to exercise the semi-async path. CPU-sized by default
(reduced smollm ~= 5M params); --full uses the real 135M config.

    PYTHONPATH=src python examples/lm_paota_pretrain.py --rounds 20
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced
from repro.data.synthetic import token_stream
from repro.launch.shapes import InputShape
from repro.models import init_model
from repro.models.transformer import loss_fn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=3)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mb", type=int, default=4)
    ap.add_argument("--lr", type=float, default=3e-2)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_reduced(args.arch)
    k, m = args.clients, args.local_steps
    params = init_model(jax.random.PRNGKey(0), cfg)
    stacked = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (k,) + x.shape), params)

    def local_sgd(p, mbs):
        def sgd(p, mb):
            (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(p, mb, cfg)
            return jax.tree_util.tree_map(lambda a, b: a - args.lr * b, p, g), l
        return jax.lax.scan(sgd, p, mbs)

    @jax.jit
    def paota_round(stacked, batch, powers, mask, seed):
        new_stacked, losses = jax.vmap(local_sgd)(stacked, batch)
        bp = powers * mask
        varsigma = jnp.maximum(jnp.sum(bp), 1e-12)

        def agg(leaf):
            s = jnp.einsum("k,k...->...", bp.astype(leaf.dtype), leaf)
            return s / varsigma.astype(leaf.dtype)

        def merge(a, local):
            mm = mask.reshape((k,) + (1,) * (local.ndim - 1)).astype(local.dtype)
            return mm * jnp.broadcast_to(a[None], local.shape) + (1 - mm) * local

        agg_t = jax.tree_util.tree_map(agg, new_stacked)
        return jax.tree_util.tree_map(merge, agg_t, new_stacked), jnp.mean(losses)

    rng = np.random.default_rng(0)
    stream = token_stream(cfg.vocab_size, k * m * args.mb, args.seq,
                          args.rounds)
    t0 = time.time()
    for r, batch in enumerate(stream):
        toks = batch["tokens"].reshape(k, m, args.mb, args.seq)
        # semi-async: a rotating subset of clients misses the aggregation
        mask = np.ones(k, np.float32)
        mask[r % k] = 0.0
        powers = np.full(k, 15.0, np.float32) * rng.uniform(0.6, 1.0, k).astype(np.float32)
        stacked, loss = paota_round(stacked, {"tokens": jnp.asarray(toks)},
                                    jnp.asarray(powers), jnp.asarray(mask),
                                    jax.random.PRNGKey(r))
        if r % 5 == 0 or r == args.rounds - 1:
            print(f"round {r:3d} loss {float(loss):.4f} "
                  f"({time.time() - t0:.1f}s)")
    print("done — loss should fall from ~ln(V) as the Markov stream is learned")


if __name__ == "__main__":
    main()
