"""Quickstart: PAOTA federated training on a synthetic non-IID MNIST-like
task — 20 clients, 15 rounds, compares against ideal Local SGD.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import ChannelConfig, SchedulerConfig
from repro.data.partition import partition_noniid
from repro.data.pipeline import build_federation
from repro.data.synthetic import get_dataset
from repro.fl import (FLClient, LocalSGDServer, PAOTAConfig, PAOTAServer,
                      SyncConfig, evaluate)
from repro.models.mlp import init_mlp_params, mlp_apply, mlp_loss


def main():
    x_tr, y_tr, x_te, y_te = get_dataset(n_train=4000, n_test=1000)
    parts = partition_noniid(y_tr, n_clients=20, seed=0)
    fed = build_federation(x_tr, y_tr, parts)
    clients = [FLClient(d, mlp_loss, batch_size=32, lr=0.1, local_steps=5)
               for d in fed]
    params = init_mlp_params(jax.random.PRNGKey(0))

    paota = PAOTAServer(params, clients, ChannelConfig(),
                        SchedulerConfig(n_clients=20, seed=1),
                        PAOTAConfig(solver="waterfill"))
    sync = LocalSGDServer(params, clients, SchedulerConfig(n_clients=20, seed=2),
                          SyncConfig(n_select=10))

    print(f"{'round':>5} {'PAOTA acc':>10} {'PAOTA t(s)':>10} "
          f"{'LocalSGD acc':>13} {'LocalSGD t(s)':>13}")
    for r in range(15):
        paota.round()
        sync.round()
        if r % 3 == 2:
            a1 = evaluate(paota.global_params(), x_te, y_te, mlp_apply)
            a2 = evaluate(sync.global_params(), x_te, y_te, mlp_apply)
            print(f"{r:>5} {a1['accuracy']:>10.3f} {paota.scheduler.time:>10.1f} "
                  f"{a2['accuracy']:>13.3f} {sync.time:>13.1f}")
    print("\nPAOTA fixed-period rounds vs sync straggler-bound rounds — "
          "same takeaway as paper Fig. 4 / Table I.")


if __name__ == "__main__":
    main()
