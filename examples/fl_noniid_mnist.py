"""End-to-end FL driver (paper Section IV): PAOTA vs Local SGD vs COTAF on
the non-IID federation, a few hundred rounds, trajectories + Table-I-style
summary written to experiments/bench/.

    PYTHONPATH=src python examples/fl_noniid_mnist.py [--rounds 200]
    REPRO_BENCH_FULL=1 ... for the paper-scale 100-client setting.
"""
import argparse

from benchmarks.common import BenchSetting, build_world, run_algorithm
from repro.fl import time_to_accuracy, write_csv


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--clients", type=int, default=40)
    ap.add_argument("--n0", type=float, default=-174.0)
    ap.add_argument("--solver", default="waterfill",
                    choices=["waterfill", "pgd", "milp"])
    ap.add_argument("--engine", default="batched",
                    choices=["batched", "legacy", "fused", "sharded"],
                    help="batched = one jitted vmap/scan call per "
                         "broadcast; legacy = seed per-client loop; fused "
                         "= whole PAOTA round on-device (counter RNG, "
                         "waterfill_jnp; baselines stay batched); sharded "
                         "= the fused round shard_map'd over the mesh "
                         "client axis (multi-device backend; a client "
                         "count the devices don't divide pads with masked "
                         "phantom clients)")
    ap.add_argument("--params-mode", default="raveled",
                    choices=["raveled", "pytree"],
                    help="fused/sharded model carry: raveled = flat (K, d) "
                         "stack (historical); pytree = the params tree "
                         "carried natively by the round core (allclose "
                         "trajectories, tree-reduced psums)")
    ap.add_argument("--pending-dtype", default="float32",
                    choices=["float32", "bfloat16"],
                    help="fused/sharded carry storage for the (K, ...) "
                         "pending/delta planes: bfloat16 halves the K x d "
                         "working set (f32 accumulation everywhere; the "
                         "globals stay f32) — footprint opt-in for "
                         "giant-model clients")
    ap.add_argument("--group-period", type=int, default=0,
                    help="sharded only: grouped aggregation window N on a "
                         "('pod', 'data') mesh — intra-pod psums every "
                         "period, ONE cross-pod model-sized psum per N "
                         "periods (0 = flat; the trajectory advances in "
                         "whole windows)")
    ap.add_argument("--cohort-size", type=int, default=0,
                    help="fused/sharded: active-cohort mode with m slots — "
                         "model-sized rows exist only for the in-flight "
                         "cohort (0 = dense (K, ...) planes)")
    ap.add_argument("--compress", default="",
                    choices=["", "topk", "randmask"],
                    help="fused/sharded + --cohort-size: sparsify the slot "
                         "payloads to s = round(d * ratio) coordinates "
                         "(per-slot top-k | shared per-round random mask); "
                         "switches transmit to 'delta' and keeps per-client "
                         "error-feedback residuals so dropped coordinates "
                         "re-enter later rounds")
    ap.add_argument("--compress-ratio", type=float, default=1.0 / 16.0,
                    help="s/d for --compress (default 1/16)")
    ap.add_argument("--tp", type=int, default=1,
                    help="sharded + --params-mode pytree: intra-client "
                         "tensor-parallel extent — the mesh becomes "
                         "('pod','data','tp') with the tp extent taken "
                         "off the client axis, and every client's stacked "
                         "payload leaves TP-shard over it (per-device "
                         "model-plane carry ~1/tp; the round keeps ONE "
                         "cross-client model-sized psum, which also "
                         "gathers the TP blocks)")
    ap.add_argument("--no-error-feedback", action="store_true",
                    help="drop the error-feedback residual planes (plain "
                         "sparsification; frees the per-client (K, s) "
                         "parked rows)")
    ap.add_argument("--faults", default="",
                    help="fused/sharded PAOTA only: fault-injection spec, "
                         "comma-separated kind:value pairs — nan:F / inf:F "
                         "(NaN/+Inf payload fraction), byz:F + scale:S "
                         "(Byzantine deltas), fade:F + gain:G (deep-fade "
                         "channel outliers), start:R / stop:R (active "
                         "window), pods:0|2 + bstart:R + bstop:R (pod "
                         "blackout, grouped sharded mode). E.g. "
                         "'nan:0.05,start:1'")
    ap.add_argument("--screen", action="store_true",
                    help="mask non-finite uploads out of the AirComp "
                         "superposition (per-row containment; the round "
                         "still runs ONE cross-client psum)")
    ap.add_argument("--screen-max-norm", type=float, default=0.0,
                    help="with --screen: also screen rows with payload "
                         "norm beyond this fence (0 = finite-only)")
    ap.add_argument("--divergence-factor", type=float, default=0.0,
                    help="roll the global back to the last-good slot when "
                         "a post-update norm jump exceeds this factor "
                         "(0 = detector off)")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="fused/sharded PAOTA: snapshot the FULL round "
                         "carry every N rounds (bit-exact resume via "
                         "--resume; 0 = off)")
    ap.add_argument("--checkpoint-dir", default="",
                    help="where --checkpoint-every snapshots go (default "
                         "<bench out dir>/checkpoints)")
    ap.add_argument("--resume", default="",
                    help="checkpoint path to restore before training: the "
                         "resumed PAOTA run continues the killed one "
                         "bit-for-bit (counter RNG replays the identical "
                         "streams), then runs --rounds more rounds")
    ap.add_argument("--out", default="experiments/bench/fl_noniid.csv")
    args = ap.parse_args()

    s = BenchSetting.from_env(n_rounds=args.rounds, n_clients=args.clients,
                              n0_dbm_hz=args.n0, solver=args.solver,
                              engine=args.engine,
                              params_mode=args.params_mode,
                              pending_dtype=args.pending_dtype,
                              group_period=args.group_period,
                              cohort_size=args.cohort_size,
                              compress=args.compress,
                              compress_ratio=args.compress_ratio,
                              error_feedback=not args.no_error_feedback,
                              tp=args.tp, faults=args.faults,
                              screen=args.screen,
                              screen_max_norm=args.screen_max_norm,
                              divergence_factor=args.divergence_factor,
                              checkpoint_every=args.checkpoint_every,
                              checkpoint_dir=args.checkpoint_dir,
                              resume=args.resume)
    clients, params, data = build_world(s)
    all_rows = []
    for algo in ("paota", "local_sgd", "cotaf"):
        rows = run_algorithm(algo, s, clients, params, data)
        if not rows:
            continue        # fault-tolerance sweeps skip the baselines
        all_rows.extend(rows)
        tta = time_to_accuracy(rows)
        print(f"\n=== {algo} === final acc {rows[-1]['accuracy']:.3f} "
              f"@ sim {rows[-1]['time']:.0f}s")
        for tgt, (rnd, tm) in tta.items():
            print(f"  target {tgt:.0%}: round={rnd} time={tm}")
    write_csv(args.out, all_rows)
    print(f"\ntrajectories -> {args.out}")


if __name__ == "__main__":
    main()
