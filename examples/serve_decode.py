"""End-to-end serving driver: batched greedy decoding with a KV cache on a
REAL assigned config (smollm-135m by default — 135M params, llama
architecture). Demonstrates the serve_step path the decode_32k/long_500k
dry-runs lower, on actual CPU devices.

    PYTHONPATH=src python examples/serve_decode.py --arch smollm-135m \
        --batch 4 --steps 24
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced
from repro.models import decode_step, init_decode_state, init_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=24)
    ap.add_argument("--cache", type=int, default=256)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-test-sized variant")
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if not cfg.supports_decode:
        raise SystemExit(f"{args.arch} is encoder-only — no decode path")
    print(f"arch={cfg.name} layers={cfg.num_layers} d={cfg.d_model} "
          f"vocab={cfg.vocab_size}")

    t0 = time.time()
    params = init_model(jax.random.PRNGKey(0), cfg)
    n = sum(int(x.size) for x in jax.tree_util.tree_leaves(params))
    print(f"init {n / 1e6:.1f}M params in {time.time() - t0:.1f}s")

    step = jax.jit(lambda p, t, s, i: decode_step(p, t, s, i, cfg),
                   donate_argnums=(2,))
    state = init_decode_state(cfg, args.batch, args.cache)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (args.batch, 1)),
                       jnp.int32)

    seqs = [toks]
    t0 = time.time()
    for i in range(args.steps):
        logits, state = step(params, seqs[-1], state, jnp.int32(i))
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        seqs.append(nxt)
        if i == 0:
            print(f"first step (compile+run): {time.time() - t0:.1f}s")
            t0 = time.time()
    dt = (time.time() - t0) / max(args.steps - 1, 1)
    out = jnp.concatenate(seqs, axis=1)
    print(f"steady-state: {dt * 1e3:.0f} ms/step, batch {args.batch} "
          f"-> {args.batch / dt:.1f} tok/s")
    print("sampled ids:", np.asarray(out)[:, :10])


if __name__ == "__main__":
    main()
