"""Minimal functional optimizers (optax-like init/update pairs).

The paper's clients run plain SGD (eq. 3) — that is the default everywhere
in the FL path. AdamW is provided for the datacenter training examples.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable  # (grads, state, params) -> (updates, state)


def sgd(lr, momentum: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        mu = (jax.tree_util.tree_map(jnp.zeros_like, params)
              if momentum else None)
        return {"step": jnp.zeros((), jnp.int32), "mu": mu}

    def update(grads, state, params=None):
        step = state["step"] + 1
        lr_t = lr_fn(step)
        if momentum:
            mu = jax.tree_util.tree_map(
                lambda m, g: momentum * m + g, state["mu"], grads)
            upd = jax.tree_util.tree_map(lambda m: -lr_t * m, mu)
            return upd, {"step": step, "mu": mu}
        upd = jax.tree_util.tree_map(lambda g: -lr_t * g, grads)
        return upd, {"step": step, "mu": None}

    return Optimizer(init, update)


def adamw(lr, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        z = jax.tree_util.tree_map(jnp.zeros_like, params)
        return {"step": jnp.zeros((), jnp.int32), "m": z,
                "v": jax.tree_util.tree_map(jnp.zeros_like, params)}

    def update(grads, state, params):
        step = state["step"] + 1
        m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g,
                                   state["m"], grads)
        v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g,
                                   state["v"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr_t = lr_fn(step)

        def u(m, v, p):
            upd = -(lr_t * (m / bc1) / (jnp.sqrt(v / bc2) + eps))
            if weight_decay:
                upd = upd - lr_t * weight_decay * p
            return upd

        return (jax.tree_util.tree_map(u, m, v, params),
                {"step": step, "m": m, "v": v})

    return Optimizer(init, update)


def apply_updates(params, updates):
    return jax.tree_util.tree_map(lambda p, u: p + u, params, updates)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-12))
    return jax.tree_util.tree_map(lambda x: x * scale.astype(x.dtype), grads)
