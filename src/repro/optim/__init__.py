from repro.optim.optimizers import adamw, sgd  # noqa: F401
from repro.optim.schedules import constant, cosine, wsd  # noqa: F401
