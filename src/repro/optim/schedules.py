"""Learning-rate schedules. WSD (warmup-stable-decay) is the MiniCPM recipe
[arXiv:2404.06395] selected by the minicpm-2b config's training setup."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine(peak: float, warmup: int, total: int, floor: float = 0.0):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = peak * s / max(warmup, 1)
        t = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(s < warmup, warm, cos)
    return fn


def wsd(peak: float, warmup: int, stable: int, decay: int,
        floor_frac: float = 0.1):
    """MiniCPM WSD: linear warmup -> flat stable phase -> exponential-style
    decay to floor_frac*peak over `decay` steps."""
    floor = peak * floor_frac

    def fn(step):
        s = step.astype(jnp.float32)
        warm = peak * s / max(warmup, 1)
        t = jnp.clip((s - warmup - stable) / max(decay, 1), 0.0, 1.0)
        dec = peak * (floor / peak) ** t
        return jnp.where(s < warmup, warm,
                         jnp.where(s < warmup + stable, peak, dec))
    return fn
