"""Core transformer layers: GQA/SWA attention (train / prefill / decode),
RoPE, SwiGLU MLP, RMSNorm and OLMo-style non-parametric LayerNorm.

Pure-functional style: ``init_*`` returns a param pytree (nested dicts of
jnp arrays), ``apply_*`` consumes it. No framework dependency — this keeps
sharding annotation (PartitionSpec trees) fully explicit in repro.sharding.
"""
from __future__ import annotations

import functools
import math

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def _normal(key, shape, dtype, scale=0.02):
    return (scale * jax.random.normal(key, shape)).astype(dtype)


def init_dense(key, d_in: int, d_out: int, dtype, scale: float = 0.02):
    return {"w": _normal(key, (d_in, d_out), dtype, scale)}


def apply_dense(params, x):
    return x @ params["w"]


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def apply_norm(params, x, cfg: ModelConfig):
    """RMSNorm (llama family) or non-parametric LayerNorm (OLMo)."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    if cfg.norm == "nonparam_ln":
        # OLMo [arXiv:2402.00838]: LayerNorm without learnable affine params.
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.var(x32, axis=-1, keepdims=True)
        return ((x32 - mu) * jax.lax.rsqrt(var + cfg.norm_eps)).astype(dt)
    ms = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(ms + cfg.norm_eps)
    if params is not None:
        y = y * params["scale"].astype(jnp.float32)
    return y.astype(dt)


def maybe_init_norm(d: int, cfg: ModelConfig, dtype):
    return None if cfg.norm == "nonparam_ln" else init_rmsnorm(d, dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_rotate(x, positions, theta: float):
    """Apply rotary embedding. x: (..., T, H, D), positions: (..., T)."""
    d = x.shape[-1]
    half = d // 2
    freq = jnp.exp(-jnp.log(theta) * (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., :, None].astype(jnp.float32) * freq  # (..., T, half)
    cos = jnp.cos(ang)[..., :, None, :]  # (..., T, 1, half)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:2 * half]
    rot = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    if d > 2 * half:  # odd head_dim tail passes through
        rot = jnp.concatenate([rot, x[..., 2 * half:]], axis=-1)
    return rot.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA, optional sliding window) — train / prefill / decode
# ---------------------------------------------------------------------------

def constrain(x, cfg: ModelConfig, kind: str):
    """Activation sharding constraint (no-op unless launch.steps set the
    hints). kind: 'btd' (batch,seq,d) | 'bthd' (batch,seq,heads,hd) |
    'btf' (batch,seq,ffn). Leading batch dim -> cfg.act_dp axes; head/ffn
    dim -> cfg.act_tp. See EXPERIMENTS.md §Perf iter 1."""
    if not cfg.act_dp and cfg.act_tp is None:
        return x
    from jax.sharding import PartitionSpec as P
    dp = tuple(cfg.act_dp) or None
    if dp is not None and len(dp) == 1:
        dp = dp[0]
    tp = cfg.act_tp
    spec = {
        "btd": P(dp, None, None),
        # sequence parallelism (§Perf iter F): residual-stream activations
        # sharded over the TP axis on the sequence dim — row-parallel
        # projections emit reduce-scatters instead of all-reduces
        "btd_seq": P(dp, tp, None),
        "bthd": P(dp, None, tp, None),
        "btf": P(dp, None, tp),
    }[kind]
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):  # no ambient mesh (unit tests)
        return x


def init_attention(key, cfg: ModelConfig, dtype):
    d, h, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    depth_scale = 0.02 / math.sqrt(2.0 * cfg.num_layers)
    return {
        "wq": init_dense(ks[0], d, h * hd, dtype),
        "wk": init_dense(ks[1], d, hkv * hd, dtype),
        "wv": init_dense(ks[2], d, hkv * hd, dtype),
        "wo": {"w": _normal(ks[3], (h * hd, d), dtype, depth_scale)},
    }


def _gqa_scores(q, k, cfg: ModelConfig):
    """q: (B,T,Hq,D)  k: (B,S,Hkv,D) -> logits (B,Hkv,G,T,S)."""
    b, t, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, t, hkv, g, d)
    logits = jnp.einsum("btkgd,bskd->bkgts", qg.astype(jnp.float32),
                        k.astype(jnp.float32))
    return logits / jnp.sqrt(d).astype(jnp.float32)


def _attend(q, k, v, mask, cfg: ModelConfig):
    """mask: broadcastable to (B,1,1,T,S) boolean — True = attend."""
    logits = _gqa_scores(q, k, cfg)
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    b, t = q.shape[0], q.shape[1]
    hkv, g, d = k.shape[2], q.shape[2] // k.shape[2], v.shape[3]
    out = jnp.einsum("bkgts,bskd->btkgd", probs, v.astype(jnp.float32))
    return out.reshape(b, t, hkv * g, d).astype(q.dtype)


def causal_window_mask(t_positions, s_positions, window: Optional[int]):
    """True where query at t may attend key at s (causal, optional window)."""
    tq = t_positions[..., :, None]
    sk = s_positions[..., None, :]
    m = sk <= tq
    if window is not None:
        m = m & (sk > tq - window)
    return m


ATTN_CHUNK_THRESHOLD = 2048   # switch to the scan/flash path beyond this S
ATTN_KV_CHUNK = 1024


def _chunk_valid(pj, q_pos, window, causal):
    """pj: (B,c) float key positions (-1 = pad); q_pos: (B,T) float."""
    valid = (pj[:, None, :] >= 0)
    if causal:
        valid = valid & (pj[:, None, :] <= q_pos[:, :, None])
    if window is not None:
        valid = valid & (pj[:, None, :] > q_pos[:, :, None] - window)
    return valid  # (B, T, c)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def _flash(q, k, v, q_pos, k_pos, window, causal, chunk):
    """Flash attention with O(T*chunk) memory in BOTH passes.

    q: (B,T,Hq,D); k/v: (B,S,Hkv,D); q_pos/k_pos: float32 positions
    (-1 = padding). The backward recomputes per-chunk probabilities from
    the saved logsumexp — the full (T,S) matrix never exists; without this
    custom VJP the train_4k dry-run needed 684 GB/chip of residuals.
    """
    out, _ = _flash_fwd_impl(q, k, v, q_pos, k_pos, window, causal, chunk)
    return out


def _flash_fwd_impl(q, k, v, q_pos, k_pos, window, causal, chunk):
    bz, t, hq, d = q.shape
    s, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    pad = (-s) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1.0)
    nc = (s + pad) // chunk
    qg = (q.reshape(bz, t, hkv, g, d).astype(jnp.float32)
          * (1.0 / math.sqrt(d)))

    kc = jnp.moveaxis(k.reshape(bz, nc, chunk, hkv, d), 1, 0)
    vc = jnp.moveaxis(v.reshape(bz, nc, chunk, hkv, d), 1, 0)
    pc = jnp.moveaxis(k_pos.reshape(bz, nc, chunk), 1, 0)

    m0 = jnp.full((bz, hkv, g, t), -1e30, jnp.float32)
    l0 = jnp.zeros((bz, hkv, g, t), jnp.float32)
    a0 = jnp.zeros((bz, t, hkv, g, d), jnp.float32)

    def body(carry, xs):
        m, l, acc = carry
        kj, vj, pj = xs
        logits = jnp.einsum("btkgd,bskd->bkgts", qg, kj.astype(jnp.float32))
        valid = _chunk_valid(pj, q_pos, window, causal)
        logits = jnp.where(valid[:, None, None, :, :], logits, -1e30)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgts,bskd->btkgd", p, vj.astype(jnp.float32))
        acc_new = acc * corr.transpose(0, 3, 1, 2)[..., None] + pv
        return (m_new, l_new, acc_new), 0.0

    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
    lse = m + jnp.log(jnp.maximum(l, 1e-30))              # (B,Hkv,G,T)
    return out.reshape(bz, t, hq, d).astype(q.dtype), lse


def _flash_fwd(q, k, v, q_pos, k_pos, window, causal, chunk):
    out, lse = _flash_fwd_impl(q, k, v, q_pos, k_pos, window, causal, chunk)
    return out, (q, k, v, q_pos, k_pos, out, lse)


def _flash_bwd(window, causal, chunk, res, dout):
    q, k, v, q_pos, k_pos, out, lse = res
    bz, t, hq, d = q.shape
    s, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = 1.0 / math.sqrt(d)
    pad = (-s) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1.0)
    nc = (s + pad) // chunk
    qg = q.reshape(bz, t, hkv, g, d).astype(jnp.float32)
    do = dout.reshape(bz, t, hkv, g, d).astype(jnp.float32)
    o32 = out.reshape(bz, t, hkv, g, d).astype(jnp.float32)
    delta = jnp.sum(do * o32, axis=-1)                    # (B,T,Hkv,G)
    delta = delta.transpose(0, 2, 3, 1)                   # (B,Hkv,G,T)

    kc = jnp.moveaxis(k.reshape(bz, nc, chunk, hkv, d), 1, 0)
    vc = jnp.moveaxis(v.reshape(bz, nc, chunk, hkv, d), 1, 0)
    pc = jnp.moveaxis(k_pos.reshape(bz, nc, chunk), 1, 0)

    dq0 = jnp.zeros((bz, t, hkv, g, d), jnp.float32)

    def body(dq, xs):
        kj, vj, pj = xs
        logits = jnp.einsum("btkgd,bskd->bkgts", qg * scale,
                            kj.astype(jnp.float32))
        valid = _chunk_valid(pj, q_pos, window, causal)
        logits = jnp.where(valid[:, None, None, :, :], logits, -1e30)
        p = jnp.exp(logits - lse[..., None])              # normalized probs
        dv_j = jnp.einsum("bkgts,btkgd->bskd", p, do)
        dp = jnp.einsum("btkgd,bskd->bkgts", do, vj.astype(jnp.float32))
        ds = p * (dp - delta[..., None]) * scale
        dq = dq + jnp.einsum("bkgts,bskd->btkgd", ds, kj.astype(jnp.float32))
        dk_j = jnp.einsum("bkgts,btkgd->bskd", ds, qg)
        return dq, (dk_j, dv_j)

    dq, (dks, dvs) = jax.lax.scan(body, dq0, (kc, vc, pc))
    dk = jnp.moveaxis(dks, 0, 1).reshape(bz, s + pad, hkv, d)[:, :s]
    dv = jnp.moveaxis(dvs, 0, 1).reshape(bz, s + pad, hkv, d)[:, :s]
    return (dq.reshape(bz, t, hq, d).astype(q.dtype), dk.astype(k.dtype),
            dv.astype(v.dtype), jnp.zeros_like(q_pos), jnp.zeros_like(k_pos))


_flash.defvjp(_flash_fwd, _flash_bwd)


def _attend_chunked(q, k, v, cfg: ModelConfig, q_pos, k_pos,
                    window: Optional[int], causal: bool,
                    chunk: int = ATTN_KV_CHUNK):
    return _flash(q, k, v, q_pos.astype(jnp.float32),
                  k_pos.astype(jnp.float32), window, causal, chunk)


def attend_positions(q, k, v, cfg: ModelConfig, q_pos, k_pos,
                     window: Optional[int], causal: bool):
    """Dispatcher: direct einsum for small S, chunked flash beyond."""
    s = k.shape[1]
    if s <= ATTN_CHUNK_THRESHOLD:
        mask = (k_pos[:, None, :] >= 0)
        if causal:
            mask = mask & (k_pos[:, None, :] <= q_pos[:, :, None])
        if window is not None:
            mask = mask & (k_pos[:, None, :] > q_pos[:, :, None] - window)
        return _attend(q, k, v, mask[:, None, None, :, :], cfg)
    return _attend_chunked(q, k, v, cfg, q_pos, k_pos, window, causal)


def apply_attention(params, x, cfg: ModelConfig, positions):
    """Full-sequence attention (training / prefill compute).

    x: (B, T, d_model); positions: (B, T). Masking (causal / sliding
    window / bidirectional) is derived from positions and cfg — the (T,S)
    mask is never materialized globally.
    """
    b, t, _ = x.shape
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    x = constrain(x, cfg, "btd")
    q = constrain(apply_dense(params["wq"], x).reshape(b, t, h, hd), cfg, "bthd")
    k = apply_dense(params["wk"], x).reshape(b, t, hkv, hd)
    v = apply_dense(params["wv"], x).reshape(b, t, hkv, hd)
    q = rope_rotate(q, positions, cfg.rope_theta)
    k = rope_rotate(k, positions, cfg.rope_theta)
    out = attend_positions(q, k, v, cfg, positions, positions,
                           cfg.sliding_window, cfg.causal)
    out = constrain(out, cfg, "bthd")
    return apply_dense(params["wo"], out.reshape(b, t, h * hd)), (k, v)


def init_kv_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype):
    """KV cache for one layer. Sliding-window archs use a ring buffer of
    size `window` — this is what makes long_500k decode O(window).
    kv_quant: int8 payload + per-(token, head) f16 scales (EXPERIMENTS
    §Perf E): bytes/token drop from 2*D*2 to 2*D + 4."""
    size = seq_len if cfg.sliding_window is None else min(seq_len, cfg.sliding_window)
    shape = (batch, size, cfg.num_kv_heads, cfg.head_dim)
    if cfg.kv_quant:
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(shape[:3], jnp.float16),
                "v_scale": jnp.zeros(shape[:3], jnp.float16)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _quantize_kv(x):
    """x: (B, 1, Hkv, D) -> (int8 payload, f16 per-(token,head) scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)     # (B,1,Hkv)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float16)


def _dequantize_kv(q, scale, dtype):
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)[..., None]
            ).astype(dtype)


def _attend_quant(q, kq, ks, vq, vs, mask, cfg: ModelConfig):
    """Decode attention directly on the int8 cache: per-(token, head)
    scales fold into the logits / probs instead of materializing a
    dequantized cache copy (halves decode HBM traffic — §Perf iter E)."""
    b, t, hq, d = q.shape
    hkv = kq.shape[2]
    g = hq // hkv
    qg = (q.reshape(b, t, hkv, g, d).astype(jnp.float32)
          * (1.0 / math.sqrt(d)))
    logits = jnp.einsum("btkgd,bskd->bkgts", qg, kq.astype(jnp.float32))
    logits = logits * ks.astype(jnp.float32).transpose(0, 2, 1)[:, :, None, None, :]
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = probs * vs.astype(jnp.float32).transpose(0, 2, 1)[:, :, None, None, :]
    out = jnp.einsum("bkgts,bskd->btkgd", probs, vq.astype(jnp.float32))
    return out.reshape(b, t, hq, d).astype(q.dtype)


def apply_attention_decode(params, x, cache, index, cfg: ModelConfig):
    """Single-token decode step.

    x: (B, 1, d_model); cache: {"k","v"} ring buffers (B, S_c, Hkv, D);
    index: scalar int32 — number of tokens already in the cache.
    Returns (out (B,1,d), new_cache).
    """
    b = x.shape[0]
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    s_c = cache["k"].shape[1]
    pos = jnp.full((b, 1), index, dtype=jnp.int32)
    q = apply_dense(params["wq"], x).reshape(b, 1, h, hd)
    k = apply_dense(params["wk"], x).reshape(b, 1, hkv, hd)
    v = apply_dense(params["wv"], x).reshape(b, 1, hkv, hd)
    q = rope_rotate(q, pos, cfg.rope_theta)
    k = rope_rotate(k, pos, cfg.rope_theta)

    slot = jnp.mod(index, s_c)  # ring-buffer write position
    if cfg.kv_quant:
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
        new_cache = {
            "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], kq, slot, 1),
            "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], vq, slot, 1),
            "k_scale": jax.lax.dynamic_update_slice_in_dim(
                cache["k_scale"], ks, slot, 1),
            "v_scale": jax.lax.dynamic_update_slice_in_dim(
                cache["v_scale"], vs, slot, 1),
        }
    else:
        new_k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
        new_v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
        new_cache = {"k": new_k, "v": new_v}

    # validity: slot j holds absolute position p_j; attend iff p_j <= index
    # and (window) p_j > index - window. Ring algebra:
    j = jnp.arange(s_c)[None, :]                      # (1, S_c)
    wrapped = index + 1 > s_c
    # absolute position stored in slot j after the write:
    abs_pos = jnp.where(
        j <= slot, index - slot + j, index - slot + j - s_c
    )
    valid = (abs_pos >= 0) & (abs_pos <= index)
    if cfg.sliding_window is not None:
        valid = valid & (abs_pos > index - cfg.sliding_window)
    del wrapped
    mask = valid[:, None, None, None, :]              # (1,1,1,1,S_c)
    if cfg.kv_quant:
        out = _attend_quant(q, new_cache["k"], new_cache["k_scale"],
                            new_cache["v"], new_cache["v_scale"], mask, cfg)
    else:
        out = _attend(q, new_k, new_v, mask, cfg)
    out = apply_dense(params["wo"], out.reshape(b, 1, h * hd))
    return out, new_cache


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, dtype, d_ff: Optional[int] = None):
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    depth_scale = 0.02 / math.sqrt(2.0 * cfg.num_layers)
    return {
        "gate": init_dense(ks[0], d, ff, dtype),
        "up": init_dense(ks[1], d, ff, dtype),
        "down": {"w": _normal(ks[2], (ff, d), dtype, depth_scale)},
    }


def apply_mlp(params, x, cfg: ModelConfig = None):
    h = jax.nn.silu(apply_dense(params["gate"], x)) * apply_dense(params["up"], x)
    if cfg is not None:
        h = constrain(h, cfg, "btf")
    return apply_dense(params["down"], h)


# ---------------------------------------------------------------------------
# embeddings / unembedding
# ---------------------------------------------------------------------------

def init_embedding(key, cfg: ModelConfig, dtype):
    p = {"embed": _normal(key, (cfg.vocab_size, cfg.d_model), dtype)}
    if not cfg.tie_embeddings:
        p["unembed"] = _normal(jax.random.fold_in(key, 1),
                               (cfg.d_model, cfg.vocab_size), dtype)
    return p


def embed_tokens(params, tokens, cfg: ModelConfig):
    return jnp.take(params["embed"], tokens, axis=0)


def unembed(params, x, cfg: ModelConfig):
    if cfg.tie_embeddings:
        logits = jnp.einsum("...d,vd->...v", x, params["embed"])
    else:
        logits = x @ params["unembed"]
    return (logits * cfg.logit_scale).astype(jnp.float32)
