"""Mixture-of-Experts layer: top-k router with GShard-style grouped
capacity dispatch.

Tokens are partitioned into groups of ``cfg.moe_group_size``; each group
computes its own one-hot dispatch/combine tensors, bounding the dispatch
memory to O(G * g * E * C) with C = g*k*cf/E (instead of the quadratic
ungrouped form). When the group axis is sharded over the mesh's data axis
and the expert axis over the EP axis, XLA SPMD turns the dispatch/combine
einsums into all-to-alls — the collective the roofline tracks.

Covers: llama4-maverick (128e top-1) [hf:meta-llama/Llama-4-Scout-17B-16E],
mixtral-8x22b (8e top-2, SWA) [arXiv:2401.04088].
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import _normal, apply_dense, constrain


def init_moe(key, cfg: ModelConfig, dtype):
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    depth_scale = 0.02 / math.sqrt(2.0 * cfg.num_layers)
    return {
        "router": {"w": _normal(ks[0], (d, e), dtype)},
        # stacked expert weights, leading expert axis (sharded as EP)
        "gate": _normal(ks[1], (e, d, ff), dtype),
        "up": _normal(ks[2], (e, d, ff), dtype),
        "down": (float(depth_scale) / 0.02 * _normal(ks[3], (e, ff, d), dtype)
                 ).astype(dtype),
    }


def _group_capacity(g: int, cfg: ModelConfig) -> int:
    cap = int(cfg.capacity_factor * cfg.experts_per_token * g
              / max(cfg.num_experts, 1))
    return max(cap, 1)


def router_topk(logits, cfg: ModelConfig):
    """Top-k routing with load-balance aux loss (Switch/GShard style).

    logits: (..., E). Returns (weights (..., E), aux_loss scalar): weights
    nonzero only at chosen experts, rows sum to 1.
    """
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    k = cfg.experts_per_token
    topv, topi = jax.lax.top_k(probs, k)
    sel = jax.nn.one_hot(topi, cfg.num_experts, dtype=probs.dtype)
    weights = jnp.einsum("...k,...ke->...e",
                         topv / jnp.sum(topv, -1, keepdims=True), sel)
    # load-balance loss: E * sum_e f_e * p_e  (Switch Transformer eq. 4)
    flat_sel = sel.reshape(-1, sel.shape[-2], sel.shape[-1])
    f = jnp.mean(jnp.sum(flat_sel, axis=1), axis=0)
    p = jnp.mean(probs.reshape(-1, probs.shape[-1]), axis=0)
    aux = cfg.num_experts * jnp.sum(f * p)
    return weights, aux


def apply_moe(params, x, cfg: ModelConfig):
    """x: (B, T, d). Returns (out (B,T,d), aux_loss)."""
    b, t, d = x.shape
    n_tok = b * t
    g = min(cfg.moe_group_size, n_tok)
    use_smap = cfg.act_ep is not None and cfg.act_ep_size > 1
    if use_smap:
        # group count must be a multiple of the EP axis for the shard_map
        # dispatch (single-token decode pads up to ep groups of 1)
        ep = cfg.act_ep_size
        ng0 = max(1, (n_tok + g - 1) // g)
        ng0 = max(ep, ((ng0 + ep - 1) // ep) * ep)
        g = max(1, (n_tok + ng0 - 1) // ng0)
        pad = ng0 * g - n_tok
    else:
        # pad token count to a multiple of the group size
        pad = (-n_tok) % g
    xt = x.reshape(n_tok, d)
    if pad:
        xt = jnp.pad(xt, ((0, pad), (0, 0)))
    ng = (n_tok + pad) // g
    xg = xt.reshape(ng, g, d)                                 # (G, g, d)
    cap = _group_capacity(g, cfg)

    logits = apply_dense(params["router"], xg)                # (G, g, E)
    weights, aux = router_topk(logits, cfg)                   # (G, g, E)

    # per-group position of each token within its expert queue
    chosen = (weights > 0).astype(jnp.int32)                  # (G, g, E)
    pos_in_expert = jnp.cumsum(chosen, axis=1) * chosen - 1
    keep = chosen * (pos_in_expert < cap)
    weights = weights * keep

    slot = jax.nn.one_hot(jnp.clip(pos_in_expert, 0, cap - 1), cap,
                          dtype=x.dtype)                      # (G, g, E, C)
    disp = keep[..., None].astype(x.dtype) * slot
    combine = weights[..., None].astype(x.dtype) * slot

    # --- all-to-all boundary when E is mesh-sharded: constrain the
    # dispatched tensor to expert-sharded layout so GSPMD emits an
    # all-to-all (G-sharded -> E-sharded) instead of all-gathering the
    # full activation (EXPERIMENTS.md §Perf iter 2) ---
    exp_in = jnp.einsum("Gtd,Gtec->Gecd", xg, disp)           # (G, E, C, d)
    if use_smap:
        # explicit all-to-all dispatch: GSPMD's auto resharding chose
        # all-gathers of the full dispatched tensor (13.4 GB/layer for
        # llama4) — the shard_map region pins the Mesh-TF dataflow:
        # (G/ep, E, C, d) -all_to_all-> (G, E/ep, C, d) -> expert matmuls
        # (local) -> all_to_all back. §Perf iter 2d.
        exp_out = _expert_compute_shardmap(exp_in, params, cfg)
    else:
        exp_in = _constrain_ep4(exp_in, cfg)
        h = jnp.einsum("Gecd,edf->Gecf", exp_in, params["gate"])
        u = jnp.einsum("Gecd,edf->Gecf", exp_in, params["up"])
        act = jax.nn.silu(h) * u
        exp_out = jnp.einsum("Gecf,efd->Gecd", act, params["down"])
        exp_out = _constrain_ep4(exp_out, cfg)
    # --- combine ---
    out = jnp.einsum("Gecd,Gtec->Gtd", exp_out, combine)      # (G, g, d)
    out = _constrain_g(out, cfg)
    out = out.reshape(ng * g, d)[:n_tok]
    return out.reshape(b, t, d), aux


def _constrain_ep4(x, cfg: ModelConfig):
    """(G,E,C,d) -> expert-sharded over act_ep (fallback constraint path
    for expert counts that do not divide the EP axis)."""
    if cfg.act_ep is None:
        return x
    from jax.sharding import PartitionSpec as P
    try:
        return jax.lax.with_sharding_constraint(
            x, P(None, cfg.act_ep, None, None))
    except (ValueError, RuntimeError):
        return x


def _expert_compute_shardmap(exp_in, params, cfg: ModelConfig):
    """Expert FFN with explicit all-to-all dispatch over the EP axis.

    exp_in: (G, E, C, d) with G sharded over cfg.act_ep; expert weights
    (E, d, ff) with E sharded over cfg.act_ep (ff stays auto/TP-sharded).
    """
    from jax.sharding import PartitionSpec as P
    ep = cfg.act_ep

    def inner(x, gate, up, down):
        # local x: (G/n, E, C, d) -> (G, E/n, C, d)
        x = jax.lax.all_to_all(x, ep, split_axis=1, concat_axis=0, tiled=True)
        h = jnp.einsum("Gecd,edf->Gecf", x, gate)
        u = jnp.einsum("Gecd,edf->Gecf", x, up)
        act = jax.nn.silu(h) * u
        y = jnp.einsum("Gecf,efd->Gecd", act, down)
        # back: (G, E/n, C, d) -> (G/n, E, C, d)
        return jax.lax.all_to_all(y, ep, split_axis=0, concat_axis=1,
                                  tiled=True)

    smap = jax.shard_map(
        inner,
        in_specs=(P(ep, None, None, None), P(ep, None, None),
                  P(ep, None, None), P(ep, None, None)),
        out_specs=P(ep, None, None, None),
        axis_names={ep})
    return smap(exp_in, params["gate"], params["up"], params["down"])


def _constrain_g(x, cfg: ModelConfig):
    """(G,g,d) -> token-group-sharded over act_dp."""
    if not cfg.act_dp:
        return x
    from jax.sharding import PartitionSpec as P
    dp = tuple(cfg.act_dp)
    dp = dp[0] if len(dp) == 1 else dp
    try:
        return jax.lax.with_sharding_constraint(x, P(dp, None, None))
    except (ValueError, RuntimeError):
        return x
