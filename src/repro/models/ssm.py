"""Mamba2 (SSD — state-space duality) blocks [arXiv:2405.21060].

Implements the chunked SSD algorithm for training/prefill (quadratic within
a chunk on the MXU, linear across chunks via a state recurrence) and the O(1)
recurrent step for decode. This is the TPU adaptation of the paper's GPU
kernel: chunk-local work is dense einsums (MXU-friendly), the cross-chunk
recurrence is a ``lax.scan`` carrying the (H, P, N) state.

Used by: mamba2-370m [ssm], zamba2-7b [hybrid, arXiv:2411.15242].
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import _normal


def _dims(cfg: ModelConfig):
    d_in = cfg.d_inner
    h = cfg.ssm_nheads
    p = cfg.ssm_head_dim
    g = cfg.ssm_ngroups
    n = cfg.ssm_state
    d_xbc = d_in + 2 * g * n
    return d_in, h, p, g, n, d_xbc


def init_mamba2(key, cfg: ModelConfig, dtype):
    d_in, h, p, g, n, d_xbc = _dims(cfg)
    ks = jax.random.split(key, 5)
    d_proj = 2 * d_in + 2 * g * n + h  # z, x, B, C, dt
    return {
        "in_proj": _normal(ks[0], (cfg.d_model, d_proj), dtype),
        "conv_w": _normal(ks[1], (cfg.conv_kernel, d_xbc), dtype, scale=0.2),
        "a_log": jnp.zeros((h,), jnp.float32),        # A = -exp(a_log) in (-inf,0)
        "dt_bias": jnp.full((h,), -2.0, jnp.float32),  # softplus(-2) ~ 0.13
        "skip_d": jnp.ones((h,), jnp.float32),
        "norm_scale": jnp.ones((d_in,), dtype),
        "out_proj": _normal(ks[4], (d_in, cfg.d_model), dtype,
                            scale=0.02 / math.sqrt(2.0 * cfg.num_layers)),
    }


def _split_proj(params, u, cfg: ModelConfig):
    d_in, h, p, g, n, d_xbc = _dims(cfg)
    zxbcdt = u @ params["in_proj"]
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in:d_in + d_xbc]
    dt = zxbcdt[..., d_in + d_xbc:]
    return z, xbc, dt


def _causal_conv(params, xbc, conv_state=None):
    """Depthwise causal conv width K via shifted adds. xbc: (B, T, C).
    conv_state: (B, K-1, C) tail of previous tokens (decode/prefill chain)."""
    w = params["conv_w"]                      # (K, C)
    k = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros(xbc.shape[:1] + (k - 1,) + xbc.shape[2:], xbc.dtype)
    else:
        pad = conv_state.astype(xbc.dtype)
    full = jnp.concatenate([pad, xbc], axis=1)          # (B, T+K-1, C)
    t = xbc.shape[1]
    out = sum(full[:, i:i + t, :] * w[i][None, None, :] for i in range(k))
    new_state = full[:, -(k - 1):, :] if k > 1 else full[:, :0, :]
    return jax.nn.silu(out), new_state


def _gated_norm(params, y, z, cfg: ModelConfig):
    dt = y.dtype
    y32 = (y * jax.nn.silu(z)).astype(jnp.float32)
    ms = jnp.mean(jnp.square(y32), axis=-1, keepdims=True)
    return (y32 * jax.lax.rsqrt(ms + cfg.norm_eps)
            * params["norm_scale"].astype(jnp.float32)).astype(dt)


def ssd_chunked(x, dt, a, B, C, cfg: ModelConfig, init_state=None,
                use_kernel: bool = False):
    """Chunked SSD forward.

    x: (Bz, T, H, P)  dt: (Bz, T, H)  a: (H,) negative
    B, C: (Bz, T, G, N). Returns (y (Bz,T,H,P), final_state (Bz,H,P,N)).
    use_kernel: route the intra-chunk quadratic part through the Pallas
    kernel (repro.kernels.ssd_chunk) — the TPU hot path; default stays
    pure-jnp on CPU.
    """
    bz, t, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    q = min(cfg.ssm_chunk, t)
    pad = (-t) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    tt = t + pad
    nc = tt // q
    rep = h // g  # heads per B/C group

    # reshape to chunks
    xc = x.reshape(bz, nc, q, h, p)
    dtc = dt.reshape(bz, nc, q, h)                       # (Bz,NC,Q,H)
    Bc = B.reshape(bz, nc, q, g, n)
    Cc = C.reshape(bz, nc, q, g, n)
    Bh = jnp.repeat(Bc, rep, axis=3)                     # (Bz,NC,Q,H,N)
    Ch = jnp.repeat(Cc, rep, axis=3)

    da = dtc * a[None, None, None, :]                    # log-decay per step (<0)
    cum = jnp.cumsum(da, axis=2)                         # (Bz,NC,Q,H)
    xdt = xc * dtc[..., None]

    if use_kernel:
        from repro.kernels.ssd_chunk import ssd_intra_chunk_pallas
        gsz = bz * nc * h
        cum_f = cum.transpose(0, 1, 3, 2).reshape(gsz, q)
        b_f = Bh.transpose(0, 1, 3, 2, 4).reshape(gsz, q, n)
        c_f = Ch.transpose(0, 1, 3, 2, 4).reshape(gsz, q, n)
        x_f = xdt.transpose(0, 1, 3, 2, 4).reshape(gsz, q, p)
        y_f, st_f, dec_f = ssd_intra_chunk_pallas(cum_f, b_f, c_f, x_f)
        y_intra = y_f.reshape(bz, nc, h, q, p).transpose(0, 1, 3, 2, 4)
        chunk_state = st_f.reshape(bz, nc, h, n, p).transpose(0, 1, 2, 4, 3)
        chunk_decay = dec_f.reshape(bz, nc, h)
    else:
        # intra-chunk (dual / attention-like form)
        li = cum[:, :, :, None, :]                       # (Bz,NC,Q,1,H) query i
        lj = cum[:, :, None, :, :]                       # (Bz,NC,1,Q,H) key j
        decay = jnp.exp(jnp.clip(li - lj, -60.0, 0.0))   # (Bz,NC,Q,Q,H)
        causal = jnp.tril(jnp.ones((q, q), bool))[None, None, :, :, None]
        scores = jnp.einsum("bcihn,bcjhn->bcijh", Ch, Bh) * decay
        scores = jnp.where(causal, scores, 0.0)
        y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores, xdt)

        # chunk summaries: state contributed by each chunk.
        # cum[-1]-cum[j] <= 0 (negative log decays), so clip to [-60, 0].
        tail = jnp.exp(jnp.clip(cum[:, :, -1:, :] - cum, -60.0, 0.0))
        chunk_state = jnp.einsum("bcjhn,bcjhp->bchpn",
                                 Bh * tail[..., None], xdt)
        chunk_decay = jnp.exp(jnp.clip(cum[:, :, -1, :], -60.0, 0.0))

    # cross-chunk recurrence
    if init_state is None:
        init_state = jnp.zeros((bz, h, p, n), jnp.float32)

    def step(s, inp):
        cs, cd = inp                                      # (Bz,H,P,N), (Bz,H)
        s_out = s                                         # state BEFORE this chunk
        s_new = s * cd[:, :, None, None] + cs
        return s_new, s_out

    states = jnp.swapaxes(chunk_state, 0, 1).astype(jnp.float32)  # (NC,Bz,H,P,N)
    decays = jnp.swapaxes(chunk_decay, 0, 1)
    final_state, prev_states = jax.lax.scan(step, init_state, (states, decays))
    prev_states = jnp.swapaxes(prev_states, 0, 1)         # (Bz,NC,H,P,N)

    # inter-chunk output: C_i · (decay_to_i * S_prev)
    into = jnp.exp(jnp.clip(cum, -60.0, 0.0))             # decay from chunk start
    y_inter = jnp.einsum("bcihn,bchpn->bcihp",
                         Ch * into[..., None], prev_states.astype(Ch.dtype))

    y = (y_intra + y_inter).reshape(bz, tt, h, p)[:, :t]
    return y.astype(x.dtype), final_state


def init_ssm_state(cfg: ModelConfig, batch: int, dtype):
    d_in, h, p, g, n, d_xbc = _dims(cfg)
    return {
        "ssm": jnp.zeros((batch, h, p, n), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, d_xbc), dtype),
    }


def apply_mamba2(params, u, cfg: ModelConfig, state=None):
    """Full-sequence forward (train / prefill). u: (B, T, d_model).
    Returns (out (B,T,d_model), new_state dict)."""
    d_in, h, p, g, n, d_xbc = _dims(cfg)
    bz, t, _ = u.shape
    z, xbc, dt = _split_proj(params, u, cfg)
    conv_in = None if state is None else state["conv"]
    xbc, conv_state = _causal_conv(params, xbc, conv_in)
    x = xbc[..., :d_in].reshape(bz, t, h, p)
    B = xbc[..., d_in:d_in + g * n].reshape(bz, t, g, n)
    C = xbc[..., d_in + g * n:].reshape(bz, t, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["a_log"])
    init_s = None if state is None else state["ssm"]
    y, final_state = ssd_chunked(x, dt, a, B, C, cfg, init_s)
    y = y + x * params["skip_d"][None, None, :, None].astype(y.dtype)
    y = _gated_norm(params, y.reshape(bz, t, d_in), z, cfg)
    out = y @ params["out_proj"]
    return out, {"ssm": final_state, "conv": conv_state}


def apply_mamba2_decode(params, u, state, cfg: ModelConfig):
    """Single-token recurrent step. u: (B, 1, d_model). O(1) in context length —
    this is why SSM/hybrid archs run long_500k."""
    d_in, h, p, g, n, d_xbc = _dims(cfg)
    bz = u.shape[0]
    z, xbc, dt = _split_proj(params, u, cfg)
    xbc, conv_state = _causal_conv(params, xbc, state["conv"])
    x = xbc[:, 0, :d_in].reshape(bz, h, p)
    B = xbc[:, 0, d_in:d_in + g * n].reshape(bz, g, n)
    C = xbc[:, 0, d_in + g * n:].reshape(bz, g, n)
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])  # (B,H)
    a = -jnp.exp(params["a_log"])
    decay = jnp.exp(dt1 * a[None, :])                     # (B,H)
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=1)                       # (B,H,N)
    Ch = jnp.repeat(C, rep, axis=1)
    xdt = (x * dt1[..., None]).astype(jnp.float32)
    s_new = (state["ssm"] * decay[:, :, None, None]
             + jnp.einsum("bhn,bhp->bhpn", Bh.astype(jnp.float32), xdt))
    y = jnp.einsum("bhn,bhpn->bhp", Ch.astype(jnp.float32), s_new)
    y = y.astype(u.dtype) + x * params["skip_d"][None, :, None].astype(u.dtype)
    y = _gated_norm(params, y.reshape(bz, 1, d_in), z, cfg)
    out = y @ params["out_proj"]
    return out, {"ssm": s_new, "conv": conv_state}
