"""Model configuration schema covering all assigned architecture families.

Families: dense | moe | ssm | hybrid | vlm | audio
Every assigned architecture in ``repro.configs`` instantiates ``ModelConfig``
with the exact published numbers (citations in each config module).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int                   # 0 for attention-free (pure SSM)
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    # ---- attention ----
    head_dim: Optional[int] = None   # default: d_model // num_heads
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None   # None = full attention
    causal: bool = True                    # False for encoder-only (hubert)

    # ---- MoE ----
    num_experts: int = 0
    experts_per_token: int = 0
    moe_layer_period: int = 1        # every p-th layer is MoE (1 = all)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01  # load-balance loss weight
    moe_group_size: int = 2048       # GShard token-group size (bounds the
                                     # one-hot dispatch tensor to g^2-ish)

    # ---- SSM (Mamba2 / SSD, arXiv:2405.21060) ----
    ssm_state: int = 0               # N: state size per head
    ssm_expand: int = 2              # d_inner = expand * d_model
    ssm_head_dim: int = 64           # P: channels per SSM head
    ssm_ngroups: int = 1             # groups for B/C
    ssm_chunk: int = 256             # SSD chunk length
    conv_kernel: int = 4             # depthwise conv width

    # ---- hybrid (Zamba2, arXiv:2411.15242) ----
    shared_attn_period: int = 0      # every p-th layer applies the shared attn block

    # ---- norms / residuals ----
    norm: str = "rmsnorm"            # rmsnorm | nonparam_ln (OLMo, arXiv:2402.00838)
    norm_eps: float = 1e-5
    residual_scale: float = 1.0      # MiniCPM depth-scaled residual (arXiv:2404.06395)
    logit_scale: float = 1.0         # granite-style logit scaling
    tie_embeddings: bool = True

    # ---- modality frontends (STUBS per instructions) ----
    modality: str = "text"           # text | vision_text | audio
    frontend_dim: int = 0            # dim of precomputed patch/frame embeddings
    num_patches: int = 0             # VLM: patches prepended per example
    encoder_only: bool = False       # hubert: no decode path
    mask_prob: float = 0.08          # hubert masked-prediction probability

    # ---- training memory policy ----
    remat: str = "none"              # none | block (checkpoint each layer)

    # ---- serving memory policy ----
    kv_quant: bool = False           # int8 KV cache (per-token-per-head
                                     # scales); halves the decode memory
                                     # roofline term (EXPERIMENTS §Perf E)

    # ---- distribution hints (set by launch.steps.runtime_config) ----
    # activation sharding constraints: without them GSPMD loses the batch/
    # head sharding inside vmap+scan and replicates activations (measured:
    # 16x compute + TB-scale all-reduces, EXPERIMENTS.md §Perf iter 1).
    act_dp: tuple = ()               # mesh axes for the activation batch dim
    act_tp: Optional[str] = None     # mesh axis for heads/ffn dims
    act_ep: Optional[str] = None     # mesh axis for the expert dim (MoE
                                     # dispatch all-to-all boundary)
    act_ep_size: int = 0             # size of that axis (shard_map dispatch)
    seq_parallel: bool = False       # sequence-sharded residual stream
                                     # between blocks (§Perf iter F)

    # ---- dtypes ----
    param_dtype: str = "float32"
    compute_dtype: str = "float32"

    # ---- provenance ----
    source: str = ""                 # citation for the config numbers

    def __post_init__(self):
        if self.head_dim is None and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.family in ("moe",) and self.num_experts <= 0:
            raise ValueError(f"{self.name}: moe family requires num_experts>0")
        if self.family in ("ssm", "hybrid") and self.ssm_state <= 0:
            raise ValueError(f"{self.name}: ssm/hybrid family requires ssm_state>0")
        if self.num_heads and self.num_heads % max(self.num_kv_heads, 1) != 0:
            raise ValueError(f"{self.name}: num_heads must divide by num_kv_heads")

    # ------------------------------------------------------------------
    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def is_moe_layer(self, layer_idx: int) -> bool:
        return self.num_experts > 0 and (layer_idx % self.moe_layer_period == 0)

    def is_shared_attn_layer(self, layer_idx: int) -> bool:
        """Zamba2-style: a shared attention block every `shared_attn_period` layers."""
        return self.shared_attn_period > 0 and (layer_idx % self.shared_attn_period == 0)

    @property
    def supports_decode(self) -> bool:
        return not self.encoder_only

    @property
    def subquadratic(self) -> bool:
        """True if the arch can run long_500k (O(T) or windowed attention)."""
        return self.family in ("ssm", "hybrid") or self.sliding_window is not None

    def reduced(self, **overrides) -> "ModelConfig":
        """A smoke-test-sized variant of the same family (<=2 layers, d_model<=512,
        <=4 experts) for CPU forward/train-step tests."""
        small = dict(
            num_layers=2,
            d_model=min(self.d_model, 128),
            vocab_size=min(self.vocab_size, 512),
            d_ff=min(self.d_ff, 256) if self.d_ff else 0,
        )
        if self.num_heads:
            heads = min(self.num_heads, 4)
            ratio = max(1, self.num_heads // max(self.num_kv_heads, 1))
            small.update(
                num_heads=heads,
                num_kv_heads=max(1, heads // min(ratio, heads)),
                head_dim=32,
            )
        if self.num_experts:
            small.update(num_experts=4, experts_per_token=min(self.experts_per_token, 2))
        if self.ssm_state:
            small.update(ssm_state=16, ssm_head_dim=32, ssm_chunk=32)
        if self.shared_attn_period:
            small.update(shared_attn_period=2)
        if self.sliding_window:
            small.update(sliding_window=64)
        if self.num_patches:
            small.update(num_patches=8, frontend_dim=min(self.frontend_dim, 64))
        if self.frontend_dim and not self.num_patches:
            small.update(frontend_dim=min(self.frontend_dim, 64))
        small["name"] = self.name + "-reduced"
        small.update(overrides)
        return dataclasses.replace(self, **small)
