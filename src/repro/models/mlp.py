"""The paper's own model: an MLP with two hidden layers of 10 nodes for
MNIST-like 10-class classification (Section IV-A).

Kept separate from the transformer zoo — this is the model the FL
experiments (Fig. 3/4, Table I) train.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_mlp_params(key, d_in: int = 784, hidden: int = 10, n_classes: int = 10):
    k1, k2, k3 = jax.random.split(key, 3)

    def lin(k, i, o):
        w = jax.random.normal(k, (i, o)) * jnp.sqrt(2.0 / i)
        return {"w": w.astype(jnp.float32), "b": jnp.zeros((o,), jnp.float32)}

    return {"l1": lin(k1, d_in, hidden), "l2": lin(k2, hidden, hidden),
            "l3": lin(k3, hidden, n_classes)}


def mlp_apply(params, x):
    h = jax.nn.relu(x @ params["l1"]["w"] + params["l1"]["b"])
    h = jax.nn.relu(h @ params["l2"]["w"] + params["l2"]["b"])
    return h @ params["l3"]["w"] + params["l3"]["b"]


def mlp_loss(params, batch):
    logits = mlp_apply(params, batch["x"])
    labels = batch["y"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(lse - ll)


def mlp_accuracy(params, batch):
    logits = mlp_apply(params, batch["x"])
    return jnp.mean((jnp.argmax(logits, -1) == batch["y"]).astype(jnp.float32))
