"""Model assembly for all assigned architecture families.

Layers are *stacked* along a leading L axis and iterated with ``lax.scan`` —
this keeps HLO size and compile time bounded for 48–81-layer configs (critical
for the 80-combination multi-pod dry-run) and gives XLA a single fusion region
per block.

Families:
  dense  — llama-style decoder (smollm, olmo, minicpm, granite)
  moe    — GShard-style expert blocks (llama4-maverick top-1, mixtral top-2 SWA)
  ssm    — Mamba2 / SSD (mamba2-370m)
  hybrid — Mamba2 backbone + shared attention block every p layers (zamba2-7b)
  vlm    — decoder consuming [patch embeddings ; text] (internvl2-1b backbone)
  audio  — bidirectional encoder + masked prediction (hubert-xlarge backbone)

VLM/audio modality frontends are STUBS per instructions: ``input_specs``
provides precomputed patch/frame embeddings; a learned projector maps them
into d_model.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.config import ModelConfig

PyTree = Any


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# per-layer init / apply
# ---------------------------------------------------------------------------

def init_block(key, cfg: ModelConfig, dtype, layer_idx_static: int = 0):
    """One main-trunk block. dense/moe/vlm/audio: attn+ffn. ssm/hybrid: mamba2."""
    if cfg.family in ("ssm", "hybrid"):
        return {"mamba": SSM.init_mamba2(key, cfg, dtype),
                "norm": L.maybe_init_norm(cfg.d_model, cfg, dtype)}
    k1, k2 = jax.random.split(key)
    block = {
        "attn": L.init_attention(k1, cfg, dtype),
        "ln1": L.maybe_init_norm(cfg.d_model, cfg, dtype),
        "ln2": L.maybe_init_norm(cfg.d_model, cfg, dtype),
    }
    if cfg.num_experts > 0:
        block["moe"] = MOE.init_moe(k2, cfg, dtype)
    else:
        block["mlp"] = L.init_mlp(k2, cfg, dtype)
    return block


def apply_block_full(block, x, cfg: ModelConfig, positions):
    """Full-sequence attention block (train / prefill). Returns (x, kv, aux)."""
    h = L.apply_norm(block["ln1"], x, cfg)
    attn_out, kv = L.apply_attention(block["attn"], h, cfg, positions)
    x = x + cfg.residual_scale * attn_out
    h = L.apply_norm(block["ln2"], x, cfg)
    if cfg.num_experts > 0:
        ffn_out, aux = MOE.apply_moe(block["moe"], h, cfg)
    else:
        ffn_out, aux = L.apply_mlp(block["mlp"], h, cfg), jnp.zeros((), jnp.float32)
    x = x + cfg.residual_scale * ffn_out
    x = L.constrain(x, cfg, "btd_seq" if cfg.seq_parallel else "btd")
    return x, kv, aux


def apply_block_decode(block, x, cache, index, cfg: ModelConfig):
    h = L.apply_norm(block["ln1"], x, cfg)
    attn_out, new_cache = L.apply_attention_decode(block["attn"], h, cache, index, cfg)
    x = x + cfg.residual_scale * attn_out
    h = L.apply_norm(block["ln2"], x, cfg)
    if cfg.num_experts > 0:
        ffn_out, _ = MOE.apply_moe(block["moe"], h, cfg)
    else:
        ffn_out = L.apply_mlp(block["mlp"], h)
    x = x + cfg.residual_scale * ffn_out
    return x, new_cache


def apply_mamba_block_full(block, x, cfg: ModelConfig, state=None):
    h = L.apply_norm(block["norm"], x, cfg)
    out, new_state = SSM.apply_mamba2(block["mamba"], h, cfg, state)
    return x + cfg.residual_scale * out, new_state


def apply_mamba_block_decode(block, x, state, cfg: ModelConfig):
    h = L.apply_norm(block["norm"], x, cfg)
    out, new_state = SSM.apply_mamba2_decode(block["mamba"], h, state, cfg)
    return x + cfg.residual_scale * out, new_state


# ---------------------------------------------------------------------------
# model init
# ---------------------------------------------------------------------------

def n_shared_slots(cfg: ModelConfig) -> int:
    if cfg.shared_attn_period <= 0:
        return 0
    return (cfg.num_layers + cfg.shared_attn_period - 1) // cfg.shared_attn_period


def init_model(key, cfg: ModelConfig) -> PyTree:
    dtype = _dtype(cfg)
    keys = jax.random.split(key, 8)
    params: Dict[str, PyTree] = {}
    params["embedding"] = L.init_embedding(keys[0], cfg, dtype)

    layer_keys = jax.random.split(keys[1], cfg.num_layers)
    params["layers"] = jax.vmap(lambda k: init_block(k, cfg, dtype))(layer_keys)

    if cfg.family == "hybrid":
        # Zamba2 [arXiv:2411.15242]: ONE shared attention+MLP block reused
        # every `shared_attn_period` layers (weight sharing across depth).
        params["shared_attn"] = {
            "attn": L.init_attention(keys[2], cfg, dtype),
            "mlp": L.init_mlp(keys[3], cfg, dtype),
            "ln1": L.maybe_init_norm(cfg.d_model, cfg, dtype),
            "ln2": L.maybe_init_norm(cfg.d_model, cfg, dtype),
        }
    params["final_norm"] = L.maybe_init_norm(cfg.d_model, cfg, dtype)

    if cfg.modality == "vision_text":
        params["projector"] = L.init_dense(keys[4], cfg.frontend_dim, cfg.d_model, dtype)
    if cfg.modality == "audio":
        params["frontend_proj"] = L.init_dense(keys[5], cfg.frontend_dim, cfg.d_model, dtype)
        params["mask_emb"] = L._normal(keys[6], (cfg.d_model,), dtype)
    return params


def param_count(params: PyTree) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))


def active_param_count(params: PyTree, cfg: ModelConfig) -> int:
    """MoE-aware: count each expert tensor at k/E of its size."""
    total = 0
    for path, x in jax.tree_util.tree_leaves_with_path(params):
        keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        size = int(x.size)
        if cfg.num_experts > 0 and any(k in ("gate", "up", "down") for k in keys) \
                and "moe" in keys:
            size = size * max(cfg.experts_per_token, 1) // cfg.num_experts
        total += size
    return total


# ---------------------------------------------------------------------------
# embedding / trunk entry
# ---------------------------------------------------------------------------

def embed_inputs(params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig):
    """Returns (x (B,T,d), positions (B,T), text_offset)."""
    dtype = _dtype(cfg)
    if cfg.modality == "vision_text":
        patches = batch["patch_embeds"].astype(dtype)           # (B, P, F)
        proj = L.apply_dense(params["projector"], patches)      # (B, P, d)
        tok = L.embed_tokens(params["embedding"], batch["tokens"], cfg)
        x = jnp.concatenate([proj, tok], axis=1)
        b, t = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(t)[None, :], (b, t))
        return x, positions, cfg.num_patches
    if cfg.modality == "audio":
        feats = batch["frame_feats"].astype(dtype)              # (B, T, F)
        x = L.apply_dense(params["frontend_proj"], feats)
        if "mask_indicator" in batch:
            m = batch["mask_indicator"][..., None].astype(dtype)  # (B,T,1)
            x = x * (1 - m) + params["mask_emb"][None, None, :] * m
        b, t = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(t)[None, :], (b, t))
        return x, positions, 0
    tok = batch["tokens"]
    x = L.embed_tokens(params["embedding"], tok, cfg)
    b, t = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(t)[None, :], (b, t))
    return x, positions, 0


# ---------------------------------------------------------------------------
# full forward (train / prefill)
# ---------------------------------------------------------------------------

def forward(params, batch, cfg: ModelConfig, return_cache: bool = False,
            return_hidden: bool = False):
    """Full-sequence forward. Returns (logits, aux_loss, caches_or_None).
    return_hidden: skip the unembedding (loss_fn streams it in chunks).

    caches: attention KV stacked (L, B, S_c, Hkv, D) ring-ready; ssm states
    stacked; hybrid shared-attn caches stacked over shared slots.
    """
    x, positions, _ = embed_inputs(params, batch, cfg)
    b, t, _ = x.shape

    if cfg.family in ("ssm", "hybrid"):
        return _forward_recurrent(params, x, positions, cfg, return_cache,
                                  return_hidden)

    def body(carry, layer):
        h, aux = carry
        h, kv, aux_l = apply_block_full(layer, h, cfg, positions)
        return (h, aux + aux_l), kv if return_cache else 0.0

    if cfg.remat == "block":
        body = jax.checkpoint(body, prevent_cse=False)
    (x, aux), kvs = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                 params["layers"])
    x = L.apply_norm(params["final_norm"], x, cfg)
    caches = None
    if return_cache:
        ks, vs = kvs
        caches = {"k": ks, "v": vs}   # (L, B, T, Hkv, D)
    if return_hidden:
        return x, aux / cfg.num_layers, caches
    logits = L.unembed(params["embedding"], x, cfg)
    return logits, aux / cfg.num_layers, caches


def _forward_recurrent(params, x, positions, cfg: ModelConfig, return_cache,
                       return_hidden: bool = False):
    b, t, _ = x.shape
    n_sh = n_shared_slots(cfg)
    shared = params.get("shared_attn")
    idxs = jnp.arange(cfg.num_layers)

    def body(carry, inp):
        h = carry
        layer, i = inp
        if shared is not None:
            def with_attn(h):
                z = L.apply_norm(shared["ln1"], h, cfg)
                a_out, kv = L.apply_attention(shared["attn"], z, cfg, positions)
                h2 = h + cfg.residual_scale * a_out
                z2 = L.apply_norm(shared["ln2"], h2, cfg)
                return h2 + cfg.residual_scale * L.apply_mlp(shared["mlp"], z2), kv
            def without(h):
                hkv, hd = cfg.num_kv_heads, cfg.head_dim
                dummy = jnp.zeros((b, t, hkv, hd), h.dtype)
                return h, (dummy, dummy)
            h, kv = jax.lax.cond(i % cfg.shared_attn_period == 0, with_attn, without, h)
        else:
            kv = 0.0
        h, state = apply_mamba_block_full(layer, h, cfg)
        out = (state, kv) if return_cache else (0.0, 0.0)
        return h, out

    if cfg.remat == "block":
        body = jax.checkpoint(body, prevent_cse=False)
    x, outs = jax.lax.scan(body, x, (params["layers"], idxs))
    x = L.apply_norm(params["final_norm"], x, cfg)
    caches = None
    if return_cache:
        states, kvs = outs
        caches = {"ssm_states": states}
        if shared is not None:
            ks, vs = kvs
            # keep only the shared-attn slots (every period-th layer)
            sel = jnp.arange(0, cfg.num_layers, cfg.shared_attn_period)
            caches["shared_kv"] = {"k": ks[sel], "v": vs[sel]}
        del n_sh
    if return_hidden:
        return x, jnp.zeros((), jnp.float32), caches
    logits = L.unembed(params["embedding"], x, cfg)
    return logits, jnp.zeros((), jnp.float32), caches


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_decode_state(cfg: ModelConfig, batch: int, seq_len: int) -> PyTree:
    """ShapeDtypeStruct-compatible decode state (KV ring buffers / SSM states)."""
    dtype = _dtype(cfg)
    if cfg.family in ("ssm", "hybrid"):
        one = SSM.init_ssm_state(cfg, batch, dtype)
        state = {
            "ssm": jnp.zeros((cfg.num_layers,) + one["ssm"].shape, one["ssm"].dtype),
            "conv": jnp.zeros((cfg.num_layers,) + one["conv"].shape, one["conv"].dtype),
        }
        if cfg.family == "hybrid":
            n_sh = n_shared_slots(cfg)
            kc = L.init_kv_cache(cfg, batch, seq_len, dtype)
            state["shared_kv"] = {
                name: jnp.zeros((n_sh,) + arr.shape, arr.dtype)
                for name, arr in kc.items()}
        return state
    kc = L.init_kv_cache(cfg, batch, seq_len, dtype)
    return {name: jnp.zeros((cfg.num_layers,) + arr.shape, arr.dtype)
            for name, arr in kc.items()}


def cache_from_prefill(caches, cfg: ModelConfig, batch: int,
                       seq_len: int, prefill_len: int) -> PyTree:
    """Convert forward(return_cache=True) caches into a decode state.

    Attention caches (L,B,T,Hkv,D) are written into the ring buffers at
    the positions decode expects (slot = pos % ring_size, so for
    prefill_len <= ring_size they land at [0, prefill_len)); SSM states
    pass through. This is the prefill -> decode hand-off of the serving
    path (tests/test_serving.py validates logit continuity)."""
    dtype = _dtype(cfg)
    state = init_decode_state(cfg, batch, seq_len)

    def fill_kv(ring, got):
        size = ring.shape[2]
        take = min(prefill_len, size)
        src = got[:, :, prefill_len - take:prefill_len]
        if take == prefill_len:           # no wrap: slots [0, take)
            return ring.at[:, :, :take].set(src.astype(ring.dtype))
        # wrapped ring: absolute position p lives in slot p % size
        pos = jnp.arange(prefill_len - take, prefill_len)
        slots = pos % size
        return ring.at[:, :, slots].set(src.astype(ring.dtype))

    def fill_kv_quant(state_kv, name, got):
        """Quantize prefill K/V into the int8 ring + scale buffers."""
        amax = jnp.max(jnp.abs(got.astype(jnp.float32)), axis=-1)
        scale = jnp.maximum(amax / 127.0, 1e-8)
        q = jnp.clip(jnp.round(got.astype(jnp.float32) / scale[..., None]),
                     -127, 127).astype(jnp.int8)
        return {name: fill_kv(state_kv[name], q),
                f"{name}_scale": fill_kv(state_kv[f"{name}_scale"],
                                         scale.astype(jnp.float16))}

    if cfg.family in ("ssm", "hybrid"):
        st = caches["ssm_states"]   # {"ssm": (L,B,H,P,N), "conv": (L,B,K-1,C)}
        new = {"ssm": st["ssm"].astype(state["ssm"].dtype),
               "conv": st["conv"].astype(state["conv"].dtype)}
        if cfg.family == "hybrid" and "shared_kv" in caches:
            new["shared_kv"] = {
                "k": fill_kv(state["shared_kv"]["k"], caches["shared_kv"]["k"]),
                "v": fill_kv(state["shared_kv"]["v"], caches["shared_kv"]["v"]),
            }
        elif cfg.family == "hybrid":
            new["shared_kv"] = state["shared_kv"]
        return new
    if cfg.kv_quant:
        out = {}
        out.update(fill_kv_quant(state, "k", caches["k"]))
        out.update(fill_kv_quant(state, "v", caches["v"]))
        return out
    return {"k": fill_kv(state["k"], caches["k"]),
            "v": fill_kv(state["v"], caches["v"])}


def decode_step(params, tokens, state, index, cfg: ModelConfig,
                patch_embeds=None):
    """One-token decode. tokens: (B, 1) int32; index: scalar int32 tokens so far.
    Returns (logits (B,1,V), new_state)."""
    x = L.embed_tokens(params["embedding"], tokens, cfg)

    if cfg.family in ("ssm", "hybrid"):
        return _decode_recurrent(params, x, state, index, cfg)

    def body(h, inp):
        layer, cache = inp
        h, new_cache = apply_block_decode(layer, h, cache, index, cfg)
        return h, new_cache

    x, new_kv = jax.lax.scan(body, x, (params["layers"], dict(state)))
    x = L.apply_norm(params["final_norm"], x, cfg)
    logits = L.unembed(params["embedding"], x, cfg)
    return logits, new_kv


def _decode_recurrent(params, x, state, index, cfg: ModelConfig):
    shared = params.get("shared_attn")
    b = x.shape[0]
    idxs = jnp.arange(cfg.num_layers)

    if shared is not None:
        shared_kv = state["shared_kv"]

        def body(carry, inp):
            h, skv = carry
            layer, i, lstate = inp
            def with_attn(operand):
                h, skv = operand
                slot = i // cfg.shared_attn_period
                cache = {name: jax.lax.dynamic_index_in_dim(arr, slot, 0, False)
                         for name, arr in skv.items()}
                z = L.apply_norm(shared["ln1"], h, cfg)
                a_out, nc = L.apply_attention_decode(shared["attn"], z, cache, index, cfg)
                h2 = h + cfg.residual_scale * a_out
                z2 = L.apply_norm(shared["ln2"], h2, cfg)
                h2 = h2 + cfg.residual_scale * L.apply_mlp(shared["mlp"], z2)
                skv = {name: jax.lax.dynamic_update_index_in_dim(
                           skv[name], nc[name], slot, 0) for name in skv}
                return h2, skv
            h, skv = jax.lax.cond(i % cfg.shared_attn_period == 0,
                                  with_attn, lambda o: o, (h, skv))
            h, new_lstate = apply_mamba_block_decode(layer, h, lstate, cfg)
            return (h, skv), new_lstate

        (x, shared_kv), new_states = jax.lax.scan(
            body, (x, shared_kv),
            (params["layers"], idxs, {"ssm": state["ssm"], "conv": state["conv"]}))
        new_state = {"ssm": new_states["ssm"], "conv": new_states["conv"],
                     "shared_kv": shared_kv}
    else:
        def body(h, inp):
            layer, lstate = inp
            h, new_lstate = apply_mamba_block_decode(layer, h, lstate, cfg)
            return h, new_lstate
        x, new_states = jax.lax.scan(
            body, x, (params["layers"], {"ssm": state["ssm"], "conv": state["conv"]}))
        new_state = {"ssm": new_states["ssm"], "conv": new_states["conv"]}

    x = L.apply_norm(params["final_norm"], x, cfg)
    logits = L.unembed(params["embedding"], x, cfg)
    return logits, new_state


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def _xent(logits, labels, mask=None):
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


XENT_CHUNK_THRESHOLD = 2 ** 27   # tokens*vocab above which xent streams
XENT_CHUNK_TOKENS = 512


def _xent_chunked(params, hidden, labels, mask, cfg: ModelConfig):
    """Streamed cross-entropy: unembed+logsumexp one token-chunk at a time
    (jax.checkpoint'd, so backward recomputes chunk logits instead of
    keeping (T, V) alive — EXPERIMENTS.md §Perf iter C)."""
    b, t, d = hidden.shape
    c = min(XENT_CHUNK_TOKENS, t)
    pad = (-t) % c
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    nc = (t + pad) // c
    hs = jnp.moveaxis(hidden.reshape(b, nc, c, d), 1, 0)
    ys = jnp.moveaxis(labels.reshape(b, nc, c), 1, 0)
    ms = jnp.moveaxis(mask.reshape(b, nc, c), 1, 0)

    @jax.checkpoint
    def body(acc, xs):
        h, y, m = xs
        logits = L.unembed(params["embedding"], h, cfg)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
        nll = (lse - ll) * m
        return (acc[0] + jnp.sum(nll), acc[1] + jnp.sum(m)), 0.0

    (num, den), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32),
                                        jnp.zeros((), jnp.float32)),
                                 (hs, ys, ms))
    return num / jnp.maximum(den, 1.0)


def loss_fn(params, batch, cfg: ModelConfig):
    """Training loss for any family. Returns (loss, metrics dict)."""
    if cfg.modality == "audio":
        n_tok, vocab = batch["targets"].size, cfg.vocab_size
    elif cfg.modality == "vision_text":
        n_tok, vocab = batch["tokens"].size, cfg.vocab_size
    else:
        n_tok, vocab = batch["tokens"].size, cfg.vocab_size
    chunked = n_tok * vocab > XENT_CHUNK_THRESHOLD

    if not chunked:
        logits, aux, _ = forward(params, batch, cfg)
        if cfg.modality == "audio":
            loss = _xent(logits, batch["targets"],
                         batch["mask_indicator"].astype(jnp.float32))
        elif cfg.modality == "vision_text":
            text_logits = logits[:, cfg.num_patches:-1]
            labels = batch["tokens"][:, 1:]
            loss = _xent(text_logits, labels)
        else:
            loss = _xent(logits[:, :-1], batch["labels"][:, 1:]
                         if "labels" in batch else batch["tokens"][:, 1:])
        total = loss + cfg.router_aux_weight * aux
        return total, {"loss": loss, "aux_loss": aux}

    hidden, aux, _ = forward(params, batch, cfg, return_hidden=True)
    if cfg.modality == "audio":
        labels = batch["targets"]
        mask = batch["mask_indicator"].astype(jnp.float32)
        h = hidden
    elif cfg.modality == "vision_text":
        h = hidden[:, cfg.num_patches:-1]
        labels = batch["tokens"][:, 1:]
        mask = jnp.ones(labels.shape, jnp.float32)
    else:
        h = hidden[:, :-1]
        labels = (batch["labels"] if "labels" in batch else batch["tokens"])[:, 1:]
        mask = jnp.ones(labels.shape, jnp.float32)
    loss = _xent_chunked(params, h, labels, mask, cfg)
    total = loss + cfg.router_aux_weight * aux
    return total, {"loss": loss, "aux_loss": aux}
