"""Sliding-window flash attention (forward) — TPU Pallas.

Serves the SWA paths: mixtral-8x22b (native SWA-4096), zamba2's shared
attention block, and the long_500k sliding-window variants of the dense
archs (DESIGN.md §4). Online-softmax flash schedule with explicit VMEM
tiling:

  grid = (B*H, nQ, nJ) — j (kv stripe) innermost, carrying running
  (m, l, acc) in f32 VMEM scratch; out written at the last stripe.

Window structure is exploited STRUCTURALLY, not just by masking: for
window W the kv index map visits only ceil((W+BQ)/BK)+1 stripes per query
block (clamped at the sequence edge; clamp duplicates are masked out via
the raw-index validity test). Compute per q block is O(W + BQ) instead of
O(T) — this is what makes long_500k prefill/decode affordable.

MXU alignment: BQ/BK default 128; head_dim is the minor (lane) dimension.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 block_q: int, block_k: int, window: Optional[int],
                 n_kv_blocks: int, n_j: int, seq_q: int, seq_kv: int,
                 causal: bool):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # raw kv stripe index (must mirror the index_map arithmetic)
    if window is not None:
        raw = (i * block_q - window) // block_k + j
    else:
        raw = j
    valid_block = (raw >= 0) & (raw < n_kv_blocks)

    q = q_ref[0].astype(jnp.float32)             # (BQ, D)
    k = k_ref[0].astype(jnp.float32)             # (BK, D)
    v = v_ref[0].astype(jnp.float32)             # (BK, D)
    scale = 1.0 / (q.shape[-1] ** 0.5)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = i * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    clamped = jnp.clip(raw, 0, n_kv_blocks - 1)
    k_pos = clamped * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = valid_block & (k_pos < seq_kv) & (q_pos < seq_q)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window is not None:
        mask = mask & (k_pos > q_pos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                          # (BQ, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_new = alpha * acc_scr[...] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_new

    @pl.when(j == n_j - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "window", "causal", "block_q", "block_k", "interpret"))
def swa_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                         window: Optional[int] = None, causal: bool = True,
                         block_q: int = 128, block_k: int = 128,
                         interpret: bool = True) -> jnp.ndarray:
    """q: (BH, T, D); k, v: (BH, S, D) -> (BH, T, D).

    window: sliding-window width (None = full); causal: apply causal mask.
    """
    from jax.experimental.pallas import tpu as pltpu

    bh, t, d = q.shape
    s_kv = k.shape[1]
    pad_q = (-t) % block_q
    pad_k = (-s_kv) % block_k
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0))) if pad_q else q
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0))) if pad_k else k
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0))) if pad_k else v
    n_q = (t + pad_q) // block_q
    n_kv = (s_kv + pad_k) // block_k

    if window is not None:
        n_j = (window + block_q) // block_k + 1
        def k_map(b, i, j):
            raw = (i * block_q - window) // block_k + j
            return (b, jnp.clip(raw, 0, n_kv - 1), 0)
    else:
        n_j = n_kv
        def k_map(b, i, j):
            return (b, j, 0)

    kernel = functools.partial(
        _attn_kernel, block_q=block_q, block_k=block_k, window=window,
        n_kv_blocks=n_kv, n_j=n_j, seq_q=t, seq_kv=s_kv, causal=causal)

    out = pl.pallas_call(
        kernel,
        grid=(bh, n_q, n_j),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), k_map),
            pl.BlockSpec((1, block_k, d), k_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, t + pad_q, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :t, :]
