"""jit'd public wrappers for the Pallas kernels.

Lowering policy is backend-driven: on TPU the kernels compile for real; on
CPU/GPU containers they run in interpret mode (the kernel body executes in
Python/XLA-CPU). ``REPRO_PALLAS_COMPILE=1`` forces compilation anywhere,
``REPRO_PALLAS_COMPILE=0`` forces interpret even on TPU. The wrappers also
expose layout adaptation (GQA head repetition, (B,T,H,D) <-> (BH,T,D)) so
the model code stays clean.
"""
from __future__ import annotations

import os
from typing import Optional

import jax.numpy as jnp

import jax

from repro.kernels.aircomp_sum import (aircomp_sum_pallas,
                                       backend_interpret_default,
                                       gather_superpose_pallas,
                                       superpose_normalize_pallas)
from repro.kernels.cosine_sim import cosine_partials_pallas
from repro.kernels.round_stats import (compressed_round_stats,
                                       round_stats_jnp, round_stats_pallas,
                                       round_stats_tp)
from repro.kernels.swa_attention import swa_attention_pallas


def interpret_mode() -> bool:
    """Resolved lazily at first kernel call, NOT at import: touching
    jax.default_backend() on import would initialize the backend before
    the application could configure its platform."""
    env = os.environ.get("REPRO_PALLAS_COMPILE")
    if env == "1":
        return False
    if env == "0":
        return True
    return backend_interpret_default()


def kernels_compiled() -> bool:
    """True when the Pallas kernels lower for real (TPU, or forced with
    REPRO_PALLAS_COMPILE=1). The round's hot path switches on THIS — an
    interpret-mode kernel is a correctness tool, not a fast path, so on
    CPU/GPU the round runs the fused-jnp twins instead."""
    return not interpret_mode()


def round_stats(deltas, g, payload=None, tp=None):
    """Fused eq.-25 round stats over a params pytree (raveled = single
    (K, D) leaf): ``(dots, dn2, pn2 | None, gn2)`` in one sweep.

    Compiled Pallas kernel per leaf on TPU; the chunked-jnp twin
    elsewhere (same contract, same f32 accumulation — the interpret-mode
    kernel stays a test-only oracle check, per the interpret_mode
    policy).

    ``tp``: intra-client ``TPTopology`` under ``jax.shard_map`` — the
    sweep then runs on the TP-local leaf blocks against a TP-sliced
    global direction and reduces the sharded partials once over
    ``tp.axes`` (see ``kernels.round_stats.round_stats_tp``)."""
    if tp is not None:
        return round_stats_tp(deltas, g, payload, tp,
                              lambda d, gg, p: round_stats(d, gg, p))
    if not kernels_compiled():
        return round_stats_jnp(deltas, g, payload)
    d_leaves = jax.tree_util.tree_leaves(deltas)
    g_leaves = jax.tree_util.tree_leaves(g)
    p_leaves = (jax.tree_util.tree_leaves(payload) if payload is not None
                else [None] * len(d_leaves))
    dots = dn2 = pn2 = gn2 = None
    for dl, plf, gl in zip(d_leaves, p_leaves, g_leaves):
        d2 = dl.reshape((dl.shape[0], -1))
        p2 = None if plf is None else plf.reshape((plf.shape[0], -1))
        stats, g2 = round_stats_pallas(d2, gl.reshape(-1), p2,
                                       interpret=False)
        if dots is None:
            dots, dn2, gn2 = stats[:, 0], stats[:, 1], g2
            pn2 = stats[:, 2] if payload is not None else None
        else:
            dots, dn2, gn2 = dots + stats[:, 0], dn2 + stats[:, 1], gn2 + g2
            if payload is not None:
                pn2 = pn2 + stats[:, 2]
    return dots, dn2, pn2, gn2


def superpose_normalize(stacked: jnp.ndarray, powers: jnp.ndarray,
                        mask: jnp.ndarray, noise: jnp.ndarray,
                        vs_min: float = 1e-12):
    """Fused eq. (6)+(8) for one (K, D) leaf: (agg (D,) f32, raw varsigma).
    Compiled kernel on TPU; f32-accumulating einsum elsewhere."""
    if kernels_compiled():
        return superpose_normalize_pallas(stacked, powers, mask, noise,
                                          vs_min=vs_min, interpret=False)
    # CPU/GPU twin: one einsum with f32 accumulation (the convert of a
    # bf16 payload fuses into the contraction — no materialized f32 copy);
    # for f32 payloads this is the exact historical op sequence.
    bp = (powers * mask).astype(jnp.float32)
    raw = jnp.sum(bp)
    acc = jnp.einsum("k,kd->d", bp, stacked,
                     preferred_element_type=jnp.float32)
    agg = (acc + noise.astype(jnp.float32)) / jnp.maximum(raw, vs_min)
    return agg, raw


def gather_superpose(values, idx, bp, noise, *, d: int, scale=None,
                     vs_min: float = 1e-12):
    """Fused gather-superpose-decompress over the (m, s) compressed cohort
    plane: ((d,) f32 aggregate, raw varsigma). Compiled one-hot-scatter
    kernel on TPU; the scatter + f32 einsum twin elsewhere (the twin's
    decompressed (m, d) rows exist only transiently inside this op — the
    round carry never holds them). ``scale`` folds int8 dequantization
    into the contraction weights; varsigma is the RAW sum of b*p."""
    if kernels_compiled():
        return gather_superpose_pallas(values, idx, bp, noise, d=d,
                                       scale=scale, vs_min=vs_min,
                                       interpret=False)
    bp32 = bp.astype(jnp.float32)
    w = bp32 if scale is None else bp32 * scale.astype(jnp.float32)
    raw = jnp.sum(bp32)
    m = values.shape[0]
    rows = jnp.arange(m)[:, None]
    dense = jnp.zeros((m, d), jnp.float32).at[rows, idx].add(
        values.astype(jnp.float32))
    acc = jnp.einsum("k,kd->d", w, dense,
                     preferred_element_type=jnp.float32)
    agg = (acc + noise.astype(jnp.float32)) / jnp.maximum(raw, vs_min)
    return agg, raw


def round_stats_compressed(values, idx, resid, resid_idx, g, scale=None):
    """Round stats over the compressed plane + EF residuals. Pure jnp on
    every backend (gather-bound, no stripe contraction to fuse — see
    ``repro.kernels.round_stats.compressed_round_stats``); routed through
    ops so the round core has one kernel seam."""
    return compressed_round_stats(values, idx, resid, resid_idx, g,
                                  scale=scale)


def aircomp_sum(stacked: jnp.ndarray, bp: jnp.ndarray,
                noise: jnp.ndarray) -> jnp.ndarray:
    """Fused (sum_k bp_k w_k + n)/sum bp_k. stacked (K,D) -> (D,)."""
    return aircomp_sum_pallas(stacked, bp, noise, interpret=interpret_mode())


def cosine_sim(deltas: jnp.ndarray, g: jnp.ndarray, eps: float = 1e-12):
    """Per-client cos(dw_k, g): (K, D), (D,) -> (K,)."""
    parts = cosine_partials_pallas(deltas, g, interpret=interpret_mode())
    gn = jnp.sqrt(jnp.maximum(jnp.sum(g.astype(jnp.float32) ** 2), eps))
    return parts[:, 0] / jnp.maximum(jnp.sqrt(jnp.maximum(parts[:, 1], eps)) * gn,
                                     eps)


def swa_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  window: Optional[int] = None, causal: bool = True,
                  block_q: int = 128, block_k: int = 128) -> jnp.ndarray:
    """Flash attention with sliding window. (B,T,H,D)/(B,S,Hkv,D) layout;
    GQA: kv heads are repeated to match q heads."""
    b, t, h, d = q.shape
    s, hkv = k.shape[1], k.shape[2]
    if hkv != h:
        rep = h // hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, t, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    out = swa_attention_pallas(qf, kf, vf, window=window, causal=causal,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret_mode())
    return out.reshape(b, h, t, d).transpose(0, 2, 1, 3)
