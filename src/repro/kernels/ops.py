"""jit'd public wrappers for the Pallas kernels.

Lowering policy is backend-driven: on TPU the kernels compile for real; on
CPU/GPU containers they run in interpret mode (the kernel body executes in
Python/XLA-CPU). ``REPRO_PALLAS_COMPILE=1`` forces compilation anywhere,
``REPRO_PALLAS_COMPILE=0`` forces interpret even on TPU. The wrappers also
expose layout adaptation (GQA head repetition, (B,T,H,D) <-> (BH,T,D)) so
the model code stays clean.
"""
from __future__ import annotations

import os
from typing import Optional

import jax.numpy as jnp

from repro.kernels.aircomp_sum import (aircomp_sum_pallas,
                                       backend_interpret_default)
from repro.kernels.cosine_sim import cosine_partials_pallas
from repro.kernels.swa_attention import swa_attention_pallas


def interpret_mode() -> bool:
    """Resolved lazily at first kernel call, NOT at import: touching
    jax.default_backend() on import would initialize the backend before
    the application could configure its platform."""
    env = os.environ.get("REPRO_PALLAS_COMPILE")
    if env == "1":
        return False
    if env == "0":
        return True
    return backend_interpret_default()


def aircomp_sum(stacked: jnp.ndarray, bp: jnp.ndarray,
                noise: jnp.ndarray) -> jnp.ndarray:
    """Fused (sum_k bp_k w_k + n)/sum bp_k. stacked (K,D) -> (D,)."""
    return aircomp_sum_pallas(stacked, bp, noise, interpret=interpret_mode())


def cosine_sim(deltas: jnp.ndarray, g: jnp.ndarray, eps: float = 1e-12):
    """Per-client cos(dw_k, g): (K, D), (D,) -> (K,)."""
    parts = cosine_partials_pallas(deltas, g, interpret=interpret_mode())
    gn = jnp.sqrt(jnp.maximum(jnp.sum(g.astype(jnp.float32) ** 2), eps))
    return parts[:, 0] / jnp.maximum(jnp.sqrt(jnp.maximum(parts[:, 1], eps)) * gn,
                                     eps)


def swa_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  window: Optional[int] = None, causal: bool = True,
                  block_q: int = 128, block_k: int = 128) -> jnp.ndarray:
    """Flash attention with sliding window. (B,T,H,D)/(B,S,Hkv,D) layout;
    GQA: kv heads are repeated to match q heads."""
    b, t, h, d = q.shape
    s, hkv = k.shape[1], k.shape[2]
    if hkv != h:
        rep = h // hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, t, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    out = swa_attention_pallas(qf, kf, vf, window=window, causal=causal,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret_mode())
    return out.reshape(b, h, t, d).transpose(0, 2, 1, 3)
