"""Fused round-stats kernel (TPU Pallas) + the jnp twin.

Everything the PAOTA round's eq.-25 stage needs from the (K, d) delta
plane — per-client dots with the global direction, per-client delta
sq-norms, optionally per-client payload sq-norms for the power constraint
(7), and the global-direction sq-norm — computed in ONE tiled sweep:

    dot_k  = sum_d deltas[k, d] * g[d]
    dn2_k  = sum_d deltas[k, d]^2
    pn2_k  = sum_d payload[k, d]^2        (payload pass only)
    gn2    = sum_d g[d]^2

The naive composition (``client_dots`` + ``client_sq_norms`` +
``client_sq_norms(payload)`` + ``global_sq_norm``) sweeps the K x d plane
three times and the d vector twice; at transformer-scale d the round is
memory-bound, so the fused form is the difference between one and three
HBM passes per aggregation period.

Two implementations, same contract:

* ``round_stats_pallas`` — the TPU kernel: grid over d in BLOCK_D stripes,
  K resident per stripe, f32 VMEM accumulators (revisited-output pattern,
  like ``cosine_sim``). Inputs may be bf16; accumulation is always f32.
* ``round_stats_jnp`` — the CPU/GPU twin: the dot is a matmul and each
  sq-norm is a batched dot (``einsum kd,kd->k``) so NOTHING K x d ever
  materializes (XLA-CPU lowers ``sum(x*x, -1)`` as a full materialized
  square + reduce-window cascade — two extra plane sweeps per norm; the
  batched dot streams once). An explicitly d-chunked ``lax.scan`` variant
  (``chunk=``) exists for experimentation, but measured inside the
  scanned round XLA's own fusion of the plain ops wins (dot operands
  materialize per chunk), so the round core uses ``chunk=None``.

``repro.kernels.ops.round_stats`` picks between them by backend;
``repro.kernels.ref.round_stats_ref`` is the allclose oracle.

The leading axis is whatever client plane the round carries: the dense
(K, d) delta stack, or — in active-cohort mode (``RoundCfg.cohort_size``)
— the (m, d) cohort slot rows, m = |in-flight cohort| << K. The kernel is
shape-agnostic there; masked slot rows arrive with ``stal = 0`` exactly
like the sharded drivers' phantom clients.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_D = 512

# d-chunk of the explicitly-chunked jnp variant. Leaves at or below this
# size reduce in one shot with the historical ops (bit-identical
# small-model trajectories); ``round_stats_jnp(chunk=...)`` can stream
# larger leaves in CHUNK_D slices. The round core's default is chunk=None
# (no explicit chunking): measured inside the scanned round, XLA's own
# multi-output loop fusion of the plain reductions beats a hand-rolled
# lax.scan whose dot operands must materialize per chunk — the explicit
# form is kept for the kernel tests and for experimentation.
CHUNK_D = 8192


# ---------------------------------------------------------------------------
# TPU kernel
# ---------------------------------------------------------------------------

def _kernel(d_ref, g_ref, out_ref, gn2_ref):
    i = pl.program_id(0)
    x = d_ref[...].astype(jnp.float32)          # (K, BLOCK_D) deltas stripe
    g = g_ref[...].astype(jnp.float32)          # (1, BLOCK_D)
    dot = jax.lax.dot_general(x, g, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)   # (K, 1)
    dn2 = jnp.sum(x * x, axis=1, keepdims=True)                     # (K, 1)
    partial = jnp.concatenate([dot, dn2], axis=1)                   # (K, 2)
    gn2 = jnp.sum(g * g, axis=1, keepdims=True)                     # (1, 1)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = partial
        gn2_ref[...] = gn2

    @pl.when(i != 0)
    def _acc():
        out_ref[...] += partial
        gn2_ref[...] += gn2


def _kernel_payload(d_ref, p_ref, g_ref, out_ref, gn2_ref):
    i = pl.program_id(0)
    x = d_ref[...].astype(jnp.float32)
    p = p_ref[...].astype(jnp.float32)          # (K, BLOCK_D) payload stripe
    g = g_ref[...].astype(jnp.float32)
    dot = jax.lax.dot_general(x, g, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    dn2 = jnp.sum(x * x, axis=1, keepdims=True)
    pn2 = jnp.sum(p * p, axis=1, keepdims=True)
    partial = jnp.concatenate([dot, dn2, pn2], axis=1)              # (K, 3)
    gn2 = jnp.sum(g * g, axis=1, keepdims=True)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = partial
        gn2_ref[...] = gn2

    @pl.when(i != 0)
    def _acc():
        out_ref[...] += partial
        gn2_ref[...] += gn2


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def round_stats_pallas(deltas: jnp.ndarray, g: jnp.ndarray,
                       payload: jnp.ndarray | None = None, *,
                       block_d: int = DEFAULT_BLOCK_D,
                       interpret: bool = True):
    """deltas: (K, D); g: (D,); payload: optional (K, D).

    Returns ``(stats, gn2)`` where stats is (K, 2) ``[dot_k, dn2_k]`` (or
    (K, 3) with ``pn2_k`` appended when ``payload`` is given) and gn2 is
    the f32 scalar ``||g||^2`` — one streaming pass over every operand.
    """
    k, d = deltas.shape
    pad = (-d) % block_d
    if pad:
        deltas = jnp.pad(deltas, ((0, 0), (0, pad)))
        g = jnp.pad(g, (0, pad))
        if payload is not None:
            payload = jnp.pad(payload, ((0, 0), (0, pad)))
    dp = d + pad
    grid = (dp // block_d,)
    stripe = pl.BlockSpec((k, block_d), lambda i: (0, i))
    gspec = pl.BlockSpec((1, block_d), lambda i: (0, i))
    ncol = 2 if payload is None else 3
    out_specs = [pl.BlockSpec((k, ncol), lambda i: (0, 0)),   # revisited acc
                 pl.BlockSpec((1, 1), lambda i: (0, 0))]
    out_shape = [jax.ShapeDtypeStruct((k, ncol), jnp.float32),
                 jax.ShapeDtypeStruct((1, 1), jnp.float32)]
    if payload is None:
        stats, gn2 = pl.pallas_call(
            _kernel, grid=grid, in_specs=[stripe, gspec],
            out_specs=out_specs, out_shape=out_shape, interpret=interpret,
        )(deltas, g[None, :])
    else:
        stats, gn2 = pl.pallas_call(
            _kernel_payload, grid=grid, in_specs=[stripe, stripe, gspec],
            out_specs=out_specs, out_shape=out_shape, interpret=interpret,
        )(deltas, payload, g[None, :])
    return stats, gn2[0, 0]


# ---------------------------------------------------------------------------
# chunked-jnp twin (CPU/GPU fast path; also the interpret-free fallback)
# ---------------------------------------------------------------------------

def _leaf2d(x):
    return x.reshape((x.shape[0], -1))


def _small_leaf_stats(d2, p2, g1):
    """Single-shot per-leaf stats. The row sq-norms are batched dots
    (``einsum kd,kd->k``), NOT ``sum(x*x, -1)``: XLA-CPU lowers the
    latter as a materialized (K, d) square followed by a reduce-window
    cascade — a full extra HBM write+read of the plane per norm — while
    a batched dot contracts in one streaming pass (this was worth ~2
    plane-sweeps per round at transformer-scale d, see EXPERIMENTS.md
    §Round perf)."""
    d32 = d2.astype(jnp.float32)
    g32 = g1.astype(jnp.float32)
    dot = d32 @ g32
    dn2 = jnp.einsum("kd,kd->k", d32, d32)
    out = (dot, dn2)
    if p2 is not None:
        p32 = p2.astype(jnp.float32)
        out += (jnp.einsum("kd,kd->k", p32, p32),)
    return out + (jnp.sum(g32 * g32),)   # (d,)-sized: reduce is fine


def _chunked_leaf_stats(d2, p2, g1, chunk: int):
    """One lax.scan sweep over full d-chunks (+ a remainder tail): the
    multi-output reduction stays in cache per chunk instead of re-reading
    the leaf once per statistic. ``gn2`` reduces outside the scan — it
    only sweeps the (d,) direction vector (negligible traffic), and a
    scalar scan carry seeded from a constant trips shard_map's
    replication checker (constant = replicated, accumulated = shard-
    tagged)."""
    k, n = d2.shape
    n_full = n // chunk
    has_payload = p2 is not None

    def body(carry, i):
        off = i * chunk
        dc = jax.lax.dynamic_slice(d2, (0, off), (k, chunk)).astype(
            jnp.float32)
        gc = jax.lax.dynamic_slice(g1, (off,), (chunk,)).astype(jnp.float32)
        dot, dn2, pn2 = carry
        dot = dot + dc @ gc
        dn2 = dn2 + jnp.einsum("kd,kd->k", dc, dc)
        if has_payload:
            pc = jax.lax.dynamic_slice(p2, (0, off), (k, chunk)).astype(
                jnp.float32)
            pn2 = pn2 + jnp.einsum("kd,kd->k", pc, pc)
        return (dot, dn2, pn2), None

    z = jnp.zeros((k,), jnp.float32)
    (dot, dn2, pn2), _ = jax.lax.scan(body, (z, z, z), jnp.arange(n_full))
    tail = n - n_full * chunk
    if tail:
        dt = d2[:, n_full * chunk:].astype(jnp.float32)
        gt = g1[n_full * chunk:].astype(jnp.float32)
        dot = dot + dt @ gt
        dn2 = dn2 + jnp.einsum("kd,kd->k", dt, dt)
        if has_payload:
            pt = p2[:, n_full * chunk:].astype(jnp.float32)
            pn2 = pn2 + jnp.einsum("kd,kd->k", pt, pt)
    g32 = g1.astype(jnp.float32)
    gn2 = jnp.sum(g32 * g32)
    out = (dot, dn2)
    if has_payload:
        out += (pn2,)
    return out + (gn2,)


def _leaf_stats(dl, plf, gl, chunk):
    d2, g1 = _leaf2d(dl), gl.reshape(-1)
    p2 = None if plf is None else _leaf2d(plf)
    if chunk is None or d2.shape[1] <= chunk:
        return _small_leaf_stats(d2, p2, g1)
    return _chunked_leaf_stats(d2, p2, g1, chunk)


def round_stats_jnp(deltas, g, payload=None, *, chunk: int | None = None):
    """Pytree-generic fused round stats, pure jnp.

    ``deltas``: pytree of client-stacked (K, ...) leaves (a bare (K, D)
    matrix is the raveled single-leaf case); ``g``: the matching global-
    direction pytree / (D,) vector; ``payload``: optional pytree congruent
    with ``deltas`` whose per-client sq-norms are wanted too.

    Returns ``(dots, dn2, pn2 | None, gn2)`` — (K,) f32 vectors plus the
    f32 scalar ``||g||^2`` — accumulated across leaves in tree_flatten
    order (shard-local under a client mesh axis: every reduction runs
    over the model dims, which each shard holds whole).
    """
    d_leaves = jax.tree_util.tree_leaves(deltas)
    g_leaves = jax.tree_util.tree_leaves(g)
    p_leaves = (jax.tree_util.tree_leaves(payload) if payload is not None
                else [None] * len(d_leaves))
    dots = dn2 = pn2 = gn2 = None
    for dl, plf, gl in zip(d_leaves, p_leaves, g_leaves):
        part = _leaf_stats(dl, plf, gl, chunk)
        if dots is None:
            dots, dn2 = part[0], part[1]
            pn2 = part[2] if payload is not None else None
            gn2 = part[-1]
        else:
            dots, dn2 = dots + part[0], dn2 + part[1]
            if payload is not None:
                pn2 = pn2 + part[2]
            gn2 = gn2 + part[-1]
    return dots, dn2, pn2, gn2


# ---------------------------------------------------------------------------
# compressed-plane stats: the sweep over (m, s) rows + EF residuals
# ---------------------------------------------------------------------------

def compressed_round_stats(values, idx, resid, resid_idx, g,
                           scale=None):
    """Round stats over the compressed cohort plane: (m, s) transmitted
    values on per-row supports ``idx``, plus the (m, s) error-feedback
    residuals on their own supports — so eq. 25's similarity factor sees
    each slot's full reconstruction ``scatter(v) + scatter(e)`` without a
    dense (m, d) plane ever materializing:

        dot_k = <v_k, g[idx_k]> + <e_k, g[eidx_k]>
        dn2_k = ||v_k||^2 + ||e_k||^2
        pn2_k = ||v_k||^2      (the TRANSMITTED energy — what the power
                                constraint (7) actually caps on the air)
        gn2   = ||g||^2

    ``scale`` dequantizes int8 values ((m,) per-row factors). Pure jnp on
    every backend: the sweep is gather-bound (O(m*s) with random access
    into g), with no K x d contraction for a Pallas stripe kernel to win
    on — raveled single-leaf only, like the compressed plane itself.
    Returns ``(dots, dn2, pn2, gn2)``, all f32."""
    g32 = g.reshape(-1).astype(jnp.float32)
    v32 = values.astype(jnp.float32)
    if scale is not None:
        v32 = v32 * scale.astype(jnp.float32)[:, None]
    dots = jnp.einsum("ms,ms->m", v32, g32[idx])
    pn2 = jnp.einsum("ms,ms->m", v32, v32)
    dn2 = pn2
    if resid is not None:
        r32 = resid.astype(jnp.float32)
        dots = dots + jnp.einsum("ms,ms->m", r32, g32[resid_idx])
        dn2 = dn2 + jnp.einsum("ms,ms->m", r32, r32)
    return dots, dn2, pn2, jnp.sum(g32 * g32)


def round_stats_tp(deltas, g, payload, tp, stats_fn):
    """Intra-client-TP round stats: one psum over the TP axes.

    The stacked leaves of ``deltas``/``payload`` are this device's
    TP-local blocks (trailing dim ``tp.leaf_dims[i]`` holds 1/``shards``
    of the model) while ``g`` is the full replicated global direction —
    so the sweep slices ``g`` down to the matching block per sharded
    leaf, runs ``stats_fn`` (the backend-dispatched dense sweep) over the
    sharded and TP-replicated leaf groups separately, and reduces ONE
    concatenated ``[dots | dn2 (| pn2) | gn2]`` vector over ``tp.axes``.
    TP-replicated leaves (no dividing trailing dim) are accumulated
    OUTSIDE that psum so they count exactly once. With every leaf in one
    group the other contributes exact zeros — same totals either way."""
    from repro.sharding.tp import tp_slice

    d_leaves = jax.tree_util.tree_leaves(deltas)
    g_leaves = jax.tree_util.tree_leaves(g)
    have_p = payload is not None
    p_leaves = (jax.tree_util.tree_leaves(payload) if have_p
                else [None] * len(d_leaves))
    k = d_leaves[0].shape[0]

    sh = ([], [], [])   # sharded leaves: (deltas, g-local, payload)
    rep = ([], [], [])  # TP-replicated leaves
    for dl, gl, plf, dim in zip(d_leaves, g_leaves, p_leaves, tp.leaf_dims):
        dst = sh if dim >= 0 else rep
        dst[0].append(dl)
        dst[1].append(tp_slice(gl, dim, tp) if dim >= 0 else gl)
        dst[2].append(plf)

    def run(group):
        return stats_fn(group[0], group[1], group[2] if have_p else None)

    if sh[0]:
        dots, dn2, pn2, gn2 = run(sh)
    else:
        dots = dn2 = jnp.zeros((k,), jnp.float32)
        pn2 = jnp.zeros((k,), jnp.float32) if have_p else None
        gn2 = jnp.float32(0.0)
    parts = [dots, dn2] + ([pn2] if have_p else []) + [jnp.reshape(gn2, (1,))]
    flat = jax.lax.psum(jnp.concatenate(parts), tp.axes)
    dots, dn2 = flat[:k], flat[k:2 * k]
    off = 2 * k
    if have_p:
        pn2 = flat[off:off + k]
        off += k
    gn2 = flat[off]
    if rep[0]:
        r_dots, r_dn2, r_pn2, r_gn2 = run(rep)
        dots, dn2, gn2 = dots + r_dots, dn2 + r_dn2, gn2 + r_gn2
        if have_p:
            pn2 = pn2 + r_pn2
    return dots, dn2, (pn2 if have_p else None), gn2
