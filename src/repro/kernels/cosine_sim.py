"""Per-client cosine-similarity kernel (TPU Pallas).

For the similarity factor theta_k (eq. 25) the server needs, for every
client k:   cos_k = <dw_k, g> / (||dw_k|| ||g||)
over the full flattened model (D can be 10^6..10^9). One streaming pass
computes the partials  dot_k = sum_d dw[k,d] g[d]  and  nk = sum_d dw[k,d]^2
accumulating in an f32 VMEM block across the D-grid (revisited output
pattern: initialize at stripe 0, accumulate after).

Output: (K, 2) = [dot_k, norm2_k]; the wrapper finishes the division.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_D = 512


def _kernel(x_ref, g_ref, out_ref):
    i = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)     # (K, BLOCK_D)
    g = g_ref[...].astype(jnp.float32)     # (1, BLOCK_D)
    dot = jax.lax.dot_general(x, g, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (K, 1)
    n2 = jnp.sum(x * x, axis=1, keepdims=True)                     # (K, 1)
    partial = jnp.concatenate([dot, n2], axis=1)                   # (K, 2)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(i != 0)
    def _acc():
        out_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def cosine_partials_pallas(deltas: jnp.ndarray, g: jnp.ndarray, *,
                           block_d: int = DEFAULT_BLOCK_D,
                           interpret: bool = True) -> jnp.ndarray:
    """deltas: (K, D); g: (D,) -> (K, 2) [dot_k, ||delta_k||^2]."""
    k, d = deltas.shape
    pad = (-d) % block_d
    if pad:
        deltas = jnp.pad(deltas, ((0, 0), (0, pad)))
        g = jnp.pad(g, (0, pad))
    dp = d + pad
    return pl.pallas_call(
        _kernel,
        grid=(dp // block_d,),
        in_specs=[
            pl.BlockSpec((k, block_d), lambda i: (0, i)),
            pl.BlockSpec((1, block_d), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((k, 2), lambda i: (0, 0)),  # revisited accumulator
        out_shape=jax.ShapeDtypeStruct((k, 2), jnp.float32),
        interpret=interpret,
    )(deltas, g[None, :])


# ---------------------------------------------------------------------------
# shard-aware entry point (mesh client axis)
# ---------------------------------------------------------------------------

def cosine_sim_shard(deltas: jnp.ndarray, g: jnp.ndarray, axis_name=None,
                     eps: float = 1e-12) -> jnp.ndarray:
    """Per-client cosines for use INSIDE ``jax.shard_map`` with K laid over
    the mesh client axis/axes.

    deltas: this shard's client deltas — a pytree of (K_local, ...) leaves
    (a bare (K_local, D) matrix is the raveled single-leaf case); g: the
    matching replicated global-direction pytree / (D,) vector. The eq.-25
    reduction runs over the model dims — which every shard holds whole
    under the client-axis layout — so each client's cosine is computed
    entirely on its own shard with NO collective (per-leaf partials are
    accumulated locally, never psum'd); this entry point
    exists to make that contract explicit at shard_map call sites
    (``axis_name`` is accepted for symmetry with the psum-bearing
    reductions and intentionally unused). The math delegates to the ONE
    cosine implementation (``repro.core.power_control.cosine_similarity``,
    the same function the round core's eq.-25 stage calls), so there is no
    second formula to keep in sync.

    Returns (K_local,) cosines (replicated math, shard-local rows).
    """
    del axis_name  # reduction is over D: shard-local by construction
    from repro.core.power_control import cosine_similarity
    return cosine_similarity(deltas, g, eps=eps)
