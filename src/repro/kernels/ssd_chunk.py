"""Mamba2 SSD intra-chunk kernel (TPU Pallas) [arXiv:2405.21060].

The SSD duality splits the selective-scan into (i) a quadratic-in-chunk
"attention-like" part and (ii) a linear cross-chunk state recurrence. Part
(i) is the MXU hot-spot — per (batch, chunk, head):

    decay[i,j] = exp(cum[i] - cum[j]) * causal(i >= j)
    scores     = (C B^T) * decay            # (Q, Q)
    y_intra    = scores @ (x * dt)          # (Q, P)
    tail[j]    = exp(cum[Q-1] - cum[j])
    state      = (B * tail)^T @ (x * dt)    # (N, P)  chunk's state contribution

This kernel fuses all five in one VMEM-resident tile per grid cell
(grid = batch*chunks*heads), with Q/N/P MXU-aligned where the configs
allow (Q=256, N=64/128, P=64). The cross-chunk recurrence stays a
lax.scan on the host graph (it is O(T/Q) and bandwidth-trivial).

Validated against ref.ssd_intra_chunk_ref in interpret mode; the pure-jnp
path in repro.models.ssm remains the default on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(cum_ref, b_ref, c_ref, xdt_ref, y_ref, state_ref, decay_ref):
    cum = cum_ref[0].astype(jnp.float32)          # (Q, 1)
    b = b_ref[0].astype(jnp.float32)              # (Q, N)
    c = c_ref[0].astype(jnp.float32)              # (Q, N)
    xdt = xdt_ref[0].astype(jnp.float32)          # (Q, P)
    q = cum.shape[0]

    li = cum                                       # (Q, 1) query decay
    lj = cum.reshape(1, q)                         # (1, Q) key decay
    decay = jnp.exp(jnp.clip(li - lj, -60.0, 0.0))
    causal = (jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
              >= jax.lax.broadcasted_iota(jnp.int32, (q, q), 1))
    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    scores = jnp.where(causal, scores * decay, 0.0)
    y = jax.lax.dot_general(scores, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    tail = jnp.exp(jnp.clip(cum[q - 1] - cum, -60.0, 0.0))   # (Q, 1)
    state = jax.lax.dot_general(b * tail, xdt, (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (N, P)

    y_ref[0] = y.astype(y_ref.dtype)
    state_ref[0] = state
    decay_ref[0] = jnp.exp(jnp.clip(cum[q - 1], -60.0, 0.0)).reshape(1, 1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_intra_chunk_pallas(cum, b, c, xdt, *, interpret: bool = True):
    """cum: (G, Q) cumulative log-decay; b, c: (G, Q, N); xdt: (G, Q, P)
    where G = batch*chunks*heads (wrapper-flattened).

    Returns (y (G,Q,P), state (G,N,P), chunk_decay (G,))."""
    g, q = cum.shape
    n, p = b.shape[2], xdt.shape[2]
    y, state, decay = pl.pallas_call(
        _kernel,
        grid=(g,),
        in_specs=[
            pl.BlockSpec((1, q, 1), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, q, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, q, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, q, p), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, q, p), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n, p), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 1, 1), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((g, q, p), xdt.dtype),
            jax.ShapeDtypeStruct((g, n, p), jnp.float32),
            jax.ShapeDtypeStruct((g, 1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(cum[..., None], b, c, xdt)
    return y, state, decay[:, 0, 0]
