"""Fused PAOTA/AirComp aggregation kernel (TPU Pallas).

Computes, in ONE pass over HBM:

    out[d] = ( sum_k bp[k] * stacked[k, d] + noise[d] ) / sum_k bp[k]

where bp = b * p (masked transmit powers). The naive jnp composition makes
four HBM passes (scale, reduce, add-noise, normalize); the paper's hot loop
runs this every aggregation period over the full model vector, so the fused
streaming form is the memory-bound kernel the roofline wants: bytes moved
= K*D + D reads + D writes, arithmetic intensity ~= 1 MAC/element.

Tiling: grid over D in BLOCK_D-wide stripes (lane-dim multiples of 128);
the K axis stays resident in VMEM per stripe ((K, BLOCK_D) tile). The
reduction over K is a (1,K)x(K,BLOCK_D) matmul -> MXU-friendly.

The leading (client) axis is whatever plane the round carries: all K
clients on the dense path, or the (m, d) active-cohort slot rows under
``RoundCfg.cohort_size`` — dead/masked slots superpose with b*p = 0, so
the same kernel serves both layouts unchanged.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


DEFAULT_BLOCK_D = 512


def backend_interpret_default() -> bool:
    """Pallas lowering policy: compile for real on TPU, fall back to
    interpret mode everywhere else (CPU/GPU containers). Passing
    ``interpret=True`` unconditionally would mean the "fused" kernel never
    actually compiles even on TPU."""
    return jax.default_backend() != "tpu"


def _kernel(bp_ref, x_ref, noise_ref, out_ref):
    bp = bp_ref[...]                       # (1, K)
    x = x_ref[...]                         # (K, BLOCK_D)
    n = noise_ref[...]                     # (1, BLOCK_D)
    varsigma = jnp.maximum(jnp.sum(bp), 1e-12)
    acc = jax.lax.dot_general(
        bp, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)       # (1, BLOCK_D)
    # noise joins the reduction in the accumulator dtype, not its own
    out_ref[...] = ((acc + n.astype(acc.dtype)) / varsigma).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def aircomp_sum_pallas(stacked: jnp.ndarray, bp: jnp.ndarray,
                       noise: jnp.ndarray, *, block_d: int = DEFAULT_BLOCK_D,
                       interpret: bool | None = None) -> jnp.ndarray:
    """stacked: (K, D); bp: (K,); noise: (D,) -> (D,) f32 aggregate.

    The payload may be bf16; the contraction accumulates in f32, the AWGN
    joins that f32 accumulator un-rounded, and the aggregate comes back
    f32 — the same "f32 accumulation, f32 aggregate" contract as
    ``superpose_normalize_pallas`` / ``aircomp_sum_tree_psum`` (a bf16
    carry stores its planes rounded, but the received y must not be).

    ``interpret=None`` resolves from the active backend (compiled on TPU,
    interpret elsewhere)."""
    if interpret is None:
        interpret = backend_interpret_default()
    k, d = stacked.shape
    noise = noise.astype(jnp.float32)
    pad = (-d) % block_d
    if pad:
        stacked = jnp.pad(stacked, ((0, 0), (0, pad)))
        noise = jnp.pad(noise, (0, pad))
    dp = d + pad
    grid = (dp // block_d,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, k), lambda i: (0, 0)),           # bp (VMEM-resident)
            pl.BlockSpec((k, block_d), lambda i: (0, i)),     # stacked stripe
            pl.BlockSpec((1, block_d), lambda i: (0, i)),     # noise stripe
        ],
        out_specs=pl.BlockSpec((1, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, dp), jnp.float32),
        interpret=interpret,
    )(bp[None, :].astype(jnp.float32), stacked, noise[None, :])
    return out[0, :d]


# ---------------------------------------------------------------------------
# fused superpose-and-normalize (mask + superposition + AWGN + varsigma in
# one pass, varsigma returned)
# ---------------------------------------------------------------------------

def _superpose_kernel(vs_min, p_ref, m_ref, x_ref, noise_ref, out_ref,
                      vs_ref):
    i = pl.program_id(0)
    bp = p_ref[...] * m_ref[...]                # (1, K) f32, masked in-kernel
    raw = jnp.sum(bp)
    varsigma = jnp.maximum(raw, vs_min)
    x = x_ref[...]                              # (K, BLOCK_D), f32 or bf16
    n = noise_ref[...]                          # (1, BLOCK_D)
    acc = jax.lax.dot_general(
        bp, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)     # f32 accumulation always
    out_ref[...] = (acc + n.astype(acc.dtype)) / varsigma

    @pl.when(i == 0)
    def _emit_vs():
        vs_ref[...] = raw[None, None]


@functools.partial(jax.jit, static_argnames=("vs_min", "block_d",
                                             "interpret"))
def superpose_normalize_pallas(stacked: jnp.ndarray, powers: jnp.ndarray,
                               mask: jnp.ndarray, noise: jnp.ndarray, *,
                               vs_min: float = 1e-12,
                               block_d: int = DEFAULT_BLOCK_D,
                               interpret: bool | None = None):
    """Eqs. (6)+(8) in one sweep: stacked (K, D) payloads, powers/mask (K,)
    -> ``(agg (D,) f32, varsigma f32 scalar)`` where

        agg      = (sum_k b_k p_k stacked[k] + noise) / max(varsigma, vs_min)
        varsigma = sum_k b_k p_k                       (raw, unclamped)

    Extends ``aircomp_sum_pallas`` with the two pieces the round core had
    to compute in separate passes: the b*p masking joins the kernel (no
    materialized bp vector... trivial, but it keeps the contract whole)
    and the eq.-8 normalizer comes back with the aggregate, so the
    zero-uploader guard needs no second reduction. ``stacked`` may be
    bf16; the contraction always accumulates in f32.

    ``interpret=None`` resolves from the backend (compiled on TPU,
    interpret elsewhere)."""
    if interpret is None:
        interpret = backend_interpret_default()
    k, d = stacked.shape
    noise = noise.astype(jnp.float32)
    pad = (-d) % block_d
    if pad:
        stacked = jnp.pad(stacked, ((0, 0), (0, pad)))
        noise = jnp.pad(noise, (0, pad))
    dp = d + pad
    kern = functools.partial(_superpose_kernel, float(vs_min))
    agg, vs = pl.pallas_call(
        kern,
        grid=(dp // block_d,),
        in_specs=[
            pl.BlockSpec((1, k), lambda i: (0, 0)),          # powers
            pl.BlockSpec((1, k), lambda i: (0, 0)),          # mask
            pl.BlockSpec((k, block_d), lambda i: (0, i)),    # payload stripe
            pl.BlockSpec((1, block_d), lambda i: (0, i)),    # noise stripe
        ],
        out_specs=[pl.BlockSpec((1, block_d), lambda i: (0, i)),
                   pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((1, dp), jnp.float32),
                   jax.ShapeDtypeStruct((1, 1), jnp.float32)],
        interpret=interpret,
    )(powers[None, :].astype(jnp.float32), mask[None, :].astype(jnp.float32),
      stacked, noise[None, :])
    return agg[0, :d], vs[0, 0]


# ---------------------------------------------------------------------------
# shard-aware entry point (mesh client axis)
# ---------------------------------------------------------------------------

def aircomp_sum_psum(stacked: jnp.ndarray, bp: jnp.ndarray,
                     noise: jnp.ndarray, axis_name,
                     varsigma_min: float | None = None):
    """AirComp reduction for use INSIDE ``jax.shard_map`` with the K axis
    laid over mesh client axis/axes ``axis_name``.

    stacked: (K_local, D) this shard's client payloads; bp: (K_local,)
    masked transmit powers b_k p_k; noise: (D,) the SAME AWGN realization
    on every shard (replicated key — eq. 6 adds noise once at the server,
    not per client).

    The local partial superposition is the identical (1, K)x(K, D)
    contraction the single-device Pallas kernel tiles; the cross-shard sum
    is one psum, and the noise joins the accumulator dtype once AFTER the
    collective so every shard normalizes the same received y.

    Returns (aggregate (D,) in f32, varsigma) — both replicated across
    shards. The aggregate is NOT cast back to the payload dtype: a bf16
    carry stores its planes rounded, but the global update must stay
    full precision (same contract as ``superpose_normalize``).
    """
    if varsigma_min is None:
        # the division clamp doubles as the zero-uploader threshold; there
        # is exactly one value of it (lazy import: cycle-free, and keeps
        # this module importable without touching core)
        from repro.core.aircomp import VARSIGMA_MIN
        varsigma_min = VARSIGMA_MIN
    acc = jax.lax.dot_general(
        bp[None, :].astype(jnp.float32), stacked, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[0]            # (D,) local partial
    acc = jax.lax.psum(acc, axis_name)
    varsigma = jnp.maximum(jax.lax.psum(jnp.sum(bp), axis_name), varsigma_min)
    agg = (acc + noise.astype(acc.dtype)) / varsigma
    return agg, varsigma


def aircomp_sum_tree_psum(stacked_leaves, bp: jnp.ndarray, noise_leaves,
                          axis_name, varsigma_min: float | None = None):
    """AirComp reduction for a params PYTREE inside ``jax.shard_map`` with
    the leading K axis of every leaf laid over mesh client axis/axes.

    stacked_leaves: list of (K_local, ...) leaves (tree_flatten order);
    bp: (K_local,) masked transmit powers b_k p_k; noise_leaves: matching
    per-leaf slices of the SAME flat AWGN realization on every shard
    (``repro.core.aggregation.stacked_tree_noise`` from the replicated key
    — eq. 6 adds noise once at the server, not per client or per leaf).

    One-psum-per-round invariant: each leaf's local superposition partial
    (the same (1, K)x(K, D) contraction the single-leaf entry runs) is
    flattened in f32, all partials are concatenated WITH the local
    varsigma partial appended, and the cross-shard reduction is a single
    psum of that flat vector — never one collective per leaf. Noise joins
    the f32 accumulator once, after the collective, so every shard
    normalizes the same received y.

    Returns (list of (D_leaf...) f32 aggregates, varsigma) — both
    replicated across shards. Aggregates are NOT cast back to the leaf
    dtype: a bf16 carry stores its planes rounded, but the global update
    must stay full precision (same contract as ``superpose_normalize``).
    """
    flat = aircomp_partial_tree(stacked_leaves, bp, axis_name=axis_name)
    return aircomp_finalize_tree(flat, stacked_leaves, noise_leaves,
                                 varsigma_min=varsigma_min)


def aircomp_partial_tree(stacked_leaves, bp: jnp.ndarray, axis_name=None):
    """The local half of ``aircomp_sum_tree_psum``: this shard's flattened
    eq.-6 superposition partial — per-leaf (1, K)x(K, D) f32 contractions
    concatenated with the local varsigma partial (sum of bp) appended,
    one flat (d_total + 1,) f32 vector.

    ``axis_name=None`` returns the purely local partial; a mesh axis
    name/tuple reduces it over that SUBSET of the client axes (e.g. the
    intra-pod axes of grouped aggregation — a per-pod partial that stays
    resident across periods until the cross-pod sync). bp = 0 rows (masked
    or phantom clients) contribute exact zeros, so an all-masked shard's
    partial is bit-exactly zero."""
    bp32 = bp[None, :].astype(jnp.float32)
    parts = [jax.lax.dot_general(
        bp32, leaf.reshape((leaf.shape[0], -1)).astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)[0]
        for leaf in stacked_leaves]
    parts.append(jnp.sum(bp).astype(jnp.float32)[None])
    flat = jnp.concatenate(parts)
    if axis_name:
        flat = jax.lax.psum(flat, axis_name)
    return flat


def aircomp_partial_tree_tp(stacked_leaves, bp: jnp.ndarray, tp):
    """The local half of ``aircomp_sum_tree_psum_tp``: this device's
    eq.-6 superposition partial embedded at its position in the FULL
    flattened model vector.

    Each TP-sharded leaf's (1, K)x(K, D_local) contraction lands in a
    full-trailing-shape zero buffer at this shard's TP offset (a
    ``dynamic_update_slice`` along the leaf's TP dim, BEFORE flattening —
    a TP-local block is not a contiguous run of the row-major flat
    vector); TP-replicated leaves and the varsigma partial are masked to
    the lead TP shard so the clients x TP psum counts them exactly once.
    Returns one flat (d_total_FULL + 1,) f32 vector — psumming it over
    the client AND TP axes performs the cross-client superposition and
    the TP gather in the same single collective."""
    from repro.sharding.tp import tp_linear_index, tp_mask_lead

    bp32 = bp[None, :].astype(jnp.float32)
    idx = tp_linear_index(tp)
    parts = []
    for leaf, dim in zip(stacked_leaves, tp.leaf_dims):
        acc = jax.lax.dot_general(
            bp32, leaf.reshape((leaf.shape[0], -1)).astype(jnp.float32),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)[0]
        trail = leaf.shape[1:]
        acc = acc.reshape(trail)
        if dim >= 0:
            full = list(trail)
            full[dim] *= tp.shards
            starts = [0] * len(trail)
            starts[dim] = idx * trail[dim]
            acc = jax.lax.dynamic_update_slice(
                jnp.zeros(tuple(full), jnp.float32), acc, tuple(starts))
        else:
            acc = tp_mask_lead(acc, tp)
        parts.append(acc.reshape(-1))
    parts.append(tp_mask_lead(jnp.sum(bp).astype(jnp.float32), tp)[None])
    return jnp.concatenate(parts)


def aircomp_sum_tree_psum_tp(stacked_leaves, bp: jnp.ndarray, noise_leaves,
                             axis_name, tp,
                             varsigma_min: float | None = None):
    """``aircomp_sum_tree_psum`` with the model storage TP-sharded inside
    each client shard (``tp``: ``repro.sharding.tp.TPTopology``).

    Keeps the one-psum-per-round invariant: the single model-sized psum
    now spans the client axes AND ``tp.axes`` (one collective; the group
    is the whole mesh), simultaneously superposing across clients and
    gathering across TP shards — after it every device holds the full
    received y. ``noise_leaves`` must be drawn at the FULL leaf shapes
    (``tp_full_structs``) from the replicated round key, exactly as the
    flat program draws them, and join once after the collective — so the
    AWGN realization is a function of the MODEL, not the TP layout, and
    every TP extent consumes the same total noise.

    Returns (list of FULL-shape f32 aggregate leaves, varsigma), both
    replicated over every mesh axis."""
    from repro.sharding.tp import tp_full_structs

    flat = aircomp_partial_tree_tp(stacked_leaves, bp, tp)
    axes = axis_name if isinstance(axis_name, (tuple, list)) else (axis_name,)
    flat = jax.lax.psum(flat, tuple(axes) + tuple(tp.axes))
    return aircomp_finalize_tree(flat, tp_full_structs(stacked_leaves, tp),
                                 noise_leaves, varsigma_min=varsigma_min)


# ---------------------------------------------------------------------------
# gather-superpose-decompress: AirComp over the (m, s) compressed cohort
# plane without ever materializing the dense (m, d) payload
# ---------------------------------------------------------------------------

def _gather_superpose_kernel(vs_min, n_blocks, block_n, block_d,
                             bp_ref, w_ref, val_ref, idx_ref, noise_ref,
                             out_ref, vs_ref):
    i = pl.program_id(0)                        # d stripe
    j = pl.program_id(1)                        # flattened (m*s) block
    raw = jnp.sum(bp_ref[...])                  # (1, m) raw b*p

    @pl.when(j == 0)
    def _init():
        out_ref[...] = noise_ref[...].astype(jnp.float32)

    # per-element weighted payload: w already folds b*p (masked) and any
    # int8 dequantization scale, repeated across each row's s entries —
    # so dead slots and padding contribute exact zeros
    a = w_ref[...] * val_ref[...].astype(jnp.float32)        # (BLOCK_N, 1)
    cols = i * block_d + jax.lax.broadcasted_iota(
        jnp.int32, (block_n, block_d), 1)
    onehot = (idx_ref[...] == cols).astype(jnp.float32)      # (BLOCK_N, BLOCK_D)
    # scatter-as-matmul: contracting the flattened-element axis of the
    # one-hot support drops each a_e into its column of the stripe (MXU
    # shape, f32 accumulation) — the revisited out stripe accumulates
    # across the j blocks
    out_ref[...] += jax.lax.dot_general(
        a, onehot, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                  # (1, BLOCK_D)

    @pl.when(j == n_blocks - 1)
    def _normalize():
        out_ref[...] = out_ref[...] / jnp.maximum(raw, vs_min)

    @pl.when((i == 0) & (j == 0))
    def _emit_vs():
        vs_ref[...] = raw[None, None]


@functools.partial(jax.jit, static_argnames=("d", "vs_min", "block_d",
                                             "block_n", "interpret"))
def gather_superpose_pallas(values: jnp.ndarray, idx: jnp.ndarray,
                            bp: jnp.ndarray, noise: jnp.ndarray, *, d: int,
                            scale: jnp.ndarray | None = None,
                            vs_min: float = 1e-12,
                            block_d: int = DEFAULT_BLOCK_D,
                            block_n: int = 1024,
                            interpret: bool | None = None):
    """AirComp over compressed cohort rows, fused: slot gather + b*p
    masking + compressed superposition + AWGN + varsigma in one pass.

    values: (m, s) compressed payload rows (f32 / bf16 / int8);
    idx: (m, s) int32 support (each row's coordinates in [0, d));
    bp: (m,) masked transmit powers b_k p_k; noise: (d,) AWGN;
    scale: optional (m,) int8 dequantization factors, folded into the
    per-element weight so the stored int8 plane feeds the MXU directly
    with f32 accumulation — varsigma stays the RAW sum of b*p.

    Grid: (d stripes) x (flattened m*s element blocks); each (BLOCK_N, 1)
    element column scatters into its stripe through a one-hot
    (BLOCK_N, BLOCK_D) contraction, initialized with the noise stripe and
    normalized on the last block — the dense (m, d) plane never exists.
    Returns ((d,) f32 aggregate, raw varsigma).

    ``interpret=None`` resolves from the backend (compiled on TPU,
    interpret elsewhere)."""
    if interpret is None:
        interpret = backend_interpret_default()
    m, s = values.shape
    n = m * s
    bp32 = bp.astype(jnp.float32)
    w = bp32 if scale is None else bp32 * scale.astype(jnp.float32)
    wflat = jnp.repeat(w, s).reshape(n, 1)
    vflat = values.reshape(n, 1)
    iflat = idx.reshape(n, 1).astype(jnp.int32)
    pad_n = (-n) % block_n
    if pad_n:
        wflat = jnp.pad(wflat, ((0, pad_n), (0, 0)))
        vflat = jnp.pad(vflat, ((0, pad_n), (0, 0)))
        # idx pads with -1: matches no stripe column, and the zero weight
        # kills the product anyway
        iflat = jnp.pad(iflat, ((0, pad_n), (0, 0)), constant_values=-1)
    noise = noise.astype(jnp.float32)
    pad_d = (-d) % block_d
    if pad_d:
        noise = jnp.pad(noise, (0, pad_d))
    np_, dp = n + pad_n, d + pad_d
    n_blocks = np_ // block_n
    kern = functools.partial(_gather_superpose_kernel, float(vs_min),
                             n_blocks, block_n, block_d)
    agg, vs = pl.pallas_call(
        kern,
        grid=(dp // block_d, n_blocks),
        in_specs=[
            pl.BlockSpec((1, m), lambda i, j: (0, 0)),           # raw bp
            pl.BlockSpec((block_n, 1), lambda i, j: (j, 0)),     # weights
            pl.BlockSpec((block_n, 1), lambda i, j: (j, 0)),     # values
            pl.BlockSpec((block_n, 1), lambda i, j: (j, 0)),     # support
            pl.BlockSpec((1, block_d), lambda i, j: (0, i)),     # noise stripe
        ],
        out_specs=[pl.BlockSpec((1, block_d), lambda i, j: (0, i)),
                   pl.BlockSpec((1, 1), lambda i, j: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((1, dp), jnp.float32),
                   jax.ShapeDtypeStruct((1, 1), jnp.float32)],
        interpret=interpret,
    )(bp32[None, :], wflat, vflat, iflat, noise[None, :])
    return agg[0, :d], vs[0, 0]


def gather_superpose_psum(values: jnp.ndarray, idx: jnp.ndarray,
                          bp: jnp.ndarray, noise: jnp.ndarray, axis_name,
                          d: int, scale: jnp.ndarray | None = None,
                          varsigma_min: float | None = None):
    """Compressed-cohort AirComp INSIDE ``jax.shard_map`` with the slot
    axis laid over mesh client axis/axes ``axis_name``: this shard's
    (m_local, s) rows scatter to d-space and contract locally, the local
    aggregate partial and varsigma partial cross shards as ONE flat psum
    (the one-psum-per-round invariant), and the shared AWGN joins the f32
    accumulator once after the collective. ``scale`` folds int8
    dequantization into the contraction weights; varsigma sums RAW b*p.

    Returns ((d,) f32 aggregate, clamped varsigma), replicated."""
    if varsigma_min is None:
        from repro.core.aircomp import VARSIGMA_MIN
        varsigma_min = VARSIGMA_MIN
    m = values.shape[0]
    bp32 = bp.astype(jnp.float32)
    w = bp32 if scale is None else bp32 * scale.astype(jnp.float32)
    rows = jnp.arange(m)[:, None]
    dense = jnp.zeros((m, d), jnp.float32).at[rows, idx].add(
        values.astype(jnp.float32))
    acc = jax.lax.dot_general(
        w[None, :], dense, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[0]               # (d,) partial
    flat = jnp.concatenate([acc, jnp.sum(bp32)[None]])
    flat = jax.lax.psum(flat, axis_name)
    varsigma = jnp.maximum(flat[-1], varsigma_min)
    agg = (flat[:-1] + noise.astype(jnp.float32)) / varsigma
    return agg, varsigma


def aircomp_finalize_tree(flat: jnp.ndarray, stacked_leaves, noise_leaves,
                          axis_name=None, varsigma_min: float | None = None):
    """The finishing half of ``aircomp_sum_tree_psum``: from the flat
    (d_total + 1,) superposition partial, optionally run the final psum
    over the remaining client axes (the ONE cross-shard — or cross-pod —
    collective), then clamp varsigma, split per leaf, and add the shared
    AWGN once in f32 before normalizing. ``stacked_leaves`` only supplies
    the leaf shapes for the split.

    Returns (list of f32 aggregate leaves, varsigma) — replicated over
    every axis the partial was reduced over."""
    if varsigma_min is None:
        from repro.core.aircomp import VARSIGMA_MIN
        varsigma_min = VARSIGMA_MIN
    if axis_name:
        flat = jax.lax.psum(flat, axis_name)
    varsigma = jnp.maximum(flat[-1], varsigma_min)
    out, off = [], 0
    for leaf, noise in zip(stacked_leaves, noise_leaves):
        size = int(np.prod(leaf.shape[1:]))
        acc = flat[off:off + size]
        off += size
        agg = (acc + noise.reshape(-1).astype(acc.dtype)) / varsigma
        out.append(agg.reshape(leaf.shape[1:]))
    return out, varsigma
