"""Fused PAOTA/AirComp aggregation kernel (TPU Pallas).

Computes, in ONE pass over HBM:

    out[d] = ( sum_k bp[k] * stacked[k, d] + noise[d] ) / sum_k bp[k]

where bp = b * p (masked transmit powers). The naive jnp composition makes
four HBM passes (scale, reduce, add-noise, normalize); the paper's hot loop
runs this every aggregation period over the full model vector, so the fused
streaming form is the memory-bound kernel the roofline wants: bytes moved
= K*D + D reads + D writes, arithmetic intensity ~= 1 MAC/element.

Tiling: grid over D in BLOCK_D-wide stripes (lane-dim multiples of 128);
the K axis stays resident in VMEM per stripe ((K, BLOCK_D) tile). The
reduction over K is a (1,K)x(K,BLOCK_D) matmul -> MXU-friendly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_D = 512


def backend_interpret_default() -> bool:
    """Pallas lowering policy: compile for real on TPU, fall back to
    interpret mode everywhere else (CPU/GPU containers). Passing
    ``interpret=True`` unconditionally would mean the "fused" kernel never
    actually compiles even on TPU."""
    return jax.default_backend() != "tpu"


def _kernel(bp_ref, x_ref, noise_ref, out_ref):
    bp = bp_ref[...]                       # (1, K)
    x = x_ref[...]                         # (K, BLOCK_D)
    n = noise_ref[...]                     # (1, BLOCK_D)
    varsigma = jnp.maximum(jnp.sum(bp), 1e-12)
    acc = jax.lax.dot_general(
        bp, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)       # (1, BLOCK_D)
    # noise joins the reduction in the accumulator dtype, not its own
    out_ref[...] = ((acc + n.astype(acc.dtype)) / varsigma).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def aircomp_sum_pallas(stacked: jnp.ndarray, bp: jnp.ndarray,
                       noise: jnp.ndarray, *, block_d: int = DEFAULT_BLOCK_D,
                       interpret: bool | None = None) -> jnp.ndarray:
    """stacked: (K, D); bp: (K,); noise: (D,) -> (D,) aggregate.

    ``interpret=None`` resolves from the active backend (compiled on TPU,
    interpret elsewhere)."""
    if interpret is None:
        interpret = backend_interpret_default()
    k, d = stacked.shape
    noise = noise.astype(stacked.dtype)
    pad = (-d) % block_d
    if pad:
        stacked = jnp.pad(stacked, ((0, 0), (0, pad)))
        noise = jnp.pad(noise, (0, pad))
    dp = d + pad
    grid = (dp // block_d,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, k), lambda i: (0, 0)),           # bp (VMEM-resident)
            pl.BlockSpec((k, block_d), lambda i: (0, i)),     # stacked stripe
            pl.BlockSpec((1, block_d), lambda i: (0, i)),     # noise stripe
        ],
        out_specs=pl.BlockSpec((1, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, dp), stacked.dtype),
        interpret=interpret,
    )(bp[None, :].astype(jnp.float32), stacked, noise[None, :])
    return out[0, :d]
