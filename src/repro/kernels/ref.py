"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import jax


def aircomp_sum_ref(stacked: jnp.ndarray, bp: jnp.ndarray,
                    noise: jnp.ndarray) -> jnp.ndarray:
    """(sum_k bp_k x_k + noise) / sum_k bp_k."""
    varsigma = jnp.maximum(jnp.sum(bp), 1e-12)
    return (jnp.einsum("k,kd->d", bp.astype(jnp.float32),
                       stacked.astype(jnp.float32))
            + noise.astype(jnp.float32)) / varsigma


def round_stats_ref(deltas: jnp.ndarray, g: jnp.ndarray,
                    payload: jnp.ndarray | None = None):
    """Oracle for the fused round-stats kernel: (stats, gn2) with stats
    (K, 2) = [dot_k, ||delta_k||^2] (payload=None) or (K, 3) with
    ||payload_k||^2 appended; gn2 = ||g||^2. All f32."""
    d32 = deltas.astype(jnp.float32)
    g32 = g.astype(jnp.float32)
    cols = [d32 @ g32, jnp.sum(d32 * d32, axis=1)]
    if payload is not None:
        p32 = payload.astype(jnp.float32)
        cols.append(jnp.sum(p32 * p32, axis=1))
    return jnp.stack(cols, axis=1), jnp.sum(g32 * g32)


def superpose_normalize_ref(stacked: jnp.ndarray, powers: jnp.ndarray,
                            mask: jnp.ndarray, noise: jnp.ndarray,
                            vs_min: float = 1e-12):
    """Oracle for the fused superpose-and-normalize kernel:
    ((sum_k b_k p_k x_k + noise) / max(sum bp, vs_min), sum bp)."""
    bp = (powers * mask).astype(jnp.float32)
    raw = jnp.sum(bp)
    acc = jnp.einsum("k,kd->d", bp, stacked.astype(jnp.float32))
    return (acc + noise.astype(jnp.float32)) / jnp.maximum(raw, vs_min), raw


def gather_superpose_ref(values: jnp.ndarray, idx: jnp.ndarray,
                         bp: jnp.ndarray, noise: jnp.ndarray, d: int,
                         scale: jnp.ndarray | None = None,
                         vs_min: float = 1e-12):
    """Oracle for the gather-superpose-decompress kernel: scatter each
    (m, s) compressed row to d-space, then the dense superpose —
    ((sum_k w_k scatter(v_k) + noise) / max(sum bp, vs_min), sum bp)
    with w = bp * scale (scale = the int8 dequantization factor; the
    varsigma normalizer stays the RAW sum of b*p — scale reconstructs
    payload magnitude, it is not transmit power)."""
    m = values.shape[0]
    bp32 = bp.astype(jnp.float32)
    w = bp32 if scale is None else bp32 * scale.astype(jnp.float32)
    raw = jnp.sum(bp32)
    rows = jnp.arange(m)[:, None]
    dense = jnp.zeros((m, d), jnp.float32).at[rows, idx].add(
        values.astype(jnp.float32))
    acc = jnp.einsum("k,kd->d", w, dense)
    return (acc + noise.astype(jnp.float32)) / jnp.maximum(raw, vs_min), raw


def cosine_partials_ref(deltas: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    d32 = deltas.astype(jnp.float32)
    dot = d32 @ g.astype(jnp.float32)
    n2 = jnp.sum(d32 * d32, axis=1)
    return jnp.stack([dot, n2], axis=1)


def swa_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                      window: Optional[int] = None,
                      causal: bool = True) -> jnp.ndarray:
    """q: (BH,T,D), k/v: (BH,S,D). Full-softmax oracle with causal+window."""
    t, s = q.shape[1], k.shape[1]
    scale = 1.0 / (q.shape[-1] ** 0.5)
    logits = jnp.einsum("btd,bsd->bts", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qp = jnp.arange(t)[:, None]
    kp = jnp.arange(s)[None, :]
    mask = jnp.ones((t, s), bool)
    if causal:
        mask = mask & (kp <= qp)
    if window is not None:
        mask = mask & (kp > qp - window)
    logits = jnp.where(mask[None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    # fully-masked rows (can happen for padded queries) -> zeros
    probs = jnp.where(mask[None].any(-1, keepdims=True), probs, 0.0)
    return jnp.einsum("bts,bsd->btd", probs,
                      v.astype(jnp.float32)).astype(q.dtype)


def ssd_intra_chunk_ref(cum, b, c, xdt):
    """Oracle for the SSD intra-chunk kernel. cum: (G,Q); b,c: (G,Q,N);
    xdt: (G,Q,P) -> (y (G,Q,P), state (G,N,P), chunk_decay (G,))."""
    q = cum.shape[1]
    li = cum[:, :, None]
    lj = cum[:, None, :]
    decay = jnp.exp(jnp.clip(li - lj, -60.0, 0.0))
    causal = jnp.tril(jnp.ones((q, q), bool))[None]
    scores = jnp.einsum("gin,gjn->gij", c.astype(jnp.float32),
                        b.astype(jnp.float32))
    scores = jnp.where(causal, scores * decay, 0.0)
    y = jnp.einsum("gij,gjp->gip", scores, xdt.astype(jnp.float32))
    tail = jnp.exp(jnp.clip(cum[:, -1:] - cum, -60.0, 0.0))
    state = jnp.einsum("gjn,gjp->gnp", b.astype(jnp.float32) * tail[..., None],
                       xdt.astype(jnp.float32))
    chunk_decay = jnp.exp(jnp.clip(cum[:, -1], -60.0, 0.0))
    return y.astype(xdt.dtype), state, chunk_decay
