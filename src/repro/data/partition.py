"""Non-IID federated partitioner — Section IV-A.

Paper setting: 100 clients; per-client sample counts drawn from a discrete
ladder ({300,600,900,1200,1500}); each device holds AT MOST five of the ten
digit classes. `partition_noniid` reproduces exactly that (with a
scaled-down default ladder so CPU benchmarks stay fast — `paper_scale=True`
restores the published sizes). A Dirichlet partitioner is included for
ablations (beyond-paper)."""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

PAPER_SIZES = (300, 600, 900, 1200, 1500)
FAST_SIZES = (60, 120, 180, 240, 300)


def partition_noniid(y: np.ndarray, n_clients: int = 100,
                     max_classes_per_client: int = 5,
                     sizes: Optional[Sequence[int]] = None,
                     paper_scale: bool = False,
                     seed: int = 0) -> List[np.ndarray]:
    """Returns per-client index arrays into the training set.

    Each client: |D_k| drawn uniformly from the size ladder; classes drawn
    without replacement (<= max_classes_per_client); samples drawn (with
    replacement if a class pool is exhausted) from those classes only.
    """
    rng = np.random.default_rng(seed)
    sizes = tuple(sizes) if sizes is not None else (
        PAPER_SIZES if paper_scale else FAST_SIZES)
    classes = np.unique(y)
    by_class = {int(c): np.where(y == c)[0] for c in classes}
    out = []
    for _ in range(n_clients):
        d_k = int(rng.choice(sizes))
        n_cls = int(rng.integers(1, max_classes_per_client + 1))
        cls = rng.choice(classes, size=n_cls, replace=False)
        per = np.array_split(np.arange(d_k), n_cls)
        idx = []
        for c, chunk in zip(cls, per):
            pool = by_class[int(c)]
            take = rng.choice(pool, size=len(chunk),
                              replace=len(chunk) > len(pool))
            idx.append(take)
        out.append(np.concatenate(idx))
    return out


def partition_dirichlet(y: np.ndarray, n_clients: int, alpha: float = 0.3,
                        seed: int = 0) -> List[np.ndarray]:
    """Dirichlet(alpha) label-skew partitioner (ablation, beyond-paper)."""
    rng = np.random.default_rng(seed)
    classes = np.unique(y)
    idx_out = [[] for _ in range(n_clients)]
    for c in classes:
        pool = rng.permutation(np.where(y == c)[0])
        props = rng.dirichlet(np.full(n_clients, alpha))
        cuts = (np.cumsum(props) * len(pool)).astype(int)[:-1]
        for k, part in enumerate(np.split(pool, cuts)):
            idx_out[k].append(part)
    return [np.concatenate(p) if p else np.array([], np.int64) for p in idx_out]


def heterogeneity_stats(parts: List[np.ndarray], y: np.ndarray) -> dict:
    sizes = np.array([len(p) for p in parts])
    n_cls = np.array([len(np.unique(y[p])) if len(p) else 0 for p in parts])
    return {"sizes_min": int(sizes.min()), "sizes_max": int(sizes.max()),
            "sizes_mean": float(sizes.mean()),
            "classes_mean": float(n_cls.mean()),
            "classes_max": int(n_cls.max())}
