"""Datasets for the FL experiments and the LM examples.

MNIST is not available offline in this container (DESIGN.md §3), so
``make_mnist_like`` procedurally generates a deterministic 10-class 28x28
dataset with MNIST-like difficulty: each class has a smoothed stroke
prototype; samples add jitter (shift) and pixel noise. A loader hook
(`load_mnist_npz`) accepts a real ``mnist.npz`` if one is present, keeping
the pipeline identical.

``token_stream`` provides synthetic LM token batches for the transformer
examples (power-law unigram with Markov structure so the loss has signal).
"""
from __future__ import annotations

import os
from typing import Optional, Tuple

import numpy as np


def _class_prototypes(n_classes: int, side: int, rng) -> np.ndarray:
    """Smoothed random stroke patterns, one per class — stable, separable."""
    protos = np.zeros((n_classes, side, side), np.float32)
    for c in range(n_classes):
        img = np.zeros((side, side), np.float32)
        # draw 3 random strokes (line segments) per class
        for _ in range(3):
            x0, y0 = rng.integers(4, side - 4, 2)
            ang = rng.uniform(0, 2 * np.pi)
            length = rng.integers(8, side - 6)
            for t in np.linspace(0, 1, 60):
                x = int(np.clip(x0 + np.cos(ang) * t * length, 0, side - 1))
                y = int(np.clip(y0 + np.sin(ang) * t * length, 0, side - 1))
                img[y, x] = 1.0
        # box-blur twice for stroke thickness
        for _ in range(2):
            img = (img
                   + np.roll(img, 1, 0) + np.roll(img, -1, 0)
                   + np.roll(img, 1, 1) + np.roll(img, -1, 1)) / 5.0
        protos[c] = img / max(img.max(), 1e-6)
    return protos


def make_mnist_like(n_train: int = 20000, n_test: int = 4000,
                    n_classes: int = 10, side: int = 28, noise: float = 0.25,
                    seed: int = 1234) -> Tuple[np.ndarray, np.ndarray,
                                               np.ndarray, np.ndarray]:
    """Returns (x_train (N,784) float32 in [0,1], y_train, x_test, y_test)."""
    rng = np.random.default_rng(seed)
    protos = _class_prototypes(n_classes, side, rng)

    def gen(n):
        y = rng.integers(0, n_classes, n).astype(np.int32)
        x = np.empty((n, side * side), np.float32)
        shifts = rng.integers(-2, 3, size=(n, 2))
        for i in range(n):
            img = protos[y[i]]
            img = np.roll(img, shifts[i, 0], axis=0)
            img = np.roll(img, shifts[i, 1], axis=1)
            img = img + noise * rng.standard_normal((side, side)).astype(np.float32)
            x[i] = np.clip(img, 0.0, 1.0).reshape(-1)
        return x, y

    x_tr, y_tr = gen(n_train)
    x_te, y_te = gen(n_test)
    return x_tr, y_tr, x_te, y_te


def load_mnist_npz(path: str = "mnist.npz"):
    """Optional hook: real MNIST if a .npz with x_train/y_train/x_test/y_test
    exists (same interface as make_mnist_like). Returns None if absent."""
    if not os.path.exists(path):
        return None
    z = np.load(path)
    x_tr = z["x_train"].reshape(len(z["x_train"]), -1).astype(np.float32) / 255.0
    x_te = z["x_test"].reshape(len(z["x_test"]), -1).astype(np.float32) / 255.0
    return x_tr, z["y_train"].astype(np.int32), x_te, z["y_test"].astype(np.int32)


def get_dataset(prefer_real: bool = True, **kw):
    if prefer_real:
        real = load_mnist_npz()
        if real is not None:
            return real
    return make_mnist_like(**kw)


# ---------------------------------------------------------------------------
# synthetic LM tokens (transformer examples / integration tests)
# ---------------------------------------------------------------------------

def token_stream(vocab: int, batch: int, seq: int, n_batches: int,
                 seed: int = 0):
    """Markov-ish synthetic token batches: next token = (prev*a + c) % vocab
    with noise — learnable structure, zero storage."""
    rng = np.random.default_rng(seed)
    a = 31 % vocab or 1
    for _ in range(n_batches):
        x = np.empty((batch, seq), np.int64)
        x[:, 0] = rng.integers(0, vocab, batch)
        flip = rng.random((batch, seq)) < 0.1
        for t in range(1, seq):
            nxt = (x[:, t - 1] * a + 7) % vocab
            x[:, t] = np.where(flip[:, t], rng.integers(0, vocab, batch), nxt)
        yield {"tokens": x.astype(np.int32)}
