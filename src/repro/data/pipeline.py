"""Minibatch pipeline for federated clients: deterministic, stateless
shuffled batching (reshuffle each epoch from a fold-in seed).

Two consumers share ONE index-selection code path (``ClientData
.batch_indices``) so they are reproducible against each other:

* the legacy per-client loop (``FLClient.local_train``) gathers the
  selected rows on host, one minibatch at a time;
* the batched federation engine (``repro.fl.engine.BatchedEngine``)
  stacks the per-round index plans into a ``(K, M, B)`` tensor and
  gathers on device from the padded federation built by
  ``stack_federation``.

``counter_batch_plan`` is the third, stateless planner: a pure-jnp
``(K, M, B)`` plan keyed on (key, client id) with i.i.d. uniform index
draws. It has no epoch cursor — the plan for round r is a function of the
round key alone — which is what lets the fused on-device round
(``repro.fl.fused``) build its minibatches inside a ``lax.scan`` step with
zero host involvement. It samples WITH replacement (unlike the
epoch-shuffled host cursors), a documented statistical — not numerical —
deviation; see EXPERIMENTS.md §Fused PAOTA round.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

import jax
import jax.numpy as jnp
import numpy as np


class ClientData:
    """One client's local dataset D_k with an epoch-shuffled batch iterator."""

    def __init__(self, x: np.ndarray, y: np.ndarray, client_id: int, seed: int = 0):
        self.x, self.y = x, y
        self.client_id = client_id
        self._seed = seed
        self._epoch = 0
        self._order_cache = (-1, None)   # (epoch, permutation)

    def __len__(self):
        return len(self.y)

    def _epoch_order(self) -> np.ndarray:
        # memoized per epoch: the permutation is a pure function of
        # (seed, client_id, epoch), and successive local_train calls often
        # resume mid-epoch
        if self._order_cache[0] != self._epoch:
            rng = np.random.default_rng(
                (self._seed, self.client_id, self._epoch))
            self._order_cache = (self._epoch, rng.permutation(len(self.y)))
        return self._order_cache[1]

    def batch_indices(self, batch_size: int, n_batches: int):
        """Yield n_batches index arrays into (x, y), cycling+reshuffling as
        needed. This is the single source of truth for batch selection —
        both the legacy loop and the batched engine consume it, which is
        what makes the two engines reproducible against each other."""
        order = self._epoch_order()
        i = 0
        for _ in range(n_batches):
            if i + batch_size > len(order):
                self._epoch += 1
                order = self._epoch_order()
                i = 0
            sel = order[i:i + batch_size]
            i += batch_size
            yield sel

    def batches(self, batch_size: int, n_batches: int):
        """Yield n_batches minibatches, cycling+reshuffling as needed."""
        for sel in self.batch_indices(batch_size, n_batches):
            yield {"x": self.x[sel], "y": self.y[sel]}


def build_federation(x, y, parts, seed: int = 0):
    return [ClientData(x[p], y[p], k, seed) for k, p in enumerate(parts)]


def counter_batch_plan(key, n_samples, n_batches: int, batch_size: int,
                       client_ids=None, batch_sizes=None):
    """Stateless minibatch plan for a whole federation: (K, M, B) int32
    indices, client k drawing i.i.d. uniform from range(n_samples[k]).

    ``key`` should already encode the round (see ``repro.core.scheduler
    .round_tag_key``); each client folds in its id, so plans are
    independent across clients and rounds. Pure and jit-traceable —
    callable from inside a ``lax.scan`` step. Padding rows are never
    selected because draws are bounded by the true per-client size.

    ``client_ids``: the GLOBAL client ids behind ``n_samples``'s rows
    (default ``arange(K)``). A mesh shard holding clients [off, off+k_loc)
    passes its id slice and gets bit-identical rows to the full-federation
    plan — each client's draw depends only on (key, its id, its size), so
    plans shard over the client axis with no cross-device draws.

    ``batch_sizes``: optional (K,) per-client effective batch sizes
    b_k <= batch_size (heterogeneous-client federations). The plan keeps
    its fixed (K, M, B) shape — column j of client k's rows repeats draw
    j mod b_k — so a mean-reduced gradient over the row weights each of
    the b_k distinct samples by ceil/floor(B / b_k) / B: EXACTLY the
    b_k-minibatch gradient when b_k divides B, a near-uniform weighting
    otherwise. b_k = B reproduces the homogeneous plan bit for bit (the
    underlying draws are shared)."""
    n_samples = jnp.asarray(n_samples, jnp.int32)
    if client_ids is None:
        client_ids = jnp.arange(n_samples.shape[0], dtype=jnp.uint32)
    else:
        client_ids = jnp.asarray(client_ids, jnp.uint32)

    def one(cid, nk):
        ck = jax.random.fold_in(key, cid)
        return jax.random.randint(ck, (n_batches, batch_size), 0, nk,
                                  dtype=jnp.int32)

    plans = jax.vmap(one)(client_ids, n_samples)
    if batch_sizes is None:
        return plans
    batch_sizes = jnp.asarray(batch_sizes, jnp.int32)
    cols = jnp.arange(batch_size, dtype=jnp.int32)
    fold = jax.vmap(lambda p, bk: p[:, jnp.mod(cols, bk)])
    return fold(plans, batch_sizes)


@dataclass
class StackedFederation:
    """Padded device-friendly view of a federation: per-client datasets
    stacked into ``(K, n_max, ...)`` arrays.

    Rows beyond ``n_samples[k]`` are zero padding. Batch-index plans from
    ``ClientData.batch_indices`` never point into the padding (they are
    drawn from ``range(n_samples[k])``), so no additional masking is
    needed on the gather path; ``mask`` is provided for consumers that
    reduce over the sample axis directly.
    """
    x: np.ndarray            # (K, n_max, ...) feature dtype, zero-padded —
                             # float32 features for the MLP federation,
                             # int32 token rows for transformer clients
    y: np.ndarray            # (K, n_max) int32, zero-padded
    n_samples: np.ndarray    # (K,) int64 true per-client sizes
    mask: np.ndarray         # (K, n_max) float32, 1.0 on real rows

    @property
    def n_clients(self) -> int:
        return len(self.n_samples)


def stack_federation(fed: List[ClientData]) -> StackedFederation:
    """Pad+stack per-client (ragged) datasets into (K, n_max, ...) arrays.

    The feature dtype and trailing shape follow the clients' ``x`` (float
    feature vectors, int token sequences, ... — float64 narrows to the
    device float32); labels stack as int32."""
    if not fed:
        raise ValueError("empty federation")
    sizes = np.array([len(c) for c in fed], dtype=np.int64)
    n_max = int(sizes.max())
    x0 = np.asarray(fed[0].x)
    x_dtype = np.float32 if np.issubdtype(x0.dtype, np.floating) else x0.dtype
    x = np.zeros((len(fed), n_max) + x0.shape[1:], x_dtype)
    y = np.zeros((len(fed), n_max), np.int32)
    mask = np.zeros((len(fed), n_max), np.float32)
    for k, c in enumerate(fed):
        x[k, :len(c)] = c.x
        y[k, :len(c)] = c.y
        mask[k, :len(c)] = 1.0
    return StackedFederation(x=x, y=y, n_samples=sizes, mask=mask)
