"""Minibatch pipeline for federated clients: deterministic, stateless
shuffled batching (reshuffle each epoch from a fold-in seed)."""
from __future__ import annotations

import numpy as np


class ClientData:
    """One client's local dataset D_k with an epoch-shuffled batch iterator."""

    def __init__(self, x: np.ndarray, y: np.ndarray, client_id: int, seed: int = 0):
        self.x, self.y = x, y
        self.client_id = client_id
        self._seed = seed
        self._epoch = 0

    def __len__(self):
        return len(self.y)

    def batches(self, batch_size: int, n_batches: int):
        """Yield n_batches minibatches, cycling+reshuffling as needed."""
        rng = np.random.default_rng((self._seed, self.client_id, self._epoch))
        order = rng.permutation(len(self.y))
        i = 0
        for _ in range(n_batches):
            if i + batch_size > len(order):
                self._epoch += 1
                rng = np.random.default_rng(
                    (self._seed, self.client_id, self._epoch))
                order = rng.permutation(len(self.y))
                i = 0
            sel = order[i:i + batch_size]
            i += batch_size
            yield {"x": self.x[sel], "y": self.y[sel]}


def build_federation(x, y, parts, seed: int = 0):
    return [ClientData(x[p], y[p], k, seed) for k, p in enumerate(parts)]
