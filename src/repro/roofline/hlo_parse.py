"""Trip-count-aware HLO cost extraction.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE regardless
of trip count (verified in tests/test_roofline.py) — our layer/local-step
scans therefore undercount FLOPs and collective bytes by up to L*M (~200x).
This module parses the post-SPMD optimized HLO text:

  * splits it into computations and builds a per-computation symbol table
    (%var -> shape) so dot operand shapes can be resolved;
  * finds `while` ops and reads XLA's ``known_trip_count`` backend config
    (fallback: the comparison constant in the condition computation);
  * walks the call graph (entry -> while bodies / fusions / calls /
    conditionals), accumulating a repetition multiplier per computation;
  * per computation, sums dot/convolution FLOPs and collective result
    bytes.

All numbers are per-chip (the partitioned module is per-device).
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%([\w\.\-]+)\s*\(.*\{$")
_DEF = re.compile(r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\([^=]*?\)|\w+\[[0-9,]*\](?:\{[^}]*\})?)")
_SHAPE = re.compile(r"(\w+)\[([0-9,]*)\]")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLREF = re.compile(r"(?:body|condition|to_apply|calls)=%?([\w\.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")


def _shape_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _first_shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(text):
        total += _DTYPE_BYTES.get(dt, 0) * _shape_elems(dims)
    return total


def split_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        stripped = line.strip()
        m = _COMP_HDR.match(stripped)
        if m:
            cur = m.group(2)
            comps[cur] = []
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is not None and stripped:
            comps[cur].append(stripped)
    return comps


def _symbols(lines: List[str]) -> Dict[str, str]:
    """%var -> shape-ish string (may be a tuple type)."""
    sym = {}
    for line in lines:
        m = _DEF.match(line)
        if m:
            sym[m.group(1)] = m.group(2)
    return sym


def _op_operands(line: str, op: str) -> List[str]:
    """Operand names of ``op(...)``. Handles both bare-name operands
    (``dot(%a, %b)``) and typed operands as printed by newer XLA
    (``dot(f32[8,8]{1,0} %a, f32[8,8]{1,0} %b)``)."""
    m = re.search(rf"\b{op}\(([^)]*)\)", line)
    if not m:
        return []
    names = re.findall(r"%([\w\.\-]+)", m.group(1))
    if names:
        return names
    # very old printers omit the %-sigil entirely
    return [tok.strip() for tok in m.group(1).split(",") if tok.strip()]


def _dot_flops(line: str, sym: Dict[str, str]) -> float:
    m = _DEF.match(line)
    if not m:
        return 0.0
    out_shapes = _SHAPE.findall(m.group(2))
    if not out_shapes:
        return 0.0
    out_elems = _shape_elems(out_shapes[0][1])
    ops = _op_operands(line, "dot")
    if len(ops) < 2:
        return 0.0
    lhs_shape = sym.get(ops[0], "")
    lhs_dims_m = _SHAPE.findall(lhs_shape)
    if not lhs_dims_m:
        return 0.0
    lhs_dims = [int(x) for x in lhs_dims_m[0][1].split(",") if x]
    mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
    contracted = 1
    if mc and mc.group(1):
        for i in mc.group(1).split(","):
            if int(i) < len(lhs_dims):
                contracted *= lhs_dims[int(i)]
    return 2.0 * out_elems * contracted


def _conv_flops(line: str, sym: Dict[str, str]) -> float:
    m = _DEF.match(line)
    if not m:
        return 0.0
    out_elems = sum(_shape_elems(d) for _, d in _SHAPE.findall(m.group(2)))
    ops = _op_operands(line, "convolution")
    if len(ops) < 2:
        return 0.0
    kern = sym.get(ops[1], "")
    kern_elems = sum(_shape_elems(d) for _, d in _SHAPE.findall(kern))
    return 2.0 * out_elems * kern_elems


def _cond_trip_count(cond_lines: List[str]) -> int:
    consts = [int(m.group(1)) for line in cond_lines
              for m in re.finditer(r"constant\((\d+)\)", line)]
    return max(consts) if consts else 1


def analyze(hlo: str) -> Dict[str, float]:
    comps = split_computations(hlo)

    local: Dict[str, Dict[str, float]] = {}
    edges: Dict[str, List[Tuple[str, float]]] = {}
    for name, lines in comps.items():
        sym = _symbols(lines)
        f = 0.0
        coll = {c: 0.0 for c in _COLLECTIVES}
        edge: List[Tuple[str, float]] = []
        for line in lines:
            if " dot(" in line or line.split("=")[-1].lstrip().startswith("dot("):
                f += _dot_flops(line, sym)
            elif " convolution(" in line:
                f += _conv_flops(line, sym)
            for c in _COLLECTIVES:
                if re.search(rf"\s{c}(-start)?\(", line) and "-done(" not in line:
                    # result type = text between '=' and the op name; handles
                    # variadic tuple collectives whose type list contains
                    # /*index=N*/ comments (the PAOTA aggregation all-reduce)
                    rhs = line.split("=", 1)[1] if "=" in line else line
                    seg = re.split(rf"\s{c}(?:-start)?\(", rhs)[0]
                    coll[c] += _first_shape_bytes(seg)
                    break
            if " while(" in line:
                trips = 1.0
                tm = _TRIP.search(line)
                bm = re.search(r"body=%?([\w\.\-]+)", line)
                cm = re.search(r"condition=%?([\w\.\-]+)", line)
                if tm:
                    trips = float(tm.group(1))
                elif cm:
                    trips = float(_cond_trip_count(comps.get(cm.group(1), [])))
                if bm:
                    edge.append((bm.group(1), trips))
            else:
                for ref in _CALLREF.findall(line):
                    if ref in comps:
                        edge.append((ref, 1.0))
                br = _BRANCHES.search(line)
                if br:
                    for c in br.group(1).split(","):
                        c = c.strip().lstrip("%")
                        if c in comps:
                            edge.append((c, 1.0))
        local[name] = {"flops": f, **coll}
        edges[name] = edge

    called = {c for es in edges.values() for c, _ in es}
    entries = [n for n in comps if n not in called] or list(comps)

    totals = {"flops": 0.0, **{c: 0.0 for c in _COLLECTIVES}}
    stack = set()

    def walk(name: str, mult: float):
        if name in stack or mult <= 0:
            return
        stack.add(name)
        lc = local.get(name, {})
        totals["flops"] += lc.get("flops", 0.0) * mult
        for c in _COLLECTIVES:
            totals[c] += lc.get(c, 0.0) * mult
        for child, trips in edges.get(name, []):
            walk(child, mult * trips)
        stack.discard(name)

    for e in entries:
        walk(e, 1.0)
    totals["coll_bytes"] = sum(totals[c] for c in _COLLECTIVES)
    return totals
