"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from the dry-run JSON
records.

    PYTHONPATH=src python -m repro.roofline.report [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List


def load(dir_: str) -> List[Dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def _fmt_s(x: float) -> str:
    return f"{x:.2e}"


def dryrun_table(recs: List[Dict], mesh: str) -> str:
    lines = [
        "| arch | shape | status | FLOPs/chip | bytes/chip | coll B/chip | "
        "temp GB/chip | args GB/chip | compile s |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["mesh"] != mesh:
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['status']}: "
                         f"{r.get('note', '')[:40]} | | | | | | |")
            continue
        ma = r.get("memory_analysis", {})
        lines.append(
            f"| {r['arch']} | {r['shape']} | ok | {_fmt_s(r['hlo_flops'])} | "
            f"{_fmt_s(r['hlo_bytes'])} | "
            f"{_fmt_s(sum(r['collectives'].values()))} | "
            f"{ma.get('temp_size_in_bytes', 0) / 2 ** 30:.1f} | "
            f"{ma.get('argument_size_in_bytes', 0) / 2 ** 30:.2f} | "
            f"{r.get('compile_s', 0):.0f} |")
    return "\n".join(lines)


def roofline_table(recs: List[Dict], mesh: str = "single") -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "MODEL_FLOPS/HLO | what would move the dominant term |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["mesh"] != mesh or r["status"] != "ok":
            continue
        t = r["roofline"]
        ratio = r.get("useful_flops_ratio")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_s(t['compute_s'])} | "
            f"{_fmt_s(t['memory_s'])} | {_fmt_s(t['collective_s'])} | "
            f"{t['dominant'].replace('_s', '')} | "
            f"{'-' if ratio is None else f'{ratio:.2f}'} | "
            f"{suggestion(r)} |")
    return "\n".join(lines)


def suggestion(r: Dict) -> str:
    t = r["roofline"]
    dom = t["dominant"]
    shape = r["shape"]
    if dom == "memory_s":
        if shape in ("decode_32k", "long_500k"):
            return "KV/state reads dominate: quantize cache or widen batch"
        return "activation traffic: larger fused blocks / less remat"
    if dom == "collective_s":
        if "moe" in r["arch"] or "mixtral" in r["arch"] or "llama4" in r["arch"]:
            return "all-to-all bound: fewer EP hops or wider expert shards"
        return "TP psum bound: shard less on model / overlap collectives"
    return "compute bound (good): MXU-align tiles"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    recs = load(args.dir)
    print("### Single-pod (16x16 = 256 chips)\n")
    print(dryrun_table(recs, "single"))
    print("\n### Multi-pod (2x16x16 = 512 chips)\n")
    print(dryrun_table(recs, "multi"))
    print("\n### Roofline (single-pod)\n")
    print(roofline_table(recs, "single"))


if __name__ == "__main__":
    main()
