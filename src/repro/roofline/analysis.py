"""Roofline-term derivation from compiled dry-run artifacts (no hardware).

    compute    = HLO_FLOPs / (chips * peak_FLOPs)
    memory     = HLO_bytes / (chips * HBM_bw)
    collective = collective_bytes / (chips * link_bw)

HLO_FLOPs / HLO_bytes come from compiled.cost_analysis(). Collective bytes
are NOT in cost_analysis: we parse the post-SPMD optimized HLO
(compiled.as_text()) and sum operand sizes of every all-gather/all-reduce/
reduce-scatter/all-to-all/collective-permute op.

Hardware constants (per instructions — TPU v5e-like): 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict

import numpy as np


@dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12       # bf16 per chip
    hbm_bw: float = 819e9            # bytes/s per chip
    ici_bw: float = 50e9             # bytes/s per link


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %x = f32[8,128]{1,0} all-gather(...)   /  bf16[2,4,8] all-to-all(
_OP_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\][^=]*?\s("
    + "|".join(_COLLECTIVES) + r")[\s(]")
_TUPLE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    nb = _DTYPE_BYTES.get(dtype)
    if nb is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * nb


def cost_analysis_dict(compiled) -> Dict[str, float]:
    """Normalize ``compiled.cost_analysis()`` across jax versions: older
    releases return a dict, newer ones a one-element list of dicts."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost or {})


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum of result bytes per collective kind from optimized HLO text.

    Handles tuple-shaped results ``(f32[..], f32[..]) all-reduce``.
    """
    out = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        kind = None
        for c in _COLLECTIVES:
            # match op name, not metadata mentions
            if f" {c}(" in line or f" {c}-start(" in line:
                kind = c
                break
        if kind is None:
            continue
        if "-done(" in line:
            continue  # avoid double counting async pairs
        lhs = line.split("=", 1)[0] if "=" in line else ""
        rhs = line.split("=", 1)[1] if "=" in line else line
        shapes = _TUPLE_RE.findall(rhs.split(kind)[0])
        out[kind] += sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        del lhs
    return out


def model_flops(n_params: int, n_active: int, tokens: int,
                is_train: bool) -> float:
    """6*N*D (train) / 2*N*D (inference) with N = active params."""
    mult = 6.0 if is_train else 2.0
    return mult * n_active * tokens


def roofline_terms(hlo_flops: float, hlo_bytes: float,
                   coll: Dict[str, int], chips: int,
                   hw: HW = HW()) -> Dict[str, float]:
    """NOTE: XLA's cost_analysis()/as_text() on the SPMD-partitioned module
    report PER-PARTITION (per-chip) numbers — verified against the known
    KV-cache size in EXPERIMENTS.md §Dry-run. So the roofline terms divide
    by per-chip peaks only; `chips` is kept for reporting."""
    total_coll = float(sum(coll.values()))
    terms = {
        "compute_s": hlo_flops / hw.peak_flops,
        "memory_s": hlo_bytes / hw.hbm_bw,
        # per-chip collective traffic over ICI links (conservative: 1 link)
        "collective_s": total_coll / hw.ici_bw,
        "collective_bytes": total_coll,
    }
    dom = max(("compute_s", "memory_s", "collective_s"),
              key=lambda k: terms[k])
    terms["dominant"] = dom
    denom = max(sum(terms[k] for k in
                    ("compute_s", "memory_s", "collective_s")), 1e-30)
    terms["compute_fraction"] = terms["compute_s"] / denom
    return terms
