from repro.roofline.analysis import (HW, collective_bytes, model_flops,  # noqa: F401
                                     roofline_terms)
