"""Theorem-1 convergence-bound terms (eqs. 21-23).

Used to (i) check the learning-rate regime A^r < 1 before launching a run,
(ii) evaluate the controllable gap terms (d)+(e) that the power control
minimizes, and (iii) the bound-vs-empirical benchmark (benchmarks/bound.py).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class BoundConstants:
    """Assumption constants. Defaults follow Section IV-A (L=10, M=5)."""
    smooth_l: float = 10.0      # L   (Assumption 1)
    zeta: float = 1.0           # data-heterogeneity bound (Assumption 2)
    delta: float = 0.01         # staleness inner-product bound (Assumption 3)
    epsilon: float = 0.05       # ||w^{r-n} - w^r|| bound     (Assumption 3)
    vartheta: float = 1.0       # local gradient-change bound  (Assumption 3)
    sigma: float = 1.0          # SGD variance bound           (Assumption 4)
    eta: float = 0.01           # learning rate
    local_steps: int = 5        # M


def contraction_A(c: BoundConstants) -> float:
    """A^r (eq. 22). Must be < 1 for the recursion to contract."""
    l, eta, m, vth = c.smooth_l, c.eta, c.local_steps, c.vartheta
    denom = 1.0 - 2.0 * eta ** 2 * m ** 2 * l ** 2
    if denom <= 0:
        return np.inf
    return (1.0 + 2.0 * l * c.delta - l * eta * m
            + 8.0 * l ** 2 * eta ** 2 * m * vth ** 2
            + (eta * l ** 2 + 4.0 * m * eta ** 2 * l ** 3)
            * 8.0 * l * eta ** 2 * m ** 3 * vth ** 2 / denom)


def gap_G(c: BoundConstants, alphas: np.ndarray, sum_bp: float,
          model_dim: int, sigma_n2: float) -> dict:
    """G^r terms (a)-(e) of eq. (23). alphas: aggregation weights (K,),
    sum_bp = sum_k b_k p_k, model_dim = d."""
    l, eta, m = c.smooth_l, c.eta, c.local_steps
    denom = 1.0 - 2.0 * eta ** 2 * m ** 2 * l ** 2
    k = len(alphas)
    term_a = (2 * eta * m + 8 * l * eta * m ** 2
              + 4 * eta ** 2 * m ** 3 * l ** 2
              * (eta * l ** 2 + 4 * m * eta ** 2 * l ** 3) / denom) * c.zeta
    term_b = 2 * eta * m * l ** 2 * c.epsilon ** 2
    term_c = (2 * eta ** 2 * l * m ** 2
              + (eta * l ** 2 + 4 * m * eta ** 2 * l ** 3)
              * eta ** 2 * m ** 3 / denom) * c.sigma ** 2
    term_d = l * c.epsilon ** 2 * k * float(np.sum(alphas ** 2))
    term_e = 2.0 * l * model_dim * sigma_n2 / max(sum_bp, 1e-30) ** 2
    return {"a": term_a, "b": term_b, "c": term_c, "d": term_d, "e": term_e,
            "total": term_a + term_b + term_c + term_d + term_e,
            "controllable": term_d + term_e}


def bound_trajectory(c: BoundConstants, g_terms: list, f0_gap: float) -> np.ndarray:
    """Eq. (21): gap_R = prod A * gap_0 + sum_r (prod_{i>r} A) G^r."""
    a = contraction_A(c)
    gaps = [f0_gap]
    for g in g_terms:
        gaps.append(a * gaps[-1] + g)
    return np.array(gaps)
