"""Over-the-air computation (AirComp) channel model — Section II-C.

Implements the paper's uplink MAC model exactly:
  * Rayleigh fading, i.i.d. across rounds (Sec. II-C);
  * transmitter pre-scaling phi_k = b_k p_k h_k^H / |h_k|^2  (eq. 5) — with
    perfect CSI the phase cancels, so only |h_k| matters (DESIGN.md §3);
  * received superposition y = sum_k b_k p_k w_k + n,  n ~ N(0, sigma_n^2 I)
    (eq. 6), sigma_n^2 = B * N0 (bandwidth x noise PSD);
  * server normalization w = y / sum_k b_k p_k  (eq. 8), giving aggregation
    weights alpha_k = b_k p_k / sum_i b_i p_i.

TPU adaptation (DESIGN.md §3): the superposition is the wireless analogue of
an all-reduce; ``repro.core.aggregation`` runs the same math as a masked
weighted psum over the client mesh axis, and ``repro.kernels.aircomp_sum``
provides the fused Pallas kernel for the stacked (K, D) form used here.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


# Smallest meaningful eq.-8 normalizer sum_k b_k p_k. Used both as the
# division clamp and as the zero-uploader threshold: at or below it nothing
# superposed this period, so the received y is pure AWGN and normalizing it
# would overwrite w_g with ~1/VARSIGMA_MIN-amplified noise — the round must
# hold the global instead (repro.core.aggregation.guarded_global_update).
VARSIGMA_MIN = 1e-12


def dbm_per_hz_to_watts(n0_dbm_hz: float) -> float:
    """-174 dBm/Hz -> Watts/Hz."""
    return 10.0 ** ((n0_dbm_hz - 30.0) / 10.0)


@dataclass(frozen=True)
class ChannelConfig:
    """Section IV-A settings by default."""
    bandwidth_hz: float = 20e6
    n0_dbm_hz: float = -174.0
    p_max_watts: float = 15.0
    rayleigh_scale: float = 1.0

    @property
    def sigma_n2(self) -> float:
        """Noise power sigma_n^2 = B * N0 (Watts)."""
        return self.bandwidth_hz * dbm_per_hz_to_watts(self.n0_dbm_hz)

    @property
    def sigma_n(self) -> float:
        return float(jnp.sqrt(self.sigma_n2))


def sample_channel_gains(key, k: int, chan: ChannelConfig):
    """|h_k| ~ Rayleigh(scale): magnitude of CN(0, 2*scale^2)."""
    u = jax.random.uniform(key, (k,), minval=1e-6, maxval=1.0)
    return chan.rayleigh_scale * jnp.sqrt(-2.0 * jnp.log(u))


def effective_power_cap(w_norm2, h_abs, p_max, eps: float = 1e-12):
    """Power constraint (7): ||phi_k w_k||^2 = p_k^2 ||w_k||^2 / |h_k|^2 <= P_max
    => p_k <= |h_k| sqrt(P_max / ||w_k||^2). Returns the per-client cap."""
    return h_abs * jnp.sqrt(p_max / jnp.maximum(w_norm2, eps))


def aircomp_aggregate(stacked: jnp.ndarray, powers: jnp.ndarray,
                      mask: jnp.ndarray, key, sigma_n: float,
                      use_kernel: bool = False):
    """Eq. (6)+(8): stacked (K, D) client payloads -> (D,) normalized aggregate.

    powers: (K,) transmit powers p_k; mask: (K,) in {0,1} ready bits b_k.
    Returns (aggregate, normalizer) where normalizer = sum_k b_k p_k.
    """
    bp = powers * mask
    varsigma = jnp.maximum(jnp.sum(bp), VARSIGMA_MIN)
    noise = sigma_n * jax.random.normal(key, stacked.shape[1:], stacked.dtype)
    if use_kernel:
        from repro.kernels.ops import aircomp_sum
        agg = aircomp_sum(stacked, bp, noise)
    else:
        agg = (jnp.einsum("k,kd->d", bp.astype(stacked.dtype), stacked)
               + noise) / varsigma.astype(stacked.dtype)
    return agg, varsigma


def aggregation_weights(powers, mask):
    """alpha_k = b_k p_k / sum_i b_i p_i (eq. 8)."""
    bp = powers * mask
    return bp / jnp.maximum(jnp.sum(bp), VARSIGMA_MIN)


def equivalent_noise_var(sigma_n2: float, powers, mask, d: int):
    """E||n~||^2 = d sigma_n^2 / (sum b_k p_k)^2 — term (e) numerator basis."""
    s = jnp.maximum(jnp.sum(powers * mask), VARSIGMA_MIN)
    return d * sigma_n2 / (s * s)
