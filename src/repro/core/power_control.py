"""Power-control optimization — Section III-B.

The transmit power of client k trades off staleness vs gradient similarity
(eq. 25):

    p_k = p_max_k * ( beta_k * rho_k + (1 - beta_k) * theta_k )
    rho_k   = Omega / (s_k + Omega)                      (staleness factor)
    theta_k = (cos(dw_k, w_g^t - w_g^{t-1}) + 1) / 2     (similarity factor)

Minimizing the controllable part of the convergence bound G^r (Theorem 1,
terms (d)+(e)) over beta in [0,1]^K is the fractional program P2:

    min_beta  h1(beta)/h2(beta)
    h1 = L eps^2 K * sum_k b_k p_k^2 + 2 L d sigma_n^2      (term d + e numer.)
    h2 = (sum_k b_k p_k)^2                                  (normalizer^2)

with p = P_max (theta + D beta), D = diag(rho - theta) — both h1 and h2 are
convex quadratics in beta, exactly the paper's P2 structure (their G is the
diagonal L eps^2 K * diag(b) instance, their Q the rank-one b b^T instance).

Solvers (repro.core.dinkelbach): the paper-faithful Dinkelbach loop with a
piecewise-linear 0-1 MIP inner step (repro.core.milp — CPLEX replaced by a
pure-python branch & bound), plus two beyond-paper inner solvers validated
against it (projected gradient, and an exact KKT water-filling solver that
exploits the diagonal+rank-one structure; see DESIGN.md §3).
"""
from __future__ import annotations

import functools
import operator
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


def staleness_factor(s, omega: float):
    """rho_k = Omega / (s_k + Omega); s_k = rounds the model is stale."""
    return omega / (s + omega)


def similarity_factor(cos_sim):
    """theta_k = (cos + 1)/2 in [0, 1]."""
    return (cos_sim + 1.0) / 2.0


# ---------------------------------------------------------------------------
# tree-reduced per-client scalars
#
# The federated model is a params pytree whose leaves are client-stacked
# (K, ...) arrays; a raveled federation is just the single-(K, D)-leaf
# instance (a bare jnp array IS a one-leaf pytree, so the raveled callers
# need no adapter and execute the exact historical op sequence). Every
# cross-leaf scalar is accumulated as per-leaf partials summed in
# tree_flatten order — under a mesh client axis these per-client values are
# shard-local (the reduction runs over the model dims, which every shard
# holds whole), so none of them costs a collective.
# ---------------------------------------------------------------------------

def _leaf2d(x):
    """(K, ...) leaf -> (K, prod(trailing)) view; identity for (K, D)."""
    return x.reshape((x.shape[0], -1))


def _accumulate(parts):
    return functools.reduce(operator.add, parts)


def client_sq_norms(tree, tp_axes=None):
    """(K,) per-client ||.||^2 over every leaf's trailing dims.

    Computed as a batched dot (``einsum kd,kd->k``), not ``sum(x*x, -1)``
    — XLA-CPU materializes the (K, d) square for the latter (an extra
    full write+read of the plane) but contracts the batched dot in one
    streaming pass. Same formulation as the fused round-stats sweep
    (``repro.kernels.round_stats``), so the host reference's constraint-
    (7) norms stay bit-identical to the fused core's.

    ``tp_axes``: mesh axis name(s) when every leaf's trailing dims are
    this shard's TP-local block under ``jax.shard_map`` — the accumulated
    partial is psum'd over them so every TP shard returns the full-model
    norm. Callers with mixed sharded/replicated leaves split the tree
    first (``repro.kernels.round_stats.round_stats_tp`` does)."""
    out = _accumulate([jnp.einsum("kd,kd->k", _leaf2d(l), _leaf2d(l))
                       for l in jax.tree_util.tree_leaves(tree)])
    return out if not tp_axes else jax.lax.psum(out, tp_axes)


def client_dots(tree, vec_tree, tp_axes=None):
    """(K,) per-client <leaf_k, vec> accumulated across leaves;
    ``tp_axes`` as in ``client_sq_norms`` (vec_tree leaves must be the
    matching TP-local blocks)."""
    out = _accumulate([_leaf2d(l) @ g.reshape(-1)
                       for l, g in zip(jax.tree_util.tree_leaves(tree),
                                       jax.tree_util.tree_leaves(vec_tree))])
    return out if not tp_axes else jax.lax.psum(out, tp_axes)


def global_sq_norm(vec_tree, tp_axes=None):
    """Scalar ||vec||^2 over all leaves of an unstacked params tree;
    ``tp_axes`` as in ``client_sq_norms``."""
    out = _accumulate([jnp.sum(g * g)
                       for g in jax.tree_util.tree_leaves(vec_tree)])
    return out if not tp_axes else jax.lax.psum(out, tp_axes)


def cosine_similarity(deltas, global_dir, use_kernel: bool = False, eps=1e-12):
    """cos(dw_k, g) per client: stacked deltas pytree ((K, ...) leaves — a
    bare (K, D) matrix is the single-leaf case) vs the matching global
    direction pytree ((...) leaves / a (D,) vector)."""
    if use_kernel:
        from repro.kernels.ops import cosine_sim
        return cosine_sim(deltas, global_dir)
    num = client_dots(deltas, global_dir)
    den = jnp.sqrt(jnp.maximum(client_sq_norms(deltas), eps)
                   * jnp.maximum(global_sq_norm(global_dir), eps))
    return num / den


def power_from_beta(beta, rho, theta, p_max):
    """Eq. (25). All (K,) vectors; result clipped to [0, p_max] (cond. 7)."""
    p = p_max * (beta * rho + (1.0 - beta) * theta)
    return jnp.clip(p, 0.0, p_max)


@dataclass(frozen=True)
class P2Problem:
    """Quadratic-ratio data for P2 (all numpy, solver-side)."""
    rho: np.ndarray      # (K,)
    theta: np.ndarray    # (K,)
    p_max: np.ndarray    # (K,)
    b: np.ndarray        # (K,) in {0,1}
    c1: float            # L * eps^2 * K      (term-d scale)
    c0: float            # 2 * L * d * sigma_n^2  (term-e numerator)

    @property
    def K(self) -> int:
        return len(self.rho)

    def power(self, beta: np.ndarray) -> np.ndarray:
        p = self.p_max * (beta * self.rho + (1 - beta) * self.theta)
        return np.clip(p, 0.0, self.p_max)

    def h1(self, beta: np.ndarray) -> float:
        p = self.power(beta) * self.b
        return float(self.c1 * np.sum(p * p) + self.c0)

    def h2(self, beta: np.ndarray) -> float:
        p = self.power(beta) * self.b
        s = np.sum(p)
        return float(s * s)

    def objective(self, beta: np.ndarray) -> float:
        """P2: h1/h2 (minimize). Equivalently maximize h2/h1 (P3 form)."""
        return self.h1(beta) / max(self.h2(beta), 1e-30)

    # ---- quadratic-form coefficients (paper's G, g, g0, Q, q, q0) ----
    def quadratics(self):
        """h1 = b'Gb + g'b + g0 ; h2 = b'Qb + q'b + q0 over beta (unclipped)."""
        pm, th, d = self.p_max, self.theta, (self.rho - self.theta)
        m = self.b.astype(float)
        # p_k = pm_k (th_k + d_k beta_k); active entries only
        A = pm * d * np.sqrt(m)            # sqrt-mask keeps G diagonal PSD
        Bc = pm * th * np.sqrt(m)
        G = self.c1 * np.diag(A * A)
        g = 2 * self.c1 * A * Bc
        g0 = self.c1 * float(Bc @ Bc) + self.c0
        u = pm * d * m
        v = pm * th * m
        Q = np.outer(u, u)
        q = 2 * float(np.sum(v)) * u
        q0 = float(np.sum(v)) ** 2
        return (G, g, g0), (Q, q, q0)


def p2_constants(smooth_l: float, eps_bound: float, k: int, model_dim: int,
                 sigma_n2: float):
    """Theorem-1 constants of P2: c1 = L eps^2 K (term-d scale) and
    c0 = 2 L d sigma_n^2 (term-e numerator). Shared by the numpy problem
    builder and the fused on-device solver."""
    return smooth_l * eps_bound ** 2 * k, 2.0 * smooth_l * model_dim * sigma_n2


def build_p2(rho, theta, p_max, b, *, smooth_l: float, eps_bound: float,
             model_dim: int, sigma_n2: float) -> P2Problem:
    """Assemble P2 from Theorem-1 constants: c1 = L eps^2 K, c0 = 2 L d sigma^2."""
    rho = np.asarray(rho, float)
    c1, c0 = p2_constants(smooth_l, eps_bound, len(rho), model_dim, sigma_n2)
    return P2Problem(
        rho=rho, theta=np.asarray(theta, float),
        p_max=np.asarray(p_max, float), b=np.asarray(b, float),
        c1=c1, c0=c0,
    )
