"""Analog-payload compression primitives for the cohort plane.

The cohort refactor (PR 7) left the payload plane at (m, d) in-flight
rows; these helpers shrink each row to an (m, s) compressed plane with
s << d before it enters the carry, per the sparsification + error
feedback family the AirComp FEEL overview surveys (arxiv 2208.05643):

* support selection — per-row magnitude top-k (``topk_support``) or a
  shared per-round random mask (``randmask_indices``, counter-RNG so
  every shard re-derives the identical support);
* error feedback — the exact f32 complement of a row against its
  transmitted reconstruction (``ef_residual``: residual + scattered
  transmit == original, bit-for-bit), re-sparsified to width s for the
  carry (``sparsify``);
* int8 slot storage — per-row absmax scaling with an unbiased
  stochastic-rounding dither (``quantize_int8_stochastic``), accumulated
  in f32 downstream.

Everything here is a pure jnp shape-polymorphic helper; the kernels that
consume the compressed plane live in ``repro.kernels``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

INT8_MAX = 127.0


def _row_ids(m: int):
    return jnp.arange(m, dtype=jnp.int32)[:, None]


def topk_support(a, s: int):
    """(m, s) int32 indices of the s largest-|.| entries per row."""
    _, idx = jax.lax.top_k(jnp.abs(a), s)
    return idx.astype(jnp.int32)


def randmask_indices(key, d: int, s: int):
    """(s,) int32 shared support: s distinct coordinates of [0, d)."""
    return jax.random.permutation(key, d)[:s].astype(jnp.int32)


def gather_rows(a, idx):
    """(m, s) values of ``a`` at the per-row support ``idx``."""
    return jnp.take_along_axis(a, idx, axis=1)


def scatter_rows(vals, idx, d: int):
    """Decompress: scatter (m, s) values back to (m, d) rows (zeros off
    the support; duplicate indices sum, though supports never hold any)."""
    m = vals.shape[0]
    return jnp.zeros((m, d), vals.dtype).at[_row_ids(m), idx].add(vals)


def ef_residual(comp, idx, v_hat):
    """Exact error-feedback residual: ``comp - scatter_rows(v_hat, idx)``
    computed in place, so ``residual + scatter_rows(v_hat, idx) == comp``
    holds bit-for-bit in f32 (on-support entries cancel to exactly 0.0
    when ``v_hat`` is the untouched gather; off-support entries pass
    through unchanged)."""
    return comp.at[_row_ids(comp.shape[0]), idx].add(-v_hat)


def sparsify(e, s: int):
    """Re-sparsify a dense (m, d) residual to carry width: top-s by |.|.
    Returns ((m, s) values, (m, s) int32 indices)."""
    idx = topk_support(e, s)
    return gather_rows(e, idx), idx


def quantize_int8_stochastic(v, key):
    """Per-row absmax int8 with an unbiased stochastic-rounding dither:
    ``q = floor(v / scale + u)``, u ~ U[0, 1), so E[q * scale] = v
    entrywise (the dither is drawn from the round's counter key — same
    key, same draw). Returns ((m, s) int8, (m,) f32 scale)."""
    v32 = v.astype(jnp.float32)
    amax = jnp.max(jnp.abs(v32), axis=1)
    scale = jnp.maximum(amax / INT8_MAX, jnp.float32(1e-30))
    u = jax.random.uniform(key, v32.shape, jnp.float32)
    q = jnp.clip(jnp.floor(v32 / scale[:, None] + u), -INT8_MAX, INT8_MAX)
    return q.astype(jnp.int8), scale


def dequantize_int8(q, scale):
    """f32 reconstruction of ``quantize_int8_stochastic`` output."""
    return q.astype(jnp.float32) * scale[:, None]
