"""PAOTA aggregation — the paper's round update (eq. 8/9) in two forms:

1. ``paota_aggregate_stacked``: the FL-simulator form. Client models stacked
   as a (K, D) matrix; fused weighted sum + channel noise + normalization
   (optionally via the Pallas ``aircomp_sum`` kernel).

2. ``paota_allreduce``: the datacenter/shard_map form. Each device group on
   the client mesh axis holds ONE client's payload; the AirComp superposition
   becomes a masked weighted ``psum`` over that axis with AWGN injected after
   normalization — the TPU-native realization of the wireless MAC
   (DESIGN.md §3). Used by repro.launch.train's PAOTA round step.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from repro.core.aircomp import VARSIGMA_MIN, aircomp_aggregate


def ravel(params) -> Tuple[jnp.ndarray, callable]:
    return ravel_pytree(params)


def guarded_global_update(global_vec, prev_global, agg, varsigma, *,
                          delta: bool = False,
                          threshold: float = VARSIGMA_MIN):
    """Apply the round update with the zero-uploader guard (masked select).

    When the eq.-8 normalizer sum_k b_k p_k sits at/below the clamp, no
    client transmitted this period: `agg` is pure AWGN divided by the
    ~1e-12 clamp, and assigning it would destroy the global model. The
    guard holds both w_g AND prev_global (the gradient-similarity
    direction w_g^t - w_g^{t-1} must not collapse to zero from a skipped
    period). Pure jnp select — the same code path serves the host
    reference server and the jitted fused round.

    Returns (new_global, new_prev_global)."""
    cand = global_vec + agg if delta else agg
    has_uploaders = varsigma > threshold
    return (jnp.where(has_uploaders, cand, global_vec),
            jnp.where(has_uploaders, global_vec, prev_global))


def paota_aggregate_stacked(stacked_models: jnp.ndarray, powers: jnp.ndarray,
                            mask: jnp.ndarray, key, sigma_n: float,
                            use_kernel: bool = False, axis_name=None):
    """Eq. (8): w_g^{r+1} = (sum_k b_k p_k w_k + n) / sum_k b_k p_k.

    ``axis_name``: when the (K, D) stack is laid over mesh client axis/axes
    inside ``jax.shard_map``, the superposition runs as a psum over that
    axis (``repro.kernels.aircomp_sum.aircomp_sum_psum``) with the single
    shared noise realization drawn from the replicated ``key`` and added
    once, after the collective — the same eq.-6 semantics as the
    single-device reduction."""
    if axis_name is not None:
        from repro.kernels.aircomp_sum import aircomp_sum_psum
        bp = powers * mask
        noise = sigma_n * jax.random.normal(key, stacked_models.shape[1:],
                                            stacked_models.dtype)
        return aircomp_sum_psum(stacked_models, bp, noise, axis_name,
                                varsigma_min=VARSIGMA_MIN)
    return aircomp_aggregate(stacked_models, powers, mask, key, sigma_n,
                             use_kernel=use_kernel)


def paota_allreduce(local_payload, power: jnp.ndarray, ready: jnp.ndarray,
                    axis_name, noise_key, sigma_n: float):
    """Inside shard_map: each participant holds `local_payload` (pytree),
    scalar `power` (p_k) and `ready` (b_k in {0,1}).

    Returns the PAOTA aggregate, identical on every participant — a weighted
    masked all-reduce with post-normalization AWGN. The noise is generated
    from a shared key so every device injects the SAME realization (one
    channel, one noise draw — matches eq. 6 where noise is added once at the
    server, not per client).
    """
    bp = power * ready
    varsigma = jnp.maximum(jax.lax.psum(bp, axis_name), 1e-12)

    def agg(x):
        s = jax.lax.psum(x * bp.astype(x.dtype), axis_name)
        sub = jax.random.fold_in(noise_key, x.ndim + x.size % 9973)
        noise = sigma_n * jax.random.normal(sub, x.shape, x.dtype)
        return (s + noise) / varsigma.astype(x.dtype)

    return jax.tree_util.tree_map(agg, local_payload)


def exact_average(local_payload, weight: jnp.ndarray, axis_name):
    """Ideal Local SGD aggregation (baseline 1): lossless weighted mean."""
    wsum = jax.lax.psum(weight, axis_name)

    def agg(x):
        return jax.lax.psum(x * weight.astype(x.dtype), axis_name) / wsum.astype(x.dtype)

    return jax.tree_util.tree_map(agg, local_payload)
