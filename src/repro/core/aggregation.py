"""PAOTA aggregation — the paper's round update (eq. 8/9) in two forms:

1. ``paota_aggregate_stacked``: the FL-simulator form. Client models stacked
   along a leading K axis — either one raveled (K, D) matrix or an arbitrary
   params pytree of (K, ...) leaves. The weighted superposition + channel
   noise + normalization run per leaf with ONE flat AWGN realization for the
   whole model (drawn once from ``key`` and split across leaves in
   tree_flatten order), so the pytree and raveled forms of the same model
   consume bit-identical noise. The single-(K, D)-leaf case is the exact
   historical op sequence (optionally via the Pallas ``aircomp_sum`` kernel).

2. ``paota_allreduce``: the datacenter/shard_map form. Each device group on
   the client mesh axis holds ONE client's payload; the AirComp superposition
   becomes a masked weighted ``psum`` over that axis with AWGN injected after
   normalization — the TPU-native realization of the wireless MAC
   (DESIGN.md §3). Used by repro.launch.train's PAOTA round step.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree

from repro.core.aircomp import VARSIGMA_MIN, aircomp_aggregate


def ravel(params) -> Tuple[jnp.ndarray, callable]:
    return ravel_pytree(params)


def guarded_global_update(global_vec, prev_global, agg, varsigma, *,
                          delta: bool = False,
                          threshold: float = VARSIGMA_MIN):
    """Apply the round update with the zero-uploader guard (masked select).

    When the eq.-8 normalizer sum_k b_k p_k sits at/below the clamp, no
    client transmitted this period: `agg` is pure AWGN divided by the
    ~1e-12 clamp, and assigning it would destroy the global model. The
    guard holds both w_g AND prev_global (the gradient-similarity
    direction w_g^t - w_g^{t-1} must not collapse to zero from a skipped
    period). Pure jnp select over every leaf of the (pytree) global — the
    same code path serves the host reference server and the jitted fused
    round; a raveled global is the single-leaf case.

    The same select also guards a NON-FINITE aggregate (a deep-fade round
    whose normalizer survives the clamp but whose payload overflowed, a
    bf16 overflow, an unscreened NaN row): any NaN/Inf anywhere in ``agg``
    holds w_g AND prev_global bit-identically — one poisoned period is a
    skipped period, never a destroyed model. The check is a scalar
    reduction over the (replicated, post-collective) aggregate, so the
    sharded round still compiles to ONE cross-client psum.

    Returns (new_global, new_prev_global)."""
    finite = jnp.bool_(True)
    for leaf in jax.tree_util.tree_leaves(agg):
        finite = finite & jnp.all(jnp.isfinite(leaf))
    has_uploaders = (varsigma > threshold) & finite

    def upd(g, a):
        cand = g + a if delta else a
        return jnp.where(has_uploaders, cand, g)

    return (jax.tree_util.tree_map(upd, global_vec, agg),
            jax.tree_util.tree_map(
                lambda g, pg: jnp.where(has_uploaders, g, pg),
                global_vec, prev_global))


def stacked_tree_noise(key, stacked_leaves, sigma_n):
    """ONE eq.-6 AWGN realization for the whole model: a flat float32 draw
    of the total model size, split per leaf in tree_flatten order (leaf i
    gets the next prod(shape[1:]) entries, shaped to its trailing dims).

    Splitting one flat draw — instead of folding a subkey per leaf — makes
    the noise a function of the MODEL, not of how its params happen to be
    split into leaves: the 4-leaf pytree form of an MLP and its raveled
    (K, D) form consume bit-identical realizations (the single-leaf split
    is exactly the historical ``normal(key, (D,))``), which is what the
    pytree-vs-raveled equivalence tests pin."""
    sizes = [int(np.prod(l.shape[1:])) for l in stacked_leaves]
    flat = sigma_n * jax.random.normal(key, (sum(sizes),), jnp.float32)
    out, off = [], 0
    for leaf, size in zip(stacked_leaves, sizes):
        out.append(flat[off:off + size].reshape(leaf.shape[1:]))
        off += size
    return out


def paota_aggregate_stacked(stacked_models, powers: jnp.ndarray,
                            mask: jnp.ndarray, key, sigma_n: float,
                            use_kernel: bool = False, axis_name=None,
                            tp=None):
    """Eq. (8): w_g^{r+1} = (sum_k b_k p_k w_k + n) / sum_k b_k p_k.

    ``stacked_models``: a pytree of client-stacked (K, ...) leaves; the
    raveled federation passes its bare (K, D) matrix (single-leaf pytree)
    and runs the exact historical op sequence. Returns (aggregate pytree /
    (D,) vector, varsigma).

    ``axis_name``: when the K axis is laid over mesh client axis/axes
    inside ``jax.shard_map``, the superposition runs as ONE psum over that
    axis per round — per-leaf local partials are flattened and concatenated
    (``repro.kernels.aircomp_sum.aircomp_sum_tree_psum``), not psum'd leaf
    by leaf — with the single shared noise realization drawn from the
    replicated ``key`` and added once, after the collective: the same
    eq.-6 semantics as the single-device reduction.

    ``tp``: intra-client ``repro.sharding.tp.TPTopology`` when the leaves
    are additionally TP-local model blocks — the single psum then spans
    the client axes AND ``tp.axes`` (superpose + TP-gather in one
    collective), and the AWGN is drawn at the FULL leaf shapes from the
    same replicated key, so the realization is identical across every TP
    layout (the noise-split determinism contract; EXPERIMENTS.md
    §Intra-client TP). Aggregate leaves come back FULL-shape."""
    leaves, treedef = jax.tree_util.tree_flatten(stacked_models)
    single = len(leaves) == 1 and leaves[0].ndim == 2
    bp = powers * mask
    if axis_name is not None:
        from repro.kernels.aircomp_sum import (aircomp_sum_psum,
                                               aircomp_sum_tree_psum,
                                               aircomp_sum_tree_psum_tp)
        if tp is not None:
            from repro.sharding.tp import tp_full_structs
            noise = stacked_tree_noise(key, tp_full_structs(leaves, tp),
                                       sigma_n)
            agg_leaves, varsigma = aircomp_sum_tree_psum_tp(
                leaves, bp, noise, axis_name, tp,
                varsigma_min=VARSIGMA_MIN)
            return (jax.tree_util.tree_unflatten(treedef, agg_leaves),
                    varsigma)
        noise = stacked_tree_noise(key, leaves, sigma_n)
        if single:
            # noise stays f32: the psum entry accumulates f32 and returns
            # an f32 aggregate regardless of payload storage dtype
            agg, varsigma = aircomp_sum_psum(
                leaves[0], bp, noise[0], axis_name,
                varsigma_min=VARSIGMA_MIN)
            return jax.tree_util.tree_unflatten(treedef, [agg]), varsigma
        agg_leaves, varsigma = aircomp_sum_tree_psum(
            leaves, bp, noise, axis_name, varsigma_min=VARSIGMA_MIN)
        return jax.tree_util.tree_unflatten(treedef, agg_leaves), varsigma
    if single and use_kernel:
        return aircomp_aggregate(leaves[0], powers, mask, key, sigma_n,
                                 use_kernel=True)
    varsigma = jnp.maximum(jnp.sum(bp), VARSIGMA_MIN)
    # a STATICALLY zero sigma (noiseless ablation, e.g. the train step's
    # sigma_over_varsigma=0) skips the model-sized AWGN draw entirely —
    # XLA does not fold a float multiply-by-zero away
    noiseless = isinstance(sigma_n, (int, float)) and sigma_n == 0.0
    if noiseless:
        agg = []
        for leaf in leaves:
            l2 = leaf.reshape((leaf.shape[0], -1))
            acc = jnp.einsum("k,kd->d", bp.astype(jnp.float32),
                             l2.astype(jnp.float32))
            agg.append((acc / varsigma).reshape(leaf.shape[1:]))
        return jax.tree_util.tree_unflatten(treedef, agg), varsigma
    # fused superpose-and-normalize per leaf (sweep 2 of the round): b*p
    # masking, superposition, AWGN, and the varsigma division in one pass
    # — compiled Pallas kernel on TPU, f32-accumulating einsum elsewhere
    # (repro.kernels.ops.superpose_normalize). Leaves may be bf16; the
    # aggregate always comes back f32 (the globals stay f32).
    from repro.kernels.ops import superpose_normalize
    noise = stacked_tree_noise(key, leaves, sigma_n)
    agg = []
    for leaf, nz in zip(leaves, noise):
        out, _ = superpose_normalize(leaf.reshape((leaf.shape[0], -1)),
                                     powers, mask, nz.reshape(-1),
                                     vs_min=VARSIGMA_MIN)
        agg.append(out.reshape(leaf.shape[1:]))
    return jax.tree_util.tree_unflatten(treedef, agg), varsigma


def paota_aggregate_compressed(values, idx, powers: jnp.ndarray,
                               mask: jnp.ndarray, key, sigma_n: float,
                               d: int, scale=None, axis_name=None):
    """Eq. (8) over the (m, s) COMPRESSED cohort plane: each slot's stored
    values on its own support superpose directly into d-space (the
    gather-superpose-decompress kernel — decompression IS the
    superposition, no dense (m, d) plane), with the same flat f32 AWGN
    realization the dense path draws (single-leaf ``stacked_tree_noise``
    == ``sigma_n * normal(key, (d,))``) and the same varsigma clamp.
    ``scale`` folds int8 slot dequantization into the contraction;
    varsigma sums the RAW b*p. Raveled single-leaf only — the compressed
    plane has no pytree form.

    Returns ((d,) f32 aggregate, clamped varsigma); with ``axis_name``
    the slot axis crosses shards as ONE flat psum."""
    bp = powers * mask
    noiseless = isinstance(sigma_n, (int, float)) and sigma_n == 0.0
    noise = (jnp.zeros((d,), jnp.float32) if noiseless
             else sigma_n * jax.random.normal(key, (d,), jnp.float32))
    if axis_name is not None:
        from repro.kernels.aircomp_sum import gather_superpose_psum
        return gather_superpose_psum(values, idx, bp, noise, axis_name, d,
                                     scale=scale, varsigma_min=VARSIGMA_MIN)
    from repro.kernels.ops import gather_superpose
    agg, raw = gather_superpose(values, idx, bp, noise, d=d, scale=scale,
                                vs_min=VARSIGMA_MIN)
    return agg, jnp.maximum(raw, VARSIGMA_MIN)


def paota_partial_stacked(stacked_models, powers: jnp.ndarray,
                          mask: jnp.ndarray, axis_name=None) -> jnp.ndarray:
    """Grouped-aggregation half of eq. (8): the superposition PARTIAL of
    this shard's clients — the flattened per-leaf contractions of
    ``paota_aggregate_stacked`` with the varsigma partial appended, one
    (d_total + 1,) f32 vector — without noise or normalization.

    ``axis_name`` optionally reduces over a SUBSET of the client axes
    (the intra-pod psum that fires every period); the remaining reduction,
    the AWGN, and the eq.-8 division happen once at the window sync
    (``paota_finalize_stacked``). Masked clients (b_k = 0) contribute
    exact zeros, so a pod with no uploaders holds a bit-exactly-zero
    partial."""
    from repro.kernels.aircomp_sum import aircomp_partial_tree
    leaves, _ = jax.tree_util.tree_flatten(stacked_models)
    return aircomp_partial_tree(leaves, powers * mask, axis_name=axis_name)


def paota_finalize_stacked(flat: jnp.ndarray, stacked_models, key,
                           sigma_n: float, axis_name=None):
    """Finish a grouped AirComp window from its accumulated flat partial:
    the final psum over ``axis_name`` (the ONE cross-pod collective of the
    window), then the same single flat AWGN realization
    (``stacked_tree_noise`` — identical draw to the flat path's) joins the
    f32 accumulator once before the varsigma clamp + normalization.
    ``stacked_models`` supplies the leaf shapes only.

    Returns (aggregate pytree / (D,) vector, varsigma) — the exact shapes
    ``paota_aggregate_stacked`` returns, so the round update downstream is
    shared."""
    from repro.kernels.aircomp_sum import aircomp_finalize_tree
    leaves, treedef = jax.tree_util.tree_flatten(stacked_models)
    noise = stacked_tree_noise(key, leaves, sigma_n)
    agg_leaves, varsigma = aircomp_finalize_tree(
        flat, leaves, noise, axis_name=axis_name, varsigma_min=VARSIGMA_MIN)
    return jax.tree_util.tree_unflatten(treedef, agg_leaves), varsigma


def paota_allreduce(local_payload, power: jnp.ndarray, ready: jnp.ndarray,
                    axis_name, noise_key, sigma_n: float):
    """Inside shard_map: each participant holds `local_payload` (pytree),
    scalar `power` (p_k) and `ready` (b_k in {0,1}).

    Returns the PAOTA aggregate, identical on every participant — a weighted
    masked all-reduce with post-normalization AWGN. The noise is generated
    from a shared key so every device injects the SAME realization (one
    channel, one noise draw — matches eq. 6 where noise is added once at the
    server, not per client).
    """
    bp = power * ready
    varsigma = jnp.maximum(jax.lax.psum(bp, axis_name), 1e-12)

    def agg(x):
        s = jax.lax.psum(x * bp.astype(x.dtype), axis_name)
        sub = jax.random.fold_in(noise_key, x.ndim + x.size % 9973)
        noise = sigma_n * jax.random.normal(sub, x.shape, x.dtype)
        return (s + noise) / varsigma.astype(x.dtype)

    return jax.tree_util.tree_map(agg, local_payload)


def exact_average(local_payload, weight: jnp.ndarray, axis_name):
    """Ideal Local SGD aggregation (baseline 1): lossless weighted mean."""
    wsum = jax.lax.psum(weight, axis_name)

    def agg(x):
        return jax.lax.psum(x * weight.astype(x.dtype), axis_name) / wsum.astype(x.dtype)

    return jax.tree_util.tree_map(agg, local_payload)
