"""Exact solver for our P2 instance — beyond-paper optimization.

Observation (DESIGN.md §3): with the paper's own modeling choices the P2
numerator matrix is *diagonal* (G = c1 * diag of squared affine coeffs) and
the denominator matrix is *rank-one* (Q = u u'). Writing t_k = b_k p_k(beta_k)
(each an interval [tlo_k, thi_k]) the ratio becomes

    f(t) = (c1 * sum_k t_k^2 + c0) / (sum_k t_k)^2 .

KKT for a box-constrained minimum: every interior coordinate satisfies
t_k = (c1 sum t^2 + c0) / (c1 sum t) — the SAME scalar tau for all interior
coordinates. So the minimizer has the water-filling form

    t_k* = clip(tau, tlo_k, thi_k)

and a 1-D search over tau finds the global optimum. This replaces the
Dinkelbach + MIP machinery with an O(K log(1/eps)) exact solve; the tests
validate it against Dinkelbach(MILP) and exhaustive enumeration.
"""
from __future__ import annotations

import numpy as np

from repro.core.dinkelbach import SolveResult
from repro.core.power_control import P2Problem


def _t_bounds(prob: P2Problem):
    """Interval of t_k = b_k * p_k(beta_k) as beta_k sweeps [0,1]."""
    p0 = np.clip(prob.p_max * prob.theta, 0, prob.p_max)   # beta=0
    p1 = np.clip(prob.p_max * prob.rho, 0, prob.p_max)     # beta=1
    lo = np.minimum(p0, p1) * prob.b
    hi = np.maximum(p0, p1) * prob.b
    return lo, hi


def _ratio(t, c1, c0):
    s = np.sum(t)
    if s <= 1e-30:
        return np.inf
    return (c1 * np.sum(t * t) + c0) / (s * s)


def solve_waterfill(prob: P2Problem, grid: int = 4096,
                    refine: int = 60) -> SolveResult:
    lo, hi = _t_bounds(prob)
    active = prob.b > 0
    if not np.any(active):
        return SolveResult(beta=np.zeros(prob.K), objective=np.inf,
                           lam=0.0, iterations=0, inner="waterfill")
    tau_lo, tau_hi = float(np.min(lo[active])), float(np.max(hi[active]))
    taus = np.linspace(tau_lo, tau_hi, grid)
    ts = np.clip(taus[:, None], lo[None, :], hi[None, :]) * prob.b[None, :]
    vals = (prob.c1 * np.sum(ts * ts, 1) + prob.c0) / np.maximum(
        np.sum(ts, 1), 1e-30) ** 2
    j = int(np.argmin(vals))
    a, bnd = taus[max(j - 1, 0)], taus[min(j + 1, grid - 1)]
    # golden-section refine
    gr = (np.sqrt(5.0) - 1) / 2
    for _ in range(refine):
        m1 = bnd - gr * (bnd - a)
        m2 = a + gr * (bnd - a)
        f1 = _ratio(np.clip(m1, lo, hi) * prob.b, prob.c1, prob.c0)
        f2 = _ratio(np.clip(m2, lo, hi) * prob.b, prob.c1, prob.c0)
        if f1 < f2:
            bnd = m2
        else:
            a = m1
    tau = (a + bnd) / 2
    t = np.clip(tau, lo, hi) * prob.b
    # recover beta from t = pm (theta + (rho-theta) beta)
    d = prob.p_max * (prob.rho - prob.theta)
    base = prob.p_max * prob.theta
    beta = np.where(np.abs(d) > 1e-12, (t - base) / np.where(
        np.abs(d) > 1e-12, d, 1.0), 0.5)
    beta = np.clip(beta, 0.0, 1.0)
    obj = prob.objective(beta)
    return SolveResult(beta=beta, objective=obj,
                       lam=1.0 / max(obj, 1e-30), iterations=1,
                       inner="waterfill")
