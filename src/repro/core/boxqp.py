"""Exact solver for our P2 instance — beyond-paper optimization.

Observation (DESIGN.md §3): with the paper's own modeling choices the P2
numerator matrix is *diagonal* (G = c1 * diag of squared affine coeffs) and
the denominator matrix is *rank-one* (Q = u u'). Writing t_k = b_k p_k(beta_k)
(each an interval [tlo_k, thi_k]) the ratio becomes

    f(t) = (c1 * sum_k t_k^2 + c0) / (sum_k t_k)^2 .

KKT for a box-constrained minimum: every interior coordinate satisfies
t_k = (c1 sum t^2 + c0) / (c1 sum t) — the SAME scalar tau for all interior
coordinates. So the minimizer has the water-filling form

    t_k* = clip(tau, tlo_k, thi_k)

and a 1-D search over tau finds the global optimum. This replaces the
Dinkelbach + MIP machinery with an O(K log(1/eps)) exact solve; the tests
validate it against Dinkelbach(MILP) and exhaustive enumeration.

``waterfill_beta_jnp`` is the same algorithm as a pure, jit-traceable jnp
function (fixed grid scan + fixed-iteration golden-section refine, no data-
dependent control flow) — the P2 step of the fused on-device PAOTA round
(``repro.fl.fused``). ``solve_waterfill_jnp`` wraps it in the SolveResult
interface so the host-path server can run the bit-identical solver
(``PAOTAConfig.solver = "waterfill_jnp"``) for fused-vs-host equivalence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dinkelbach import SolveResult
from repro.core.power_control import P2Problem


def _t_bounds(prob: P2Problem):
    """Interval of t_k = b_k * p_k(beta_k) as beta_k sweeps [0,1]."""
    p0 = np.clip(prob.p_max * prob.theta, 0, prob.p_max)   # beta=0
    p1 = np.clip(prob.p_max * prob.rho, 0, prob.p_max)     # beta=1
    lo = np.minimum(p0, p1) * prob.b
    hi = np.maximum(p0, p1) * prob.b
    return lo, hi


class _PrefixEvaluator:
    """O(log K) per-tau evaluation of S1(tau) = sum_k clip(tau, lo, hi)
    and S2(tau) = sum of squares, over the ACTIVE clients only.

    The dense grid evaluation materializes a (grid, K) matrix — 320 MB of
    float64 temporaries per solve at K = 10^4 — which was the numpy host
    path's scale ceiling. Sorting lo/hi once and prefix-summing turns every
    tau into three searchsorted lookups:

        S1(tau) = sum_{hi_k < tau} hi_k  +  sum_{lo_k > tau} lo_k
                  + tau * #{lo_k <= tau <= hi_k}

    (ties land on t_k = tau = bound, so the boundary side is value-exact).
    Same math as the dense path up to float summation order.
    """

    def __init__(self, lo: np.ndarray, hi: np.ndarray):
        self.lo_s = np.sort(lo)
        self.hi_s = np.sort(hi)
        self.n = len(lo)
        self.cum_lo = np.concatenate([[0.0], np.cumsum(self.lo_s)])
        self.cum_lo2 = np.concatenate([[0.0], np.cumsum(self.lo_s ** 2)])
        self.cum_hi = np.concatenate([[0.0], np.cumsum(self.hi_s)])
        self.cum_hi2 = np.concatenate([[0.0], np.cumsum(self.hi_s ** 2)])

    def sums(self, taus):
        taus = np.asarray(taus, float)
        i_hi = np.searchsorted(self.hi_s, taus, side="left")   # hi_k < tau
        i_lo = np.searchsorted(self.lo_s, taus, side="right")  # lo_k <= tau
        n_mid = i_lo - i_hi                                    # interior
        s1 = (self.cum_hi[i_hi] + (self.cum_lo[-1] - self.cum_lo[i_lo])
              + n_mid * taus)
        s2 = (self.cum_hi2[i_hi] + (self.cum_lo2[-1] - self.cum_lo2[i_lo])
              + n_mid * taus * taus)
        return s1, s2

    def objective(self, taus, c1: float, c0: float):
        s1, s2 = self.sums(taus)
        return (c1 * s2 + c0) / np.maximum(s1, 1e-30) ** 2


# dense (grid, K) evaluation below this K; prefix-sum path above it. The
# two differ only in float summation order; the threshold keeps every
# historical small-K trajectory bit-identical.
PREFIX_K_THRESHOLD = 4096

# The P2 objective is often FLAT near its optimum (many grid cells within
# float noise of the minimum), so a bare argmin's winning index depends on
# the reduction order of the evaluator — dense vs prefix, host vs psum,
# dense vs cohort-gathered all disagreed by a cell and refined to taus a
# grid-step apart. Every solver therefore picks the LOWEST-index cell
# within this relative band of the minimum (vals > 0 always: c0 = sigma^2
# d > 0), making the bracket — and hence beta — independent of summation
# order.
WATERFILL_TIE_RTOL = 32 * float(np.finfo(np.float32).eps)


def solve_waterfill(prob: P2Problem, grid: int = 4096,
                    refine: int = 60, method: str = "auto") -> SolveResult:
    """Exact water-filling P2 solve. ``method``: "dense" evaluates the
    (grid, K) matrix directly (historical path), "prefix" uses the
    sorted-prefix-sum evaluator (O((K + grid) log K) time, O(K + grid)
    memory — the K >= 10^4 host path), "auto" picks by K."""
    lo, hi = _t_bounds(prob)
    active = prob.b > 0
    if not np.any(active):
        return SolveResult(beta=np.zeros(prob.K), objective=np.inf,
                           lam=0.0, iterations=0, inner="waterfill")
    if method == "auto":
        method = "prefix" if prob.K >= PREFIX_K_THRESHOLD else "dense"
    tau_lo, tau_hi = float(np.min(lo[active])), float(np.max(hi[active]))
    taus = np.linspace(tau_lo, tau_hi, grid)
    if method == "prefix":
        ev = _PrefixEvaluator(lo[active], hi[active])

        def objective(ts_arr):
            return ev.objective(ts_arr, prob.c1, prob.c0)
    else:
        def objective(ts_arr):
            ts = np.clip(ts_arr[:, None], lo[None, :], hi[None, :]) \
                * prob.b[None, :]
            return (prob.c1 * np.sum(ts * ts, 1) + prob.c0) / np.maximum(
                np.sum(ts, 1), 1e-30) ** 2

    # grid scan + golden-section refine, one loop for both evaluators
    vals = objective(taus)
    vmin = float(np.min(vals))
    j = int(np.argmax(vals <= vmin * (1.0 + WATERFILL_TIE_RTOL)))
    a, bnd = taus[max(j - 1, 0)], taus[min(j + 1, grid - 1)]
    gr = (np.sqrt(5.0) - 1) / 2
    for _ in range(refine):
        m1 = bnd - gr * (bnd - a)
        m2 = a + gr * (bnd - a)
        f1, f2 = objective(np.array([m1, m2]))
        if f1 < f2:
            bnd = m2
        else:
            a = m1
    tau = (a + bnd) / 2
    t = np.clip(tau, lo, hi) * prob.b
    # recover beta from t = pm (theta + (rho-theta) beta)
    d = prob.p_max * (prob.rho - prob.theta)
    base = prob.p_max * prob.theta
    beta = np.where(np.abs(d) > 1e-12, (t - base) / np.where(
        np.abs(d) > 1e-12, d, 1.0), 0.5)
    beta = np.clip(beta, 0.0, 1.0)
    obj = prob.objective(beta)
    return SolveResult(beta=beta, objective=obj,
                       lam=1.0 / max(obj, 1e-30), iterations=1,
                       inner="waterfill")


# ---------------------------------------------------------------------------
# jit-traceable form (fused on-device round)
# ---------------------------------------------------------------------------

def waterfill_beta_jnp(rho, theta, p_max, b, c1: float, c0: float,
                       grid: int = 4096, refine: int = 60, axis_name=None):
    """Pure-jnp water-filling solve of P2: returns (beta, objective).

    Same math as ``solve_waterfill`` with static shapes only: a `grid`-point
    scan over tau followed by `refine` golden-section steps via fori_loop.
    With no active client (b all zero) every candidate t is 0 and the
    returned beta is arbitrary — the caller's zero-uploader guard makes the
    round a no-op before beta can matter.

    ``axis_name``: mesh client axis name(s) when the (K,) inputs are this
    shard's rows under ``jax.shard_map``. The per-tau sums over K and the
    tau bracket become psum/pmin/pmax collectives; taus, the bracket, and
    the objective stay replicated, so every shard refines the SAME tau and
    returns its local slice of the same global beta. ``axis_name=None`` is
    the historical single-device op sequence, unchanged."""
    rho = jnp.asarray(rho)
    theta = jnp.asarray(theta)
    p_max = jnp.asarray(p_max)
    b = jnp.asarray(b)

    if axis_name is None:
        def ksum(v, axis=None):
            return jnp.sum(v, axis=axis)
        kmin, kmax, kany = jnp.min, jnp.max, jnp.any
    else:
        def ksum(v, axis=None):
            return jax.lax.psum(jnp.sum(v, axis=axis), axis_name)

        def kmin(v):
            return jax.lax.pmin(jnp.min(v), axis_name)

        def kmax(v):
            return jax.lax.pmax(jnp.max(v), axis_name)

        def kany(v):
            return ksum(v.astype(jnp.int32)) > 0

    p0 = jnp.clip(p_max * theta, 0.0, p_max)      # beta=0 endpoint
    p1 = jnp.clip(p_max * rho, 0.0, p_max)        # beta=1 endpoint
    lo = jnp.minimum(p0, p1) * b
    hi = jnp.maximum(p0, p1) * b
    active = b > 0
    any_active = kany(active)
    tau_lo = jnp.where(any_active,
                       kmin(jnp.where(active, lo, jnp.inf)), 0.0)
    tau_hi = jnp.where(any_active,
                       kmax(jnp.where(active, hi, -jnp.inf)), 1.0)

    def ratio(t):
        s = ksum(t)
        return (c1 * ksum(t * t) + c0) / jnp.maximum(s * s, 1e-30)

    taus = tau_lo + (tau_hi - tau_lo) * jnp.linspace(0.0, 1.0, grid)
    ts = jnp.clip(taus[:, None], lo[None, :], hi[None, :]) * b[None, :]
    s = ksum(ts, axis=1)
    vals = (c1 * ksum(ts * ts, axis=1) + c0) / jnp.maximum(s * s, 1e-30)
    vmin = jnp.min(vals)
    j = jnp.argmax(vals <= vmin * (1.0 + WATERFILL_TIE_RTOL))
    bracket = (taus[jnp.maximum(j - 1, 0)], taus[jnp.minimum(j + 1, grid - 1)])

    gr = (np.sqrt(5.0) - 1.0) / 2.0

    def refine_step(_, ab):
        a, bnd = ab
        m1 = bnd - gr * (bnd - a)
        m2 = a + gr * (bnd - a)
        f1 = ratio(jnp.clip(m1, lo, hi) * b)
        f2 = ratio(jnp.clip(m2, lo, hi) * b)
        return jnp.where(f1 < f2, a, m1), jnp.where(f1 < f2, m2, bnd)

    a, bnd = jax.lax.fori_loop(0, refine, refine_step, bracket)
    tau = (a + bnd) / 2.0
    t = jnp.clip(tau, lo, hi) * b
    # recover beta from t = pm (theta + (rho - theta) beta)
    dcoef = p_max * (rho - theta)
    interior = jnp.abs(dcoef) > 1e-12
    beta = jnp.where(interior,
                     (t - p_max * theta) / jnp.where(interior, dcoef, 1.0),
                     0.5)
    beta = jnp.clip(beta, 0.0, 1.0)
    p = jnp.clip(p_max * (beta * rho + (1.0 - beta) * theta), 0.0, p_max) * b
    return beta, ratio(p)


# host-path cache: the eager form re-dispatches ~hundreds of primitives
# (and re-lowers several) per round, which dominated the host reference's
# per-round cost next to the np.asarray transfers; c1/c0 are static per
# federation so each server instance compiles exactly one program
_waterfill_jit = jax.jit(waterfill_beta_jnp,
                         static_argnames=("c1", "c0", "grid", "refine",
                                          "axis_name"))


def solve_waterfill_jnp(prob: P2Problem) -> SolveResult:
    """SolveResult wrapper over ``waterfill_beta_jnp`` — the host-path entry
    (solver="waterfill_jnp") running the exact solver code the fused round
    jits (here under a cached jit), so host and fused trajectories agree to
    float32 reduction order."""
    beta, obj = _waterfill_jit(
        jnp.asarray(prob.rho, jnp.float32), jnp.asarray(prob.theta, jnp.float32),
        jnp.asarray(prob.p_max, jnp.float32), jnp.asarray(prob.b, jnp.float32),
        c1=float(prob.c1), c0=float(prob.c0))
    obj = float(obj)
    return SolveResult(beta=np.asarray(beta, float), objective=obj,
                       lam=1.0 / max(obj, 1e-30), iterations=1,
                       inner="waterfill_jnp")
