"""Exact solver for our P2 instance — beyond-paper optimization.

Observation (DESIGN.md §3): with the paper's own modeling choices the P2
numerator matrix is *diagonal* (G = c1 * diag of squared affine coeffs) and
the denominator matrix is *rank-one* (Q = u u'). Writing t_k = b_k p_k(beta_k)
(each an interval [tlo_k, thi_k]) the ratio becomes

    f(t) = (c1 * sum_k t_k^2 + c0) / (sum_k t_k)^2 .

KKT for a box-constrained minimum: every interior coordinate satisfies
t_k = (c1 sum t^2 + c0) / (c1 sum t) — the SAME scalar tau for all interior
coordinates. So the minimizer has the water-filling form

    t_k* = clip(tau, tlo_k, thi_k)

and a 1-D search over tau finds the global optimum. This replaces the
Dinkelbach + MIP machinery with an O(K log(1/eps)) exact solve; the tests
validate it against Dinkelbach(MILP) and exhaustive enumeration.

``waterfill_beta_jnp`` is the same algorithm as a pure, jit-traceable jnp
function (fixed grid scan + fixed-iteration golden-section refine, no data-
dependent control flow) — the P2 step of the fused on-device PAOTA round
(``repro.fl.fused``). ``solve_waterfill_jnp`` wraps it in the SolveResult
interface so the host-path server can run the bit-identical solver
(``PAOTAConfig.solver = "waterfill_jnp"``) for fused-vs-host equivalence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dinkelbach import SolveResult
from repro.core.power_control import P2Problem


def _t_bounds(prob: P2Problem):
    """Interval of t_k = b_k * p_k(beta_k) as beta_k sweeps [0,1]."""
    p0 = np.clip(prob.p_max * prob.theta, 0, prob.p_max)   # beta=0
    p1 = np.clip(prob.p_max * prob.rho, 0, prob.p_max)     # beta=1
    lo = np.minimum(p0, p1) * prob.b
    hi = np.maximum(p0, p1) * prob.b
    return lo, hi


def _ratio(t, c1, c0):
    s = np.sum(t)
    if s <= 1e-30:
        return np.inf
    return (c1 * np.sum(t * t) + c0) / (s * s)


def solve_waterfill(prob: P2Problem, grid: int = 4096,
                    refine: int = 60) -> SolveResult:
    lo, hi = _t_bounds(prob)
    active = prob.b > 0
    if not np.any(active):
        return SolveResult(beta=np.zeros(prob.K), objective=np.inf,
                           lam=0.0, iterations=0, inner="waterfill")
    tau_lo, tau_hi = float(np.min(lo[active])), float(np.max(hi[active]))
    taus = np.linspace(tau_lo, tau_hi, grid)
    ts = np.clip(taus[:, None], lo[None, :], hi[None, :]) * prob.b[None, :]
    vals = (prob.c1 * np.sum(ts * ts, 1) + prob.c0) / np.maximum(
        np.sum(ts, 1), 1e-30) ** 2
    j = int(np.argmin(vals))
    a, bnd = taus[max(j - 1, 0)], taus[min(j + 1, grid - 1)]
    # golden-section refine
    gr = (np.sqrt(5.0) - 1) / 2
    for _ in range(refine):
        m1 = bnd - gr * (bnd - a)
        m2 = a + gr * (bnd - a)
        f1 = _ratio(np.clip(m1, lo, hi) * prob.b, prob.c1, prob.c0)
        f2 = _ratio(np.clip(m2, lo, hi) * prob.b, prob.c1, prob.c0)
        if f1 < f2:
            bnd = m2
        else:
            a = m1
    tau = (a + bnd) / 2
    t = np.clip(tau, lo, hi) * prob.b
    # recover beta from t = pm (theta + (rho-theta) beta)
    d = prob.p_max * (prob.rho - prob.theta)
    base = prob.p_max * prob.theta
    beta = np.where(np.abs(d) > 1e-12, (t - base) / np.where(
        np.abs(d) > 1e-12, d, 1.0), 0.5)
    beta = np.clip(beta, 0.0, 1.0)
    obj = prob.objective(beta)
    return SolveResult(beta=beta, objective=obj,
                       lam=1.0 / max(obj, 1e-30), iterations=1,
                       inner="waterfill")


# ---------------------------------------------------------------------------
# jit-traceable form (fused on-device round)
# ---------------------------------------------------------------------------

def waterfill_beta_jnp(rho, theta, p_max, b, c1: float, c0: float,
                       grid: int = 4096, refine: int = 60, axis_name=None):
    """Pure-jnp water-filling solve of P2: returns (beta, objective).

    Same math as ``solve_waterfill`` with static shapes only: a `grid`-point
    scan over tau followed by `refine` golden-section steps via fori_loop.
    With no active client (b all zero) every candidate t is 0 and the
    returned beta is arbitrary — the caller's zero-uploader guard makes the
    round a no-op before beta can matter.

    ``axis_name``: mesh client axis name(s) when the (K,) inputs are this
    shard's rows under ``jax.shard_map``. The per-tau sums over K and the
    tau bracket become psum/pmin/pmax collectives; taus, the bracket, and
    the objective stay replicated, so every shard refines the SAME tau and
    returns its local slice of the same global beta. ``axis_name=None`` is
    the historical single-device op sequence, unchanged."""
    rho = jnp.asarray(rho)
    theta = jnp.asarray(theta)
    p_max = jnp.asarray(p_max)
    b = jnp.asarray(b)

    if axis_name is None:
        def ksum(v, axis=None):
            return jnp.sum(v, axis=axis)
        kmin, kmax, kany = jnp.min, jnp.max, jnp.any
    else:
        def ksum(v, axis=None):
            return jax.lax.psum(jnp.sum(v, axis=axis), axis_name)

        def kmin(v):
            return jax.lax.pmin(jnp.min(v), axis_name)

        def kmax(v):
            return jax.lax.pmax(jnp.max(v), axis_name)

        def kany(v):
            return ksum(v.astype(jnp.int32)) > 0

    p0 = jnp.clip(p_max * theta, 0.0, p_max)      # beta=0 endpoint
    p1 = jnp.clip(p_max * rho, 0.0, p_max)        # beta=1 endpoint
    lo = jnp.minimum(p0, p1) * b
    hi = jnp.maximum(p0, p1) * b
    active = b > 0
    any_active = kany(active)
    tau_lo = jnp.where(any_active,
                       kmin(jnp.where(active, lo, jnp.inf)), 0.0)
    tau_hi = jnp.where(any_active,
                       kmax(jnp.where(active, hi, -jnp.inf)), 1.0)

    def ratio(t):
        s = ksum(t)
        return (c1 * ksum(t * t) + c0) / jnp.maximum(s * s, 1e-30)

    taus = tau_lo + (tau_hi - tau_lo) * jnp.linspace(0.0, 1.0, grid)
    ts = jnp.clip(taus[:, None], lo[None, :], hi[None, :]) * b[None, :]
    s = ksum(ts, axis=1)
    vals = (c1 * ksum(ts * ts, axis=1) + c0) / jnp.maximum(s * s, 1e-30)
    j = jnp.argmin(vals)
    bracket = (taus[jnp.maximum(j - 1, 0)], taus[jnp.minimum(j + 1, grid - 1)])

    gr = (np.sqrt(5.0) - 1.0) / 2.0

    def refine_step(_, ab):
        a, bnd = ab
        m1 = bnd - gr * (bnd - a)
        m2 = a + gr * (bnd - a)
        f1 = ratio(jnp.clip(m1, lo, hi) * b)
        f2 = ratio(jnp.clip(m2, lo, hi) * b)
        return jnp.where(f1 < f2, a, m1), jnp.where(f1 < f2, m2, bnd)

    a, bnd = jax.lax.fori_loop(0, refine, refine_step, bracket)
    tau = (a + bnd) / 2.0
    t = jnp.clip(tau, lo, hi) * b
    # recover beta from t = pm (theta + (rho - theta) beta)
    dcoef = p_max * (rho - theta)
    interior = jnp.abs(dcoef) > 1e-12
    beta = jnp.where(interior,
                     (t - p_max * theta) / jnp.where(interior, dcoef, 1.0),
                     0.5)
    beta = jnp.clip(beta, 0.0, 1.0)
    p = jnp.clip(p_max * (beta * rho + (1.0 - beta) * theta), 0.0, p_max) * b
    return beta, ratio(p)


def solve_waterfill_jnp(prob: P2Problem) -> SolveResult:
    """SolveResult wrapper over ``waterfill_beta_jnp`` — the host-path entry
    (solver="waterfill_jnp") running the exact solver code the fused round
    jits, so host and fused trajectories agree to float32 reduction order."""
    beta, obj = waterfill_beta_jnp(
        jnp.asarray(prob.rho, jnp.float32), jnp.asarray(prob.theta, jnp.float32),
        jnp.asarray(prob.p_max, jnp.float32), jnp.asarray(prob.b, jnp.float32),
        float(prob.c1), float(prob.c0))
    obj = float(obj)
    return SolveResult(beta=np.asarray(beta, float), objective=obj,
                       lam=1.0 / max(obj, 1e-30), iterations=1,
                       inner="waterfill_jnp")
