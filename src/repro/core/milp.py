"""Paper-faithful inner solver for P3: piecewise-linear approximation of the
non-concave quadratic -> 0-1 linear MIP (paper eqs. 28-39), solved by a
pure-python branch & bound over scipy HiGHS LP relaxations (replacing the
paper's IBM CPLEX — recorded in DESIGN.md §3).

Formulation. P3 is max_beta beta'A beta + c'beta + const over [0,1]^K.
Eigendecompose A = V N V' (paper's M_2' S M_2 = N step), z = V'beta, so the
quadratic separates: sum_i n_i z_i^2 + (V c)' z. Each z_i^2 is approximated
on [zlo_i, zhi_i] with `segments` chords via the lambda-method (paper's
gamma_ij, eqs. 34-37):

    z_i = sum_j gamma_ij zbar_ij,  zsq_i = sum_j gamma_ij zbar_ij^2,
    sum_j gamma_ij = 1, gamma >= 0.

For eigendirections with n_i < 0 (concave contribution to a maximization)
adjacency is automatic. For n_i > 0 (convex), binaries y_ij force gamma
support onto one segment (paper's c_ij constraints, eq. 38) — these are the
0-1 variables of problem (39).
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np
from scipy.optimize import linprog


@dataclass(order=True)
class _Node:
    bound: float
    fixed: dict = field(compare=False)


def _build_lp(A_eig_vals, V, c, k, segments, zlo, zhi):
    """Variable layout: for each i in [k]: gamma_i1..gamma_i,S+1, then for
    convex dims: y_i1..y_iS. Returns coefficient builders."""
    s = segments
    n_gamma = k * (s + 1)
    convex = [i for i in range(k) if A_eig_vals[i] > 1e-12]
    y_offset = {i: n_gamma + j * s for j, i in enumerate(convex)}
    n_var = n_gamma + len(convex) * s
    zbar = np.stack([np.linspace(zlo[i], zhi[i], s + 1) for i in range(k)])
    return n_gamma, convex, y_offset, n_var, zbar


def solve_p3_milp(A: np.ndarray, c: np.ndarray, const: float,
                  segments: int = 8, max_nodes: int = 2000) -> np.ndarray:
    """Maximize beta'A beta + c'beta + const over [0,1]^K via PWL 0-1 MIP."""
    k = A.shape[0]
    vals, V = np.linalg.eigh((A + A.T) / 2.0)      # A = V diag(vals) V'
    cz = V.T @ c                                    # linear term in z
    # z bounds: z_i = sum_j V_ji beta_j, beta in [0,1]
    zlo = np.minimum(V, 0).sum(axis=0)
    zhi = np.maximum(V, 0).sum(axis=0)

    n_gamma, convex, y_offset, n_var, zbar = _build_lp(
        vals, V, c, k, segments, zlo, zhi)
    s = segments

    def gidx(i, j):
        return i * (s + 1) + j

    # objective (maximize -> linprog minimizes negative)
    obj = np.zeros(n_var)
    for i in range(k):
        for j in range(s + 1):
            obj[gidx(i, j)] = vals[i] * zbar[i, j] ** 2 + cz[i] * zbar[i, j]

    # equality: sum_j gamma_ij = 1 per i; plus sum_j y_ij = 1 per convex i
    a_eq_rows, b_eq = [], []
    for i in range(k):
        row = np.zeros(n_var)
        row[gidx(i, 0):gidx(i, s + 1)] = 1.0
        a_eq_rows.append(row)
        b_eq.append(1.0)
    for i in convex:
        row = np.zeros(n_var)
        row[y_offset[i]:y_offset[i] + s] = 1.0
        a_eq_rows.append(row)
        b_eq.append(1.0)

    # inequality: box on beta = V z -> 0 <= sum_i V_ji z_i <= 1 for each j.
    a_ub_rows, b_ub = [], []
    for jrow in range(k):
        row = np.zeros(n_var)
        for i in range(k):
            for j in range(s + 1):
                row[gidx(i, j)] += V[jrow, i] * zbar[i, j]
        a_ub_rows.append(row.copy());  b_ub.append(1.0)     # beta_j <= 1
        a_ub_rows.append(-row);        b_ub.append(0.0)     # beta_j >= 0
    # adjacency (paper eq. 38): gamma_i1<=y_i1; gamma_ij<=y_{ij-1}+y_ij; ...
    for i in convex:
        for j in range(s + 1):
            row = np.zeros(n_var)
            row[gidx(i, j)] = 1.0
            if j > 0:
                row[y_offset[i] + j - 1] = -1.0
            if j < s:
                row[y_offset[i] + j] = -1.0
            a_ub_rows.append(row)
            b_ub.append(0.0)

    a_eq = np.array(a_eq_rows); b_eq = np.array(b_eq)
    a_ub = np.array(a_ub_rows); b_ub = np.array(b_ub)
    binaries = [y_offset[i] + j for i in convex for j in range(s)]

    def lp_relax(fixed: dict) -> Tuple[Optional[np.ndarray], float]:
        bounds = [(0.0, 1.0)] * n_var
        for idx, v in fixed.items():
            bounds[idx] = (v, v)
        res = linprog(-obj, A_ub=a_ub, b_ub=b_ub, A_eq=a_eq, b_eq=b_eq,
                      bounds=bounds, method="highs")
        if not res.success:
            return None, -np.inf
        return res.x, -res.fun

    def extract_beta(x) -> np.ndarray:
        z = np.array([sum(x[gidx(i, j)] * zbar[i, j] for j in range(s + 1))
                      for i in range(k)])
        return np.clip(V @ z, 0.0, 1.0)

    def true_obj(beta) -> float:
        return float(beta @ A @ beta + c @ beta + const)

    # branch & bound (best-first on LP bound)
    x0, bound0 = lp_relax({})
    if x0 is None:
        return np.full(k, 0.5)
    best_beta = extract_beta(x0)
    best_val = true_obj(best_beta)
    heap: List[_Node] = [_Node(-bound0, {})]
    nodes = 0
    while heap and nodes < max_nodes:
        node = heapq.heappop(heap)
        nodes += 1
        x, bound = lp_relax(node.fixed)
        if x is None or bound + const <= best_val + 1e-12:
            continue
        frac = [(abs(x[b] - round(x[b])), b) for b in binaries
                if b not in node.fixed]
        frac = [(f, b) for f, b in frac if f > 1e-6]
        cand = extract_beta(x)
        cv = true_obj(cand)
        if cv > best_val:
            best_val, best_beta = cv, cand
        if not frac:
            continue
        _, bvar = max(frac)
        for v in (0.0, 1.0):
            fixed = dict(node.fixed); fixed[bvar] = v
            heapq.heappush(heap, _Node(-bound, fixed))
    return best_beta
