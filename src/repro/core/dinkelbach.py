"""Dinkelbach's method for the fractional program P2 (Algorithm 2).

P2: min_beta h1(beta)/h2(beta) over the box [0,1]^K — equivalently
max h2/h1. Dinkelbach's parametrization solves a sequence of subproblems

    P3: max_beta  F(beta; lam) = h2(beta) - lam * h1(beta)

updating lam <- h2(beta*)/h1(beta*) until F(beta*; lam) < tol (the paper's
stopping rule, Alg. 2 line 6).

Inner solvers for the non-concave quadratic P3:
  * "milp"      — paper-faithful piecewise-linear 0-1 MIP (repro.core.milp),
                  branch & bound replaces CPLEX. Exact up to PWL resolution.
  * "pgd"       — projected gradient ascent, multi-restart (scalable, K=100+).
  * "exhaustive"— corner + grid enumeration (tiny K; test oracle).

`solve_p2` additionally exposes method "waterfill" (repro.core.boxqp) which
solves our diagonal+rank-one instance of P2 *exactly* via its KKT system —
a beyond-paper observation recorded in DESIGN.md §3.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np

from repro.core.power_control import P2Problem


@dataclass
class SolveResult:
    beta: np.ndarray
    objective: float          # h1/h2 (the minimized ratio)
    lam: float                # final Dinkelbach parameter = h2/h1
    iterations: int
    inner: str


def _quad_terms(prob: P2Problem, lam: float):
    """A, c, const of F(beta;lam) = beta'A beta + c'beta + const."""
    (G, g, g0), (Q, q, q0) = prob.quadratics()
    return Q - lam * G, q - lam * g, q0 - lam * g0


def _eval_F(prob: P2Problem, beta: np.ndarray, lam: float) -> float:
    return prob.h2(beta) - lam * prob.h1(beta)


# ---------------------------------------------------------------------------
# inner solvers for P3
# ---------------------------------------------------------------------------

def inner_pgd(prob: P2Problem, lam: float, restarts: int = 8,
              iters: int = 300, seed: int = 0) -> np.ndarray:
    """Projected gradient ascent on the (non-concave) quadratic over [0,1]^K."""
    A, c, _ = _quad_terms(prob, lam)
    k = prob.K
    rng = np.random.default_rng(seed)
    lip = max(np.linalg.norm(A, 2) * 2.0, 1e-9)
    step = 1.0 / lip
    starts = [np.full(k, 0.5), np.zeros(k), np.ones(k), prob.rho.copy()]
    starts += [rng.random(k) for _ in range(max(restarts - len(starts), 0))]
    best, best_val = None, -np.inf
    for x0 in starts:
        x = np.clip(x0, 0, 1)
        for _ in range(iters):
            grad = 2 * A @ x + c
            x_new = np.clip(x + step * grad, 0.0, 1.0)
            if np.max(np.abs(x_new - x)) < 1e-10:
                x = x_new
                break
            x = x_new
        val = _eval_F(prob, x, lam)
        if val > best_val:
            best, best_val = x, val
    return best


def inner_exhaustive(prob: P2Problem, lam: float, grid: int = 5) -> np.ndarray:
    """Grid enumeration over [0,1]^K — oracle for K <= 6."""
    if prob.K > 6:
        raise ValueError("exhaustive inner solver limited to K <= 6")
    pts = np.linspace(0.0, 1.0, grid)
    best, best_val = None, -np.inf
    for combo in itertools.product(pts, repeat=prob.K):
        x = np.array(combo)
        v = _eval_F(prob, x, lam)
        if v > best_val:
            best, best_val = x, v
    return best


def inner_milp(prob: P2Problem, lam: float, segments: int = 8) -> np.ndarray:
    from repro.core.milp import solve_p3_milp
    A, c, const = _quad_terms(prob, lam)
    return solve_p3_milp(A, c, const, segments=segments)


_INNER: dict = {
    "pgd": inner_pgd,
    "exhaustive": inner_exhaustive,
    "milp": inner_milp,
}


# ---------------------------------------------------------------------------
# outer loop (Algorithm 2)
# ---------------------------------------------------------------------------

def dinkelbach(prob: P2Problem, inner: str = "pgd", tol: float = 1e-8,
               max_iter: int = 30,
               inner_fn: Optional[Callable] = None) -> SolveResult:
    solver = inner_fn or _INNER[inner]
    # lam_0 with F(beta; lam_0) >= 0: lam_0 = h2/h1 at any feasible point.
    beta = np.full(prob.K, 0.5)
    lam = prob.h2(beta) / max(prob.h1(beta), 1e-30)
    it = 0
    for it in range(1, max_iter + 1):
        beta_star = solver(prob, lam)
        f_val = _eval_F(prob, beta_star, lam)
        new_lam = prob.h2(beta_star) / max(prob.h1(beta_star), 1e-30)
        beta = beta_star
        if f_val < tol or abs(new_lam - lam) < 1e-14:
            lam = new_lam
            break
        lam = new_lam
    return SolveResult(beta=beta, objective=prob.objective(beta), lam=lam,
                       iterations=it, inner=inner)


def solve_p2(prob: P2Problem, method: str = "pgd", **kw) -> SolveResult:
    """Entry point. method in {milp, pgd, exhaustive, waterfill,
    waterfill_jnp} — the latter runs the jit-traceable float32 solver the
    fused on-device round uses (repro.core.boxqp.waterfill_beta_jnp)."""
    if method == "waterfill":
        from repro.core.boxqp import solve_waterfill
        return solve_waterfill(prob)
    if method == "waterfill_jnp":
        from repro.core.boxqp import solve_waterfill_jnp
        return solve_waterfill_jnp(prob)
    return dinkelbach(prob, inner=method, **kw)
