"""Time-triggered semi-asynchronous scheduler — Section II-B, Fig. 2.

Simulates K edge devices with heterogeneous compute latency. Global
aggregation fires every ``delta_t`` seconds (periodic, fixed interval). A
client whose local training (M SGD steps) finishes inside the period sets
its ready bit b_k = 1 and uploads at the next aggregation slot; stragglers
keep training their stale model and join a later round with staleness
s_k = (current round) - (round whose global model they trained from).

Latency model (Section IV-A): per-session compute time ~ U(lat_lo, lat_hi)
seconds (default U(5,15)); PAOTA period delta_t = 8 s. For the synchronous
baselines the round time is max over participating clients (bottleneck
node) — that asymmetry is exactly what Table I measures.

``SemiAsyncScheduler`` keeps the whole client state as numpy arrays
(ready bits, busy-until clocks, model rounds) so a 1000+-client round is
a handful of vector ops. ``ScalarSemiAsyncScheduler`` is the seed's
per-client-loop implementation, kept as the reference: both consume the
PCG64 stream identically (one uniform per broadcast client, in id order),
so they match draw-for-draw (tests/test_scheduler_vectorized.py).

Counter-based RNG (``SchedulerConfig.rng = "counter"``): latency draws come
from ``jax.random`` keyed purely on (seed, broadcast round) instead of a
sequential PCG64 stream. Each round's draws are then independent of how
many clients any earlier round broadcast — exactly the property the fused
on-device round (``repro.fl.fused``) needs so that a ``lax.scan`` step can
reproduce them without host state. The same fold-in scheme (one tag per
consumer) also keys the server's channel/noise/minibatch draws.

The module additionally provides the scheduler state-transition as pure
``jnp`` functions (``sched_advance`` / ``sched_broadcast``) over array
state (``ready``, ``busy_until``, ``model_round``) — the jit-traceable
form the fused round scans over.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# one tag per independent per-round RNG consumer (counter-based streams):
# key_{r,tag} = fold_in(fold_in(base_key, r), tag)
TAG_LATENCY, TAG_CHANNEL, TAG_NOISE, TAG_BATCH = 0, 1, 2, 3


def round_tag_key(base_key, round_idx, tag: int):
    """Counter-based per-round key: fold the round index, then the consumer
    tag. ``round_idx`` may be a traced int (used inside ``lax.scan``)."""
    return jax.random.fold_in(jax.random.fold_in(base_key, round_idx), tag)


def counter_latencies(base_key, round_idx, k: int, lo: float, hi: float):
    """All K latency draws for the broadcast of global round ``round_idx``
    — U(lo, hi), keyed on (base seed, round) only. Broadcast clients index
    into this vector; non-broadcast entries are simply unused, so the host
    reference and the fused path consume identical values per client."""
    key = round_tag_key(base_key, round_idx, TAG_LATENCY)
    return jax.random.uniform(key, (k,), minval=lo, maxval=hi)


# ---------------------------------------------------------------------------
# pure-jnp scheduler state transition (fused-round building blocks)
# ---------------------------------------------------------------------------

def sched_advance(ready, busy_until, model_round, time, round_idx):
    """jnp form of ``advance_to_aggregation``: at aggregation-slot ``time``
    flip ready bits for clients whose training finished, and compute the
    per-client staleness s_k = round - model_round (0 for busy clients).

    ``time`` is the already-advanced slot clock — callers compute it as
    (round+1) * delta_t rather than accumulating +=, so a float32 clock
    cannot drift from a float64 one over long scans. Returns
    (ready, staleness); the round counter itself is advanced by the caller
    (it lives in the scan carry)."""
    ready = ready | (busy_until <= time)
    stal = jnp.where(ready, round_idx - model_round, 0)
    return ready, stal


def sched_broadcast(ready, busy_until, model_round, upl_mask, time, lat,
                    new_round):
    """jnp form of ``start_round``: clients under ``upl_mask`` receive the
    new global model, go busy for their latency draw, and record the round
    they now train on. Masked no-op for everyone else (and a full no-op
    when the mask is empty — the zero-uploader round)."""
    ready = jnp.where(upl_mask, False, ready)
    busy_until = jnp.where(upl_mask, time + lat, busy_until)
    model_round = jnp.where(upl_mask, new_round, model_round)
    return ready, busy_until, model_round


@dataclass
class ClientState:
    ready: bool = True            # b_k: finished, waiting for aggregation slot
    busy_until: float = 0.0       # sim time when local training finishes
    model_round: int = 0          # round of the global model it trains on
    staleness: int = 0            # s_k at upload time


@dataclass
class SchedulerConfig:
    n_clients: int = 100
    delta_t: float = 8.0
    lat_lo: float = 5.0
    lat_hi: float = 15.0
    seed: int = 0
    rng: str = "host"             # "host": sequential PCG64 stream (seed
                                  # behaviour); "counter": per-round
                                  # jax.random draws (fused-path reference)


class SemiAsyncScheduler:
    """Vectorized simulation of PAOTA's periodic aggregation (array state)."""

    def __init__(self, cfg: SchedulerConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self.time = 0.0
        self.round = 0
        self.ready = np.ones(cfg.n_clients, dtype=bool)
        self.busy_until = np.zeros(cfg.n_clients)
        self.model_round = np.zeros(cfg.n_clients, dtype=np.int64)
        self._jkey = (jax.random.PRNGKey(cfg.seed)
                      if cfg.rng == "counter" else None)

    def _draw_latency(self, size=None):
        return self.rng.uniform(self.cfg.lat_lo, self.cfg.lat_hi, size)

    def start_round(self, participant_ids):
        """Broadcast: clients in `participant_ids` receive w_g^r and begin
        local training; each gets a fresh latency draw (one per client, in
        id order — the same stream consumption as the scalar reference).
        Counter mode draws all K latencies keyed on the broadcast round and
        indexes the participants, matching the fused path draw-for-draw."""
        ids = np.asarray(participant_ids, dtype=np.int64)
        if ids.size == 0:
            return
        if self.cfg.rng == "counter":
            lat = np.asarray(counter_latencies(
                self._jkey, self.round, self.cfg.n_clients,
                self.cfg.lat_lo, self.cfg.lat_hi))[ids]
        else:
            lat = self._draw_latency(ids.size)
        self.ready[ids] = False
        self.model_round[ids] = self.round
        self.busy_until[ids] = self.time + lat

    def advance_to_aggregation(self) -> Tuple[np.ndarray, np.ndarray]:
        """Advance sim clock by delta_t; returns (uploaders, staleness array).

        uploaders: indices with b_k = 1 at the aggregation slot (finished
        local training during this period). staleness[k] = s_k^r.
        """
        self.time += self.cfg.delta_t
        self.ready |= self.busy_until <= self.time
        stal = np.where(self.ready, self.round - self.model_round, 0)
        uploaders = np.flatnonzero(self.ready).astype(np.int64)
        self.round += 1
        return uploaders, stal.astype(np.int64)

    # ------------------------------------------------------------------
    # synchronous baselines' clock (Local SGD / COTAF): wait for stragglers
    # ------------------------------------------------------------------
    def sync_round_time(self, n_participants: int) -> float:
        """Round duration = max of n participant latency draws (bottleneck)."""
        return float(np.max(self._draw_latency(n_participants)))


class ScalarSemiAsyncScheduler:
    """Seed implementation: per-client Python loop. Reference for the
    vectorized scheduler's draw-for-draw parity tests."""

    def __init__(self, cfg: SchedulerConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self.time = 0.0
        self.round = 0
        self.clients: List[ClientState] = [ClientState()
                                           for _ in range(cfg.n_clients)]

    def _draw_latency(self, size=None):
        return self.rng.uniform(self.cfg.lat_lo, self.cfg.lat_hi, size)

    def start_round(self, participant_ids):
        for k in participant_ids:
            c = self.clients[k]
            c.ready = False
            c.model_round = self.round
            c.busy_until = self.time + float(self._draw_latency())

    def advance_to_aggregation(self):
        self.time += self.cfg.delta_t
        uploaders = []
        stal = np.zeros(self.cfg.n_clients, dtype=np.int64)
        for k, c in enumerate(self.clients):
            if not c.ready and c.busy_until <= self.time:
                c.ready = True
                c.staleness = self.round - c.model_round
            if c.ready:
                uploaders.append(k)
                stal[k] = self.round - c.model_round
        self.round += 1
        return np.array(uploaders, dtype=np.int64), stal

    def sync_round_time(self, n_participants: int) -> float:
        return float(np.max(self._draw_latency(n_participants)))
