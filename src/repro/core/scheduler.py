"""Time-triggered semi-asynchronous scheduler — Section II-B, Fig. 2.

Simulates K edge devices with heterogeneous compute latency. Global
aggregation fires every ``delta_t`` seconds (periodic, fixed interval). A
client whose local training (M SGD steps) finishes inside the period sets
its ready bit b_k = 1 and uploads at the next aggregation slot; stragglers
keep training their stale model and join a later round with staleness
s_k = (current round) - (round whose global model they trained from).

Latency model (Section IV-A): per-session compute time ~ U(lat_lo, lat_hi)
seconds (default U(5,15)); PAOTA period delta_t = 8 s. For the synchronous
baselines the round time is max over participating clients (bottleneck
node) — that asymmetry is exactly what Table I measures.

``SemiAsyncScheduler`` keeps the whole client state as numpy arrays
(ready bits, session latency draws, model rounds) so a 1000+-client round
is a handful of vector ops. Training-finished is decided by the EXACT
relative predicate ``slot_ready`` — lat <= (rounds elapsed) * delta_t,
one float rounding in the draw's own dtype — never by accumulating an
absolute clock, so the host (f64 clock) and the fused f32 scan produce
bit-identical ready masks at any horizon (tests/test_slot_clock.py). ``ScalarSemiAsyncScheduler`` is the seed's
per-client-loop implementation, kept as the reference: both consume the
PCG64 stream identically (one uniform per broadcast client, in id order),
so they match draw-for-draw (tests/test_scheduler_vectorized.py).

Counter-based RNG (``SchedulerConfig.rng = "counter"``): latency draws come
from ``jax.random`` keyed purely on (seed, broadcast round) instead of a
sequential PCG64 stream. Each round's draws are then independent of how
many clients any earlier round broadcast — exactly the property the fused
on-device round (``repro.fl.fused``) needs so that a ``lax.scan`` step can
reproduce them without host state. The same fold-in scheme (one tag per
consumer) also keys the server's channel/noise/minibatch draws.

The module additionally provides the scheduler state-transition as pure
``jnp`` functions (``sched_advance`` / ``sched_broadcast``) over array
state (``ready``, ``busy_lat``, ``model_round``) — the jit-traceable
form the fused round scans over.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# one tag per independent per-round RNG consumer (counter-based streams):
# key_{r,tag} = fold_in(fold_in(base_key, r), tag)
TAG_LATENCY, TAG_CHANNEL, TAG_NOISE, TAG_BATCH = 0, 1, 2, 3
# scenario-simulator consumers (same fold-in family, so host and fused
# simulators are draw-identical): per-round availability / dropout masks,
# the cohort scheduler's priority scores, and the STATIC per-client traits
# (cycle phases, responsiveness offsets, heterogeneous hyperparameters —
# always drawn at round 0)
TAG_AVAIL, TAG_DROPOUT, TAG_SCHED, TAG_TRAIT = 4, 5, 6, 7
# compressed cohort payloads: the shared random-mask support drawn per
# round (replicated across shards — every shard re-derives the same mask
# from the counter stream) and the stochastic-rounding dither for int8
# slot storage (folded with the shard offset so shard-local draws differ)
TAG_COMPRESS, TAG_QUANT = 8, 9
# fault injection (FaultConfig): one per-round (K,) uniform partitioned
# into disjoint payload-fault bands plus a second fold for the channel
# deep-fade mask — same counter family, so host/fused/sharded inject the
# identical fault realization
TAG_FAULT = 10


def round_tag_key(base_key, round_idx, tag: int):
    """Counter-based per-round key: fold the round index, then the consumer
    tag. ``round_idx`` may be a traced int (used inside ``lax.scan``)."""
    return jax.random.fold_in(jax.random.fold_in(base_key, round_idx), tag)


def counter_latencies(base_key, round_idx, k: int, lo: float, hi: float):
    """All K latency draws for the broadcast of global round ``round_idx``
    — U(lo, hi), keyed on (base seed, round) only. Broadcast clients index
    into this vector; non-broadcast entries are simply unused, so the host
    reference and the fused path consume identical values per client."""
    key = round_tag_key(base_key, round_idx, TAG_LATENCY)
    return jax.random.uniform(key, (k,), minval=lo, maxval=hi)


# ---------------------------------------------------------------------------
# client-state scenario simulator (FLGo-style, vectorized)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ScenarioConfig:
    """Composable client-state scenario: availability cycles, connectivity
    dropouts, responsiveness distributions, and per-client hyperparameter
    heterogeneity — the FLGo system-simulator dimensions (availability /
    connectivity / responsiveness / completeness), but VECTORIZED: every
    draw is a (K,) counter-RNG array keyed by ``round_tag_key`` (never a
    Python priority queue), so the masks advance inside ``lax.scan`` and
    the host scheduler reproduces them draw for draw
    (tests/test_scenario_sim.py).

    The default config is the identity scenario: always available, no
    dropouts, uniform responsiveness, homogeneous hyperparameters —
    bit-identical to running with no scenario at all.
    """
    availability: str = "always"   # "always" | "cycle" (staggered duty
                                   # cycle: client k is available for
                                   # duty*period rounds out of every
                                   # `period`, phase drawn per client) |
                                   # "bernoulli" (i.i.d. per round)
    avail_period: int = 10         # cycle length in rounds ("cycle")
    avail_duty: float = 0.5        # available fraction of the cycle
    avail_prob: float = 0.9        # P(available) ("bernoulli")
    dropout_prob: float = 0.0      # P(a ready upload is lost in transit);
                                   # the client restarts from the fresh
                                   # broadcast — its update never superposes
    responsiveness: str = "uniform"  # "uniform": U(lat_lo, lat_hi) —
                                   # delegates to counter_latencies verbatim
                                   # (bit-identical draws); "lognormal":
                                   # shift + exp(mu_k + sigma * z), the
                                   # FLGo long-tail latency model, warped
                                   # from the SAME per-round uniform draw
    lat_shift: float = 0.0         # lognormal location shift (seconds)
    lat_sigma: float = 0.25        # lognormal per-draw sigma
    lat_mu_spread: float = 0.5     # stddev of the static per-client mu_k
                                   # trait (device-class speed diversity)
    het_steps: tuple = ()          # per-client local-step choices, e.g.
                                   # (1, 3, 5): each client draws one
                                   # (static trait; () = homogeneous M)
    het_batch: tuple = ()          # per-client batch-size choices; exact
                                   # small-batch gradients when each choice
                                   # divides the engine batch_size (the
                                   # plan repeats the first b_k draws
                                   # cyclically), () = homogeneous B

    def __post_init__(self):
        if self.availability not in ("always", "cycle", "bernoulli"):
            raise ValueError(f"availability={self.availability!r} (expected "
                             "'always', 'cycle' or 'bernoulli')")
        if self.responsiveness not in ("uniform", "lognormal"):
            raise ValueError(f"responsiveness={self.responsiveness!r} "
                             "(expected 'uniform' or 'lognormal')")
        if self.availability == "cycle" and self.avail_period < 1:
            raise ValueError(f"avail_period={self.avail_period} (expected "
                             ">= 1)")
        if not 0.0 <= self.dropout_prob < 1.0:
            raise ValueError(f"dropout_prob={self.dropout_prob} (expected "
                             "[0, 1))")

    @property
    def has_masks(self) -> bool:
        """True when the scenario can mask uploads at all — the dense round
        core skips the mask stage entirely otherwise (trace-time Python
        branch), keeping the no-scenario program bit-identical."""
        return self.availability != "always" or self.dropout_prob > 0.0


def scenario_traits(base_key, k: int, sc: ScenarioConfig):
    """STATIC per-client traits (drawn once, at the round-0 tag, and
    recomputed identically wherever needed — they are (K,)-sized, so
    recomputation beats carrying them): cycle phases and responsiveness
    offsets mu_k. Returns (phase (K,) i32, mu (K,) f32)."""
    tk = round_tag_key(base_key, 0, TAG_TRAIT)
    phase = jax.random.randint(jax.random.fold_in(tk, 0), (k,), 0,
                               max(sc.avail_period, 1), dtype=jnp.int32)
    mu = sc.lat_mu_spread * jax.random.normal(jax.random.fold_in(tk, 1),
                                              (k,), jnp.float32)
    return phase, mu


def scenario_masks(base_key, round_idx, k: int, sc: ScenarioConfig):
    """(available, dropped) bool (K,) masks at the aggregation slot of
    ``round_idx`` — pure counter-RNG draws (``round_idx`` may be traced).
    An unavailable-but-ready client HOLDS its finished update and retries
    at a later slot (staleness keeps growing); a dropped upload is lost
    and the client restarts from the fresh broadcast."""
    if sc.availability == "always":
        avail = jnp.ones((k,), bool)
    elif sc.availability == "cycle":
        phase, _ = scenario_traits(base_key, k, sc)
        on_rounds = int(round(sc.avail_duty * sc.avail_period))
        pos = jnp.mod(jnp.asarray(round_idx, jnp.int32) + phase,
                      sc.avail_period)
        avail = pos < jnp.int32(on_rounds)
    else:  # bernoulli
        key = round_tag_key(base_key, round_idx, TAG_AVAIL)
        avail = jax.random.uniform(key, (k,)) < jnp.float32(sc.avail_prob)
    if sc.dropout_prob > 0.0:
        key = round_tag_key(base_key, round_idx, TAG_DROPOUT)
        drop = jax.random.uniform(key, (k,)) < jnp.float32(sc.dropout_prob)
    else:
        drop = jnp.zeros((k,), bool)
    return avail, drop


def scenario_latencies(base_key, round_idx, k: int, lo: float, hi: float,
                       sc: ScenarioConfig):
    """Per-session latency draws under the scenario's responsiveness model.

    "uniform" delegates to ``counter_latencies`` verbatim — bit-identical
    to the no-scenario stream. "lognormal" warps the SAME one-uniform-per-
    client-per-round draw through the inverse normal CDF:

        lat_k = shift + exp(mu_k + sigma * ndtri(u_k)) ,

    with the static mu_k trait centered so the median session sits at the
    midpoint of (lo, hi) — heterogeneous device classes with a long tail,
    same RNG budget and keying as the uniform stream."""
    if sc.responsiveness == "uniform":
        return counter_latencies(base_key, round_idx, k, lo, hi)
    key = round_tag_key(base_key, round_idx, TAG_LATENCY)
    u = jax.random.uniform(key, (k,))
    _, mu = scenario_traits(base_key, k, sc)
    med = max(0.5 * (lo + hi) - sc.lat_shift, 1e-3)
    z = jax.scipy.special.ndtri(jnp.clip(u, 1e-7, 1.0 - 1e-7))
    lat = sc.lat_shift + jnp.exp(mu + jnp.float32(np.log(med))
                                 + jnp.float32(sc.lat_sigma) * z)
    return lat.astype(jnp.float32)


def scenario_hyperparams(base_key, k: int, sc: ScenarioConfig):
    """Static per-client hyperparameter heterogeneity: (steps_k, batch_k)
    (K,) i32 arrays drawn from the scenario's choice tuples (None for a
    dimension left homogeneous). Consumed by ``BatchedEngine
    .set_heterogeneity``."""
    tk = round_tag_key(base_key, 0, TAG_TRAIT)
    steps_k = batch_k = None
    if sc.het_steps:
        c = jnp.asarray(sc.het_steps, jnp.int32)
        steps_k = c[jax.random.randint(jax.random.fold_in(tk, 2), (k,), 0,
                                       len(sc.het_steps))]
    if sc.het_batch:
        c = jnp.asarray(sc.het_batch, jnp.int32)
        batch_k = c[jax.random.randint(jax.random.fold_in(tk, 3), (k,), 0,
                                       len(sc.het_batch))]
    return steps_k, batch_k


# ---------------------------------------------------------------------------
# fault injection (rides the scenario simulator's counter-RNG family)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FaultConfig:
    """Injectable client/channel/pod faults, advanced inside the scan with
    counter RNG (``TAG_FAULT``) exactly like the scenario masks — the same
    realization on host, fused, and sharded paths, killable/resumable
    bit-for-bit. The default config is the identity: no faults, and every
    fault stage is skipped at trace time, so the compiled program is
    bit-identical to one built without a FaultConfig at all.

    Payload faults corrupt a client's TRAINED model the round it restarts
    (one uniform per client per round, partitioned into disjoint bands, so
    a client suffers at most one payload fault per round):

    * ``nan_frac`` — the row is overwritten with NaN (``nan_mode="nan"``)
      or +Inf (``nan_mode="inf"``): the killed-job / corrupted-upload mode
      that screening must mask out of the superposition.
    * ``byzantine_frac`` with ``byzantine_scale`` — the local delta is
      scaled adversarially: w' = w_g + scale * (w - w_g); finite but
      divergent, the mode the norm screen / divergence rollback catch.

    ``deep_fade_frac`` collapses a client's channel draw to
    ``deep_fade_gain * |h_k|`` — a fade outlier that drives the power cap
    (7) toward zero and the normalizer toward the zero-uploader guard.

    ``pod_blackout`` (grouped sharded mode only) lists pod indices whose
    clients are unavailable for rounds in [``blackout_start``,
    ``blackout_stop``): ready clients HOLD their updates (staleness grows)
    and rejoin when the blackout lifts — a preempted-host drill.

    ``start``/``stop`` gate every fault to rounds in [start, stop)
    (stop = -1 means forever) — single-round injections and
    kill-at-round-r experiments key off this window.
    """
    nan_frac: float = 0.0
    nan_mode: str = "nan"          # "nan" | "inf"
    byzantine_frac: float = 0.0
    byzantine_scale: float = -50.0
    deep_fade_frac: float = 0.0
    deep_fade_gain: float = 1e-4
    pod_blackout: tuple = ()       # pod indices (grouped sharded mode)
    blackout_start: int = 0
    blackout_stop: int = 0         # blackout rounds: [start, stop)
    start: int = 0
    stop: int = -1                 # payload/channel faults: [start, stop);
                                   # -1 = no upper bound

    def __post_init__(self):
        if self.nan_mode not in ("nan", "inf"):
            raise ValueError(f"nan_mode={self.nan_mode!r} (expected 'nan' "
                             "or 'inf')")
        for name in ("nan_frac", "byzantine_frac", "deep_fade_frac"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name}={v} (expected [0, 1])")
        if self.nan_frac + self.byzantine_frac > 1.0:
            raise ValueError(
                f"nan_frac + byzantine_frac = "
                f"{self.nan_frac + self.byzantine_frac} > 1 (the payload "
                "bands partition one uniform draw)")
        if any(int(p) < 0 for p in self.pod_blackout):
            raise ValueError(f"pod_blackout={self.pod_blackout} (expected "
                             "non-negative pod indices)")

    @property
    def has_payload_faults(self) -> bool:
        return self.nan_frac > 0.0 or self.byzantine_frac > 0.0

    @property
    def has_channel_faults(self) -> bool:
        return self.deep_fade_frac > 0.0

    @property
    def has_blackout(self) -> bool:
        return (len(self.pod_blackout) > 0
                and self.blackout_stop > self.blackout_start)

    @property
    def any(self) -> bool:
        return (self.has_payload_faults or self.has_channel_faults
                or self.has_blackout)


def fault_active(fc: FaultConfig, round_idx):
    """Traced bool: payload/channel faults are live at ``round_idx``."""
    t = jnp.asarray(round_idx, jnp.int32)
    live = t >= jnp.int32(fc.start)
    if fc.stop >= 0:
        live = live & (t < jnp.int32(fc.stop))
    return live


def fault_payload_masks(base_key, round_idx, k: int, fc: FaultConfig):
    """(nan_mask, byzantine_mask) bool (K,): one uniform per client keyed
    on (seed, round, TAG_FAULT), partitioned into disjoint bands
    [0, nan_frac) and [nan_frac, nan_frac + byzantine_frac)."""
    key = round_tag_key(base_key, round_idx, TAG_FAULT)
    u = jax.random.uniform(key, (k,))
    gate = fault_active(fc, round_idx)
    nan_m = gate & (u < jnp.float32(fc.nan_frac))
    byz_m = gate & (u >= jnp.float32(fc.nan_frac)) & (
        u < jnp.float32(fc.nan_frac + fc.byzantine_frac))
    return nan_m, byz_m


def fault_channel_mask(base_key, round_idx, k: int, fc: FaultConfig):
    """Deep-fade bool (K,) mask — an independent fold (1) off the round's
    TAG_FAULT key, so it never correlates with the payload bands."""
    key = jax.random.fold_in(
        round_tag_key(base_key, round_idx, TAG_FAULT), 1)
    u = jax.random.uniform(key, (k,))
    return fault_active(fc, round_idx) & (u < jnp.float32(fc.deep_fade_frac))


def blackout_active(fc: FaultConfig, round_idx):
    """Traced bool: the pod-blackout window covers ``round_idx``."""
    t = jnp.asarray(round_idx, jnp.int32)
    return (t >= jnp.int32(fc.blackout_start)) & (
        t < jnp.int32(fc.blackout_stop))


def inject_payload_faults(trained, global_tree, nan_mask, byz_mask,
                          fc: FaultConfig):
    """Corrupt the faulty rows of a stacked trained tree: every leaf of a
    NaN-faulted client's row becomes NaN/Inf; a Byzantine row's delta from
    the global model is scaled by ``byzantine_scale`` (works for both
    transmit modes — the model row moves, so the derived delta scales).
    ``trained`` leaves are (rows, ...); ``global_tree`` the matching
    unstacked model. Masks are (rows,) bool."""
    fill = jnp.float32(jnp.nan if fc.nan_mode == "nan" else jnp.inf)
    scale = jnp.float32(fc.byzantine_scale)

    def leaf(tr, g):
        shape = (tr.shape[0],) + (1,) * (tr.ndim - 1)
        nm = nan_mask.reshape(shape)
        bm = byz_mask.reshape(shape)
        gb = jnp.broadcast_to(g[None].astype(tr.dtype), tr.shape)
        out = jnp.where(bm, (gb + scale * (tr - gb)).astype(tr.dtype), tr)
        return jnp.where(nm, fill.astype(tr.dtype), out)

    return jax.tree_util.tree_map(leaf, trained, global_tree)


# ---------------------------------------------------------------------------
# pure-jnp scheduler state transition (fused-round building blocks)
# ---------------------------------------------------------------------------

def slot_ready(lat, model_round, round_idx, delta_t):
    """Exact slot-boundary predicate, shared by the host schedulers and the
    fused/sharded round: a client broadcast at round j with latency draw
    ``lat`` has finished by the aggregation slot of round ``round_idx``
    (wall clock (round_idx + 1) * delta_t, broadcast clock j * delta_t) iff

        lat <= (round_idx + 1 - j) * delta_t .

    The relative form has ONE float rounding — the small-integer product —
    in ``lat``'s own dtype, instead of comparing absolute clocks whose f32
    rounding (ulp of t * delta_t) grows with the horizon and eventually
    flips boundaries against the host's f64 clock. Evaluated over f32
    arrays on device and over the same-dtype numpy arrays on the host, the
    comparison is bit-identical (same IEEE multiply, same inputs), for any
    delta_t and any horizon with round counts < 2^24."""
    m = (round_idx + 1) - model_round
    if isinstance(lat, np.ndarray):
        return lat <= m.astype(lat.dtype) * lat.dtype.type(delta_t)
    return lat <= m.astype(lat.dtype) * jnp.asarray(delta_t, lat.dtype)


def sched_advance(ready, busy_lat, model_round, round_idx, delta_t):
    """jnp form of ``advance_to_aggregation``: at the aggregation slot of
    round ``round_idx`` flip ready bits for clients whose training finished
    (the exact ``slot_ready`` predicate over the carried latency draws —
    no absolute-clock accumulation), and compute the per-client staleness
    s_k = round - model_round (0 for busy clients). Returns
    (ready, staleness); the round counter itself is advanced by the caller
    (it lives in the scan carry)."""
    ready = ready | slot_ready(busy_lat, model_round, round_idx, delta_t)
    stal = jnp.where(ready, round_idx - model_round, 0)
    return ready, stal


def sched_broadcast(ready, busy_lat, model_round, upl_mask, lat, new_round):
    """jnp form of ``start_round``: clients under ``upl_mask`` receive the
    new global model, go busy for their latency draw (the raw draw is
    carried — ``slot_ready`` anchors it to ``model_round``'s broadcast
    slot), and record the round they now train on. Masked no-op for
    everyone else (and a full no-op when the mask is empty — the
    zero-uploader round)."""
    ready = jnp.where(upl_mask, False, ready)
    busy_lat = jnp.where(upl_mask, lat, busy_lat)
    model_round = jnp.where(upl_mask, new_round, model_round)
    return ready, busy_lat, model_round


@dataclass
class ClientState:
    ready: bool = True            # b_k: finished, waiting for aggregation slot
    busy_lat: float = 0.0         # latency draw of the current session
                                  # (finish slot via the slot_ready predicate)
    model_round: int = 0          # round of the global model it trains on
    staleness: int = 0            # s_k at upload time


@dataclass
class SchedulerConfig:
    n_clients: int = 100
    delta_t: float = 8.0
    lat_lo: float = 5.0
    lat_hi: float = 15.0
    seed: int = 0
    rng: str = "host"             # "host": sequential PCG64 stream (seed
                                  # behaviour); "counter": per-round
                                  # jax.random draws (fused-path reference)


class SemiAsyncScheduler:
    """Vectorized simulation of PAOTA's periodic aggregation (array state).

    ``scenario`` (a ``ScenarioConfig``, counter RNG only) runs the same
    vectorized client-state simulator the fused scan advances:
    availability/dropout masks gate which ready clients upload, and the
    responsiveness model shapes the latency draws. ``restart_ids`` after
    ``advance_to_aggregation`` are the clients that should receive the new
    broadcast (ready AND available — a dropped uploader restarts too, its
    update was lost in transit); without a scenario they equal the
    uploaders, preserving the historical contract."""

    def __init__(self, cfg: SchedulerConfig, scenario=None):
        self.cfg = cfg
        if scenario is not None and cfg.rng != "counter":
            raise ValueError("scenario simulation needs counter RNG "
                             "(SchedulerConfig(rng='counter')): the per-round "
                             "masks are keyed draws shared with the fused "
                             "scan, which a sequential PCG64 stream cannot "
                             "reproduce")
        self.scenario = scenario
        self.rng = np.random.default_rng(cfg.seed)
        self.time = 0.0
        self.round = 0
        self.ready = np.ones(cfg.n_clients, dtype=bool)
        # the per-client latency draw of the current training session; the
        # finish slot is the relative slot_ready predicate, never an
        # accumulated absolute clock. Counter mode keeps the draws in their
        # f32 draw dtype so the predicate is BIT-identical to the fused
        # scan's (same IEEE ops, same inputs); host PCG64 mode stays f64.
        lat_dtype = np.float32 if cfg.rng == "counter" else np.float64
        self.busy_lat = np.zeros(cfg.n_clients, dtype=lat_dtype)
        self.model_round = np.zeros(cfg.n_clients, dtype=np.int64)
        self._jkey = (jax.random.PRNGKey(cfg.seed)
                      if cfg.rng == "counter" else None)
        self.restart_ids = np.arange(cfg.n_clients, dtype=np.int64)

    def _draw_latency(self, size=None):
        return self.rng.uniform(self.cfg.lat_lo, self.cfg.lat_hi, size)

    def start_round(self, participant_ids):
        """Broadcast: clients in `participant_ids` receive w_g^r and begin
        local training; each gets a fresh latency draw (one per client, in
        id order — the same stream consumption as the scalar reference).
        Counter mode draws all K latencies keyed on the broadcast round and
        indexes the participants, matching the fused path draw-for-draw
        (under a scenario, through its responsiveness model)."""
        ids = np.asarray(participant_ids, dtype=np.int64)
        if ids.size == 0:
            return
        if self.cfg.rng == "counter":
            if self.scenario is None:
                full = counter_latencies(
                    self._jkey, self.round, self.cfg.n_clients,
                    self.cfg.lat_lo, self.cfg.lat_hi)
            else:
                full = scenario_latencies(
                    self._jkey, self.round, self.cfg.n_clients,
                    self.cfg.lat_lo, self.cfg.lat_hi, self.scenario)
            lat = np.asarray(full)[ids]
        else:
            lat = self._draw_latency(ids.size)
        self.ready[ids] = False
        self.model_round[ids] = self.round
        self.busy_lat[ids] = lat

    def advance_to_aggregation(self) -> Tuple[np.ndarray, np.ndarray]:
        """Advance sim clock by delta_t; returns (uploaders, staleness array).

        uploaders: indices with b_k = 1 at the aggregation slot (finished
        local training during this period) — under a scenario, additionally
        available and not dropped. staleness[k] = s_k^r. ``restart_ids`` is
        refreshed with the clients the caller should re-broadcast to.
        """
        self.ready |= np.asarray(slot_ready(self.busy_lat, self.model_round,
                                            self.round, self.cfg.delta_t))
        if self.scenario is None or not self.scenario.has_masks:
            upl_mask = restart_mask = self.ready
        else:
            avail, drop = (np.asarray(m) for m in scenario_masks(
                self._jkey, self.round, self.cfg.n_clients, self.scenario))
            # unavailable-but-ready clients HOLD their update (ready stays
            # set; staleness keeps growing); dropped uploads are lost but
            # the client still restarts from the fresh broadcast
            upl_mask = self.ready & avail & ~drop
            restart_mask = self.ready & avail
        stal = np.where(upl_mask, self.round - self.model_round, 0)
        uploaders = np.flatnonzero(upl_mask).astype(np.int64)
        self.restart_ids = np.flatnonzero(restart_mask).astype(np.int64)
        self.round += 1
        # drift-free clock (report-only): recomputed, never accumulated
        self.time = self.round * self.cfg.delta_t
        return uploaders, stal.astype(np.int64)

    # ------------------------------------------------------------------
    # synchronous baselines' clock (Local SGD / COTAF): wait for stragglers
    # ------------------------------------------------------------------
    def sync_round_time(self, n_participants: int) -> float:
        """Round duration = max of n participant latency draws (bottleneck)."""
        return float(np.max(self._draw_latency(n_participants)))


class ScalarSemiAsyncScheduler:
    """Seed implementation: per-client Python loop. Reference for the
    vectorized scheduler's draw-for-draw parity tests."""

    def __init__(self, cfg: SchedulerConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self.time = 0.0
        self.round = 0
        self.clients: List[ClientState] = [ClientState()
                                           for _ in range(cfg.n_clients)]

    def _draw_latency(self, size=None):
        return self.rng.uniform(self.cfg.lat_lo, self.cfg.lat_hi, size)

    def start_round(self, participant_ids):
        for k in participant_ids:
            c = self.clients[k]
            c.ready = False
            c.model_round = self.round
            c.busy_lat = float(self._draw_latency())

    def advance_to_aggregation(self):
        uploaders = []
        stal = np.zeros(self.cfg.n_clients, dtype=np.int64)
        for k, c in enumerate(self.clients):
            done = (c.busy_lat
                    <= (self.round + 1 - c.model_round) * self.cfg.delta_t)
            if not c.ready and done:
                c.ready = True
                c.staleness = self.round - c.model_round
            if c.ready:
                uploaders.append(k)
                stal[k] = self.round - c.model_round
        self.round += 1
        self.time = self.round * self.cfg.delta_t
        return np.array(uploaders, dtype=np.int64), stal

    def sync_round_time(self, n_participants: int) -> float:
        return float(np.max(self._draw_latency(n_participants)))
