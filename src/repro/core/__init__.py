"""PAOTA — the paper's primary contribution as composable JAX modules:
AirComp channel (aircomp), semi-async scheduler (scheduler), power-control
optimization (power_control + dinkelbach/milp/boxqp), the aggregation rule
in stacked and collective forms (aggregation), and the Theorem-1 bound
calculators (convergence)."""
from repro.core.aircomp import (VARSIGMA_MIN, ChannelConfig,  # noqa: F401
                                aircomp_aggregate, aggregation_weights,
                                sample_channel_gains)
from repro.core.aggregation import (exact_average, guarded_global_update,  # noqa: F401
                                    paota_aggregate_stacked, paota_allreduce,
                                    paota_finalize_stacked,
                                    paota_partial_stacked, ravel)
from repro.core.convergence import BoundConstants, contraction_A, gap_G  # noqa: F401
from repro.core.dinkelbach import solve_p2  # noqa: F401
from repro.core.power_control import (P2Problem, build_p2, cosine_similarity,  # noqa: F401
                                      p2_constants, power_from_beta,
                                      similarity_factor, staleness_factor)
from repro.core.scheduler import (ScenarioConfig, SchedulerConfig,  # noqa: F401
                                  SemiAsyncScheduler, counter_latencies,
                                  round_tag_key, sched_advance,
                                  sched_broadcast, scenario_hyperparams,
                                  scenario_latencies, scenario_masks,
                                  scenario_traits, slot_ready)
