"""PAOTA — the paper's primary contribution as composable JAX modules:
AirComp channel (aircomp), semi-async scheduler (scheduler), power-control
optimization (power_control + dinkelbach/milp/boxqp), the aggregation rule
in stacked and collective forms (aggregation), and the Theorem-1 bound
calculators (convergence)."""
from repro.core.aircomp import (ChannelConfig, aircomp_aggregate,  # noqa: F401
                                aggregation_weights, sample_channel_gains)
from repro.core.aggregation import (exact_average, paota_aggregate_stacked,  # noqa: F401
                                    paota_allreduce, ravel)
from repro.core.convergence import BoundConstants, contraction_A, gap_G  # noqa: F401
from repro.core.dinkelbach import solve_p2  # noqa: F401
from repro.core.power_control import (P2Problem, build_p2, cosine_similarity,  # noqa: F401
                                      power_from_beta, similarity_factor,
                                      staleness_factor)
from repro.core.scheduler import SchedulerConfig, SemiAsyncScheduler  # noqa: F401
