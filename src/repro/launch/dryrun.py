import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ MUST run before any other import (jax locks device count on first init).

"""Multi-pod dry-run (deliverable e).

For every (architecture x input shape x mesh) combination, build the
distributed step, ``.lower().compile()`` it against ShapeDtypeStruct inputs
(zero allocation), print memory_analysis()/cost_analysis(), and record a
JSON blob (FLOPs, bytes, per-collective bytes, roofline terms) consumed by
EXPERIMENTS.md §Dry-run/§Roofline and benchmarks/roofline_bench.py.

Usage:
  python -m repro.launch.dryrun --arch smollm-135m --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--out experiments/dryrun]
"""
import argparse
import json
import time
import traceback

import jax


def run_one(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
            verbose: bool = True) -> dict:
    from repro.configs import get_config
    from repro.launch.mesh import make_production_mesh, client_axes_for
    from repro.launch.shapes import SHAPES, applicability
    from repro.launch.steps import build_step, runtime_config
    from repro.models.transformer import param_count, active_param_count
    from repro.models.transformer import init_model  # noqa: F401
    from repro.roofline.analysis import collective_bytes, roofline_terms, model_flops

    cfg0 = get_config(arch)
    shape = SHAPES[shape_name]
    ok, note = applicability(cfg0, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "status": "skipped", "note": note}
    if not ok:
        if verbose:
            print(f"[skip] {arch} x {shape_name}: {note}")
        return _save(rec, out_dir)

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh.size
    cfg = runtime_config(cfg0, shape)
    t0 = time.time()
    jax.set_mesh(mesh)  # context mesh: shard_map regions resolve axes on it
    try:
        extra = {}
        if shape.kind == "decode" and os.environ.get("REPRO_KV_QUANT") == "1":
            extra["kv_quant"] = True
        if shape.kind == "train" and os.environ.get("REPRO_SEQ_PARALLEL") == "1":
            extra["seq_parallel"] = True
        jitted, structs, _ = build_step(cfg0, mesh, shape, **extra)
        lowered = jitted.lower(*structs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        from repro.roofline.analysis import cost_analysis_dict
        mem = compiled.memory_analysis()
        cost = cost_analysis_dict(compiled)
        hlo = compiled.as_text()
    finally:
        pass  # set_mesh(None) unsupported; next run_one overwrites the mesh

    from repro.roofline.hlo_parse import analyze as hlo_analyze

    flops_raw = float(cost.get("flops", 0.0))
    byt_raw = float(cost.get("bytes accessed", 0.0))
    parsed = hlo_analyze(hlo)
    flops = max(parsed["flops"], flops_raw)
    # bytes: scale the cost_analysis number by the same while-loop
    # undercount factor (uniform-intensity assumption, see EXPERIMENTS.md)
    scan_factor = flops / max(flops_raw, 1.0)
    byt = byt_raw * scan_factor
    coll = {k: v for k, v in parsed.items()
            if k not in ("flops", "coll_bytes")}
    terms = roofline_terms(flops, byt, coll, chips)

    # params/tokens for the MODEL_FLOPS utilisation ratio
    n_params = _param_count_cached(arch, cfg0)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    if shape.kind == "train":
        from repro.launch.steps import make_paota_train_step  # noqa
        tokens *= 5  # M local steps per PAOTA round
    mflops = model_flops(n_params["total"], n_params["active"], tokens,
                         is_train=(shape.kind == "train")) / chips
    # ^ per-chip, matching cost_analysis' per-partition accounting

    mem_rec = {}
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        if hasattr(mem, attr):
            mem_rec[attr] = int(getattr(mem, attr))

    rec.update({
        "status": "ok",
        "chips": chips,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "hlo_flops": flops,
        "hlo_bytes": byt,
        "hlo_flops_uncorrected": flops_raw,
        "hlo_bytes_uncorrected": byt_raw,
        "scan_trip_correction": round(scan_factor, 2),
        "collectives": coll,
        "roofline": {k: (v if not isinstance(v, float) else float(v))
                     for k, v in terms.items()},
        "model_flops": mflops,
        "useful_flops_ratio": (mflops / flops) if flops else None,
        "params_total": n_params["total"],
        "params_active": n_params["active"],
        "memory_analysis": mem_rec,
        "bytes_per_chip_args": (mem_rec.get("argument_size_in_bytes", 0) / chips
                                if mem_rec else None),
        "client_axes": list(client_axes_for(cfg0, mesh)) if shape.kind == "train" else None,
    })
    if verbose:
        print(f"[ok] {arch} x {shape_name} x {mesh_kind}: "
              f"flops={flops:.3e} bytes={byt:.3e} "
              f"coll={sum(coll.values()):.3e}B dom={terms['dominant']} "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
        print(f"     memory_analysis: {mem_rec}")
    return _save(rec, out_dir)


_PC_CACHE = {}


def _param_count_cached(arch: str, cfg) -> dict:
    if arch in _PC_CACHE:
        return _PC_CACHE[arch]
    import jax
    from repro.launch.steps import abstract_params
    from repro.models.transformer import active_param_count

    tree = abstract_params(cfg)
    total = sum(int(x.size) for x in jax.tree_util.tree_leaves(tree))
    active = active_param_count(tree, cfg)
    _PC_CACHE[arch] = {"total": total, "active": active}
    return _PC_CACHE[arch]


def _save(rec: dict, out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir,
                        f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    from repro.configs import ARCH_IDS
    from repro.launch.shapes import SHAPE_IDS

    archs = ARCH_IDS if args.all or not args.arch else [args.arch]
    shapes = SHAPE_IDS if args.all or not args.shape else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                tag = f"{arch}__{shape}__{mk}"
                path = os.path.join(args.out, tag + ".json")
                if args.skip_existing and os.path.exists(path):
                    with open(path) as f:
                        if json.load(f).get("status") in ("ok", "skipped"):
                            print(f"[cached] {tag}")
                            continue
                try:
                    run_one(arch, shape, mk, args.out)
                except Exception as e:  # record, keep going
                    traceback.print_exc()
                    failures.append(tag)
                    _save({"arch": arch, "shape": shape, "mesh": mk,
                           "status": "error", "note": repr(e)[:2000]}, args.out)
    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print("dry-run complete")


if __name__ == "__main__":
    main()
