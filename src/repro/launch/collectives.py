"""Compiled-HLO collective auditing for the grouped aggregation plane.

The grouped round's contract is STRUCTURAL, not just numerical: a
``group_period=N`` window must compile to exactly ONE cross-pod
model-sized all-reduce (the window sync), with every other collective
either intra-pod (the per-period partial superpositions) or small
(water-filling grid psums, scalar metrics). Numerics cannot see the
difference — a flat psum every period produces the same N=1 trajectory —
so the benchmark and the grouped test suite pin the invariant by parsing
the compiled HLO (``ShardedPAOTA.compiled_scan_hlo``) and counting
all-reduces by replica-group span and payload size.

Replica groups come in both HLO spellings: the explicit nested-brace list
``replica_groups={{0,1},{2,3}}`` and the iota form
``replica_groups=[2,4]<=[8]`` (optionally with a transpose,
``[4,2]<=[2,4]T(1,0)``). Partition indices are row-major over the mesh
shape in axis-name order (``mesh.devices`` layout), so a partition's pod
coordinate is its unravelled index at the pod dims.
"""
from __future__ import annotations

import re
from typing import Iterator, List, Tuple

import numpy as np

# an op result type, e.g. f32[13219]{0} or pred[] — dims may be empty
_TYPE_RE = re.compile(r"\b[a-z0-9]+\[([0-9,]*)\]")
_GROUPS_RE = re.compile(
    r"replica_groups=(\{\{.*?\}\}|"
    r"\[[0-9,]+\]<=\[[0-9,]+\](?:T\([0-9,]+\))?)")


def _parse_groups(attr: str) -> List[List[int]]:
    """Materialize a replica_groups attribute into explicit index lists."""
    if attr.startswith("{"):
        return [[int(t) for t in m.group(1).replace(" ", "").split(",") if t]
                for m in re.finditer(r"\{([0-9, ]+)\}", attr)]
    m = re.match(r"\[([0-9,]+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?", attr)
    if m is None:
        raise ValueError(f"unrecognized replica_groups attribute: {attr!r}")
    out_shape = [int(t) for t in m.group(1).split(",")]
    src_shape = [int(t) for t in m.group(2).split(",")]
    arr = np.arange(int(np.prod(src_shape))).reshape(src_shape)
    if m.group(3):
        arr = arr.transpose([int(t) for t in m.group(3).split(",")])
    return [list(map(int, row)) for row in arr.reshape(out_shape)]


def iter_allreduces(hlo_text: str) -> Iterator[Tuple[int, List[List[int]]]]:
    """Yield (max element count, replica groups) for every all-reduce /
    all-reduce-start op in the HLO text. Tuple-shaped results (the
    all-reduce combiner merges independent psums into one op) report the
    LARGEST member — the op moves its biggest payload across the groups."""
    for line in hlo_text.splitlines():
        head, sep, _ = line.partition(" all-reduce(")
        if not sep:
            head, sep, _ = line.partition(" all-reduce-start(")
            if not sep:
                continue
        _, _, types = head.rpartition(" = ")
        nelems = max((int(np.prod([int(d) for d in m.group(1).split(",")]))
                      if m.group(1) else 1
                      for m in _TYPE_RE.finditer(types)), default=1)
        gm = _GROUPS_RE.search(line)
        groups = _parse_groups(gm.group(1)) if gm else []
        yield nelems, groups


def axis_crossing_allreduce_count(hlo_text: str,
                                  mesh_shape: Tuple[int, ...],
                                  dims: Tuple[int, ...],
                                  min_elements: int = 1,
                                  max_elements: int | None = None) -> int:
    """Count all-reduces whose replica groups SPAN the mesh dims ``dims``
    and whose payload size is in ``[min_elements, max_elements]``.

    ``mesh_shape`` is the mesh's extent tuple in axis-name order, ``dims``
    the positions of the axes of interest in it (pod axes for the grouped
    invariant, client axes for the cross-client superposition, the TP
    axis for the intra-client-TP reductions). An op "spans" the dims when
    some replica group holds two devices with different coordinates at
    them. Empty replica groups mean ALL devices in one group — spanning
    whenever any dim in ``dims`` has extent > 1."""
    def coord_of(p: int) -> Tuple[int, ...]:
        coords = np.unravel_index(p, mesh_shape)
        return tuple(int(coords[d]) for d in dims)

    n_at = int(np.prod([mesh_shape[d] for d in dims]))
    count = 0
    for nelems, groups in iter_allreduces(hlo_text):
        if nelems < min_elements:
            continue
        if max_elements is not None and nelems > max_elements:
            continue
        if not groups:
            crosses = n_at > 1
        else:
            crosses = any(len({coord_of(p) for p in g}) > 1 for g in groups)
        if crosses:
            count += 1
    return count


def cross_pod_allreduce_count(hlo_text: str, mesh_shape: Tuple[int, ...],
                              pod_dims: Tuple[int, ...],
                              min_elements: int = 8192) -> int:
    """Count all-reduces whose replica groups SPAN pods and whose payload
    is at least ``min_elements`` elements (model-sized; the default sits
    above the water-filling grid of 4096 and the scalar metrics, below
    any federated model). The pod-axes instance of
    ``axis_crossing_allreduce_count``."""
    return axis_crossing_allreduce_count(hlo_text, mesh_shape, pod_dims,
                                         min_elements=min_elements)
