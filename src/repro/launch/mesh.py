"""Production mesh builders.

Single pod: 16x16 = 256 chips, axes ("data", "model").
Multi-pod:  2x16x16 = 512 chips, axes ("pod", "data", "model").

FL-mode client placement (DESIGN.md §4): the PAOTA client axis is
("data",) — or ("pod","data") multi-pod — for architectures whose full
replica fits one model-parallel group; for the giant MoE archs the client
axis is ("pod",) (2 semi-async cohorts) with expert-parallel sharding over
"data" inside each client.

Functions, not module constants: importing this module never touches jax
device state (required so smoke tests see 1 CPU device while the dry-run
sees 512 forced host devices).
"""
from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_cpu_mesh(*, data: int = 1, model: int = 1):
    """Tiny mesh over real local devices (tests on CPU).

    On a CPU-only host extra devices can be forced BEFORE jax initializes
    with ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (the test
    suite's conftest does this; ``benchmarks.sharded_round_bench``
    re-execs itself with it set). Once jax has initialized, the flag is
    inert — hence the hard error here rather than a silent 1-device mesh.
    """
    n = data * model
    if len(jax.devices()) < n:
        raise RuntimeError(
            f"need {n} devices, have {len(jax.devices())}; on CPU force "
            f"virtual devices with XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n} before jax "
            f"initializes (set it in the environment, not after import)")
    return jax.make_mesh((data, model), ("data", "model"))


def make_pod_mesh(*, pods: int = 2, data: int = 256, tp: int = 1):
    """("pod", "data"[, "tp"]) client mesh: ``pods`` semi-async
    aggregation groups of ``data`` client shards each. ``tp > 1`` appends
    an intra-client tensor-parallel axis — every client replica's model
    storage spans ``tp`` chips (``ShardedPAOTA`` TP-shards the stacked
    payload leaves over it; see EXPERIMENTS.md §Intra-client TP).
    ``tp=1`` returns the historical two-axis ("pod", "data") mesh
    unchanged. Same forced-host-device contract as ``make_cpu_mesh``:
    on CPU set XLA_FLAGS before jax initializes."""
    n = pods * data * tp
    if len(jax.devices()) < n:
        raise RuntimeError(
            f"need {n} devices, have {len(jax.devices())}; on CPU force "
            f"virtual devices with XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n} before jax "
            f"initializes (set it in the environment, not after import)")
    if tp == 1:
        return jax.make_mesh((pods, data), ("pod", "data"))
    return jax.make_mesh((pods, data, tp), ("pod", "data", "tp"))


def make_client_mesh(shards: int | None = None):
    """All-devices 1-model-axis mesh (("data", "model") = (n, 1)) for the
    mesh-sharded PAOTA round: the whole device pool becomes the client
    axis (``data``), each client replica fitting a single device — the
    small-federation analogue of DESIGN.md §4's flattened-client layout."""
    n = shards if shards is not None else len(jax.devices())
    return make_cpu_mesh(data=n, model=1)


def data_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def client_axes_for(cfg, mesh) -> Tuple[str, ...]:
    """PAOTA client axis selection (DESIGN.md §4 + EXPERIMENTS.md §Perf
    iter A):

    * giant MoE (llama4/mixtral): replica needs EP+TP inside -> client=pod
      (2 semi-async cohorts multi-pod; degenerate sync single-pod);
    * small archs whose attention heads do NOT divide the model axis
      (smollm 9H, internvl2 14H, minicpm 36H): TP sharding replicated
      their attention compute 16x — flatten clients over BOTH axes
      (one chip per client, 256/512 clients, zero TP collectives);
    * everything else: client=data groups with 16-way TP inside.
    """
    giant = cfg.name.startswith(("llama4", "mixtral"))
    if giant:
        return ("pod",) if "pod" in mesh.axis_names else ()
    msize = mesh.shape.get("model", 1)
    heads_bad = cfg.num_heads and cfg.num_heads % msize != 0
    # replica must fit one chip: params bf16 + grads + activations << 16GB
    small = cfg.name.startswith(("smollm", "internvl2", "minicpm"))
    if heads_bad and small:
        return data_axes(mesh) + ("model",)
    return data_axes(mesh)
