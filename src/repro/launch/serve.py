"""Serving launcher: batched decode loop (the serve_step the decode dry-runs
lower). CPU demo via --demo; production mesh lowering via repro.launch.dryrun.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --demo
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--cache", type=int, default=128)
    ap.add_argument("--demo", action="store_true")
    args = ap.parse_args()

    import sys
    sys.argv = ["serve_decode", "--arch", args.arch, "--batch",
                str(args.batch), "--steps", str(args.steps), "--cache",
                str(args.cache)] + (["--reduced"] if args.demo else [])
    import examples.serve_decode as sd
    sd.main()


if __name__ == "__main__":
    main()
