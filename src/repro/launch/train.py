"""Datacenter training launcher: the PAOTA round step on a real device mesh.

On TPU this drives the same ``make_paota_train_step`` the dry-run lowers;
on this CPU container it runs a 1x1 mesh demo (use --demo) or validates
lowering for the production mesh (use repro.launch.dryrun for that).

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --demo \
        --rounds 5
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--demo", action="store_true",
                    help="reduced config + tiny shapes on local devices")
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--local-steps", type=int, default=5)
    ap.add_argument("--checkpoint", default="")
    args = ap.parse_args()

    from repro.configs import get_config, get_reduced
    from repro.data.synthetic import token_stream
    from repro.launch.shapes import SHAPES, InputShape
    from repro.launch.steps import make_paota_train_step, runtime_config
    from repro.models import init_model

    if args.demo:
        cfg = get_reduced(args.arch)
        import dataclasses
        cfg = dataclasses.replace(cfg, remat="block")
        shape = InputShape("demo", seq_len=128, global_batch=8, kind="train")
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        client_axes = ("data",)
    else:
        cfg = runtime_config(get_config(args.arch), SHAPES[args.shape])
        shape = SHAPES[args.shape]
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh()
        client_axes = None

    with mesh:
        step, structs, _ = make_paota_train_step(
            cfg, mesh, shape, lr=args.lr, local_steps=args.local_steps,
            client_axes=client_axes, donate=False)
        k = structs[2].shape[0]
        params = init_model(jax.random.PRNGKey(0), cfg)
        stacked = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (k,) + x.shape), params)
        mb = structs[1]["tokens"].shape[2] if "tokens" in structs[1] else 1
        stream = token_stream(cfg.vocab_size, k * args.local_steps * mb,
                              shape.seq_len, args.rounds)
        rng = np.random.default_rng(0)
        for r, batch in enumerate(stream):
            toks = batch["tokens"].reshape(k, args.local_steps, mb,
                                           shape.seq_len)
            mask = (rng.random(k) < 0.8).astype(np.float32)
            if mask.sum() == 0:
                mask[0] = 1.0
            powers = np.full(k, 15.0, np.float32)
            t0 = time.time()
            seed = jax.random.key_data(jax.random.PRNGKey(r)).astype(jnp.uint32)
            stacked, metrics = step(stacked, {"tokens": jnp.asarray(toks)},
                                    jnp.asarray(powers), jnp.asarray(mask),
                                    seed)
            print(f"round {r}: loss={float(metrics['loss']):.4f} "
                  f"participants={int(metrics['participants'])} "
                  f"({time.time() - t0:.1f}s)")
        if args.checkpoint:
            from repro.checkpoint import save_checkpoint
            save_checkpoint(args.checkpoint, jax.device_get(stacked),
                            step=args.rounds)
            print(f"checkpoint -> {args.checkpoint}")


if __name__ == "__main__":
    main()
