"""Assigned input shapes and input_specs() builders.

The four assigned shapes:
  train_4k       seq_len=  4,096  global_batch= 256  (training)
  prefill_32k    seq_len= 32,768  global_batch=  32  (inference-prefill)
  decode_32k     seq_len= 32,768  global_batch= 128  (inference-decode)
  long_500k      seq_len=524,288  global_batch=   1  (long-context-decode)

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins (weak-type
correct, shardable, zero allocation) for the dry-run; ``make_batch`` builds
small concrete batches for CPU smoke tests.

Skip rules (DESIGN.md §4):
  - encoder-only (hubert): no decode step -> decode_32k / long_500k skipped.
  - long_500k needs sub-quadratic attention: SSM/hybrid run natively; archs
    with sliding_window run windowed; full-attention archs get the
    framework's sliding-window variant (beyond-paper, flagged).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig

LONG_CONTEXT_WINDOW = 4096  # SWA width applied to full-attn archs for long_500k


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

SHAPE_IDS = list(SHAPES)


def applicability(cfg: ModelConfig, shape: InputShape):
    """Returns (applicable: bool, note: str)."""
    if shape.kind == "decode" and cfg.encoder_only:
        return False, "encoder-only: no decode step (DESIGN.md §4)"
    return True, ""


def shape_config(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Per-shape config adaptation: long_500k forces sub-quadratic attention
    on archs that would otherwise be O(T) per decoded token in cache size
    only — full-attn archs get the sliding-window variant (flagged)."""
    if (shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid")
            and cfg.sliding_window is None):
        return dataclasses.replace(cfg, sliding_window=LONG_CONTEXT_WINDOW)
    return cfg


def _text_len(cfg: ModelConfig, seq_len: int) -> int:
    if cfg.modality == "vision_text":
        return max(seq_len - cfg.num_patches, 8)
    return seq_len


def input_specs(cfg: ModelConfig, shape: InputShape, *, batch_override=None):
    """Abstract ShapeDtypeStruct inputs for jit(...).lower(**specs)."""
    from repro.models.transformer import init_decode_state

    b = batch_override or shape.global_batch
    s = shape.seq_len
    cfg = shape_config(cfg, shape)
    i32 = jnp.int32

    if shape.kind in ("train", "prefill"):
        t = _text_len(cfg, s)
        if cfg.modality == "audio":
            batch = {
                "frame_feats": jax.ShapeDtypeStruct((b, s, cfg.frontend_dim),
                                                    jnp.dtype(cfg.compute_dtype)),
                "mask_indicator": jax.ShapeDtypeStruct((b, s), i32),
                "targets": jax.ShapeDtypeStruct((b, s), i32),
            }
        elif cfg.modality == "vision_text":
            batch = {
                "tokens": jax.ShapeDtypeStruct((b, t), i32),
                "patch_embeds": jax.ShapeDtypeStruct(
                    (b, cfg.num_patches, cfg.frontend_dim),
                    jnp.dtype(cfg.compute_dtype)),
            }
        else:
            batch = {"tokens": jax.ShapeDtypeStruct((b, t), i32)}
        return {"batch": batch}

    # decode: one new token against a seq_len-deep cache/state
    state = jax.eval_shape(lambda: init_decode_state(cfg, b, s))
    return {
        "tokens": jax.ShapeDtypeStruct((b, 1), i32),
        "state": state,
        "index": jax.ShapeDtypeStruct((), i32),
    }


def make_batch(cfg: ModelConfig, shape: InputShape, key=None, *,
               batch_override: Optional[int] = None,
               seq_override: Optional[int] = None):
    """Small concrete batch for smoke tests (reduced configs on CPU)."""
    rng = np.random.default_rng(0)
    b = batch_override or shape.global_batch
    s = seq_override or shape.seq_len
    cfg = shape_config(cfg, shape)

    if shape.kind in ("train", "prefill"):
        t = _text_len(cfg, s)
        if cfg.modality == "audio":
            return {
                "frame_feats": jnp.asarray(
                    rng.normal(size=(b, s, cfg.frontend_dim)).astype(np.float32)),
                "mask_indicator": jnp.asarray(
                    (rng.random((b, s)) < cfg.mask_prob).astype(np.int32)),
                "targets": jnp.asarray(
                    rng.integers(0, cfg.vocab_size, (b, s)).astype(np.int32)),
            }
        if cfg.modality == "vision_text":
            return {
                "tokens": jnp.asarray(
                    rng.integers(0, cfg.vocab_size, (b, t)).astype(np.int32)),
                "patch_embeds": jnp.asarray(
                    rng.normal(size=(b, cfg.num_patches, cfg.frontend_dim))
                    .astype(np.float32)),
            }
        return {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, t)).astype(np.int32))}

    from repro.models.transformer import init_decode_state
    return {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, 1)).astype(np.int32)),
        "state": init_decode_state(cfg, b, s),
        "index": jnp.asarray(min(7, s - 1), jnp.int32),
    }
