"""Federated-learning launcher — the paper's experiment driver (Section IV).

Thin CLI over examples/fl_noniid_mnist.py:

    PYTHONPATH=src python -m repro.launch.fl_train --rounds 100 \
        --clients 100 --solver waterfill --engine batched

``--engine batched`` (default) runs local training as one jitted
vmap/scan call over the whole federation; ``--engine legacy`` restores
the seed's per-client loop (see EXPERIMENTS.md §Batched federation
engine).
"""
from examples.fl_noniid_mnist import main

if __name__ == "__main__":
    main()
