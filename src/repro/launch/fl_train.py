"""Federated-learning launcher — the paper's experiment driver (Section IV).

Thin CLI over examples/fl_noniid_mnist.py:

    PYTHONPATH=src python -m repro.launch.fl_train --rounds 100 \
        --clients 100 --solver waterfill --engine batched

``--engine batched`` (default) runs local training as one jitted
vmap/scan call over the whole federation; ``--engine legacy`` restores
the seed's per-client loop (see EXPERIMENTS.md §Batched federation
engine); ``--engine fused`` runs the ENTIRE PAOTA round on-device
(repro.fl.fused.FusedPAOTA — scheduler, eq.-25 factors, water-filling P2,
channel + power cap, AirComp, broadcast and local training as one jitted
lax.scan step; see EXPERIMENTS.md §Fused PAOTA round); ``--engine
sharded`` runs the same round scanned under ``jax.shard_map`` over the
mesh client axis (repro.fl.sharded.ShardedPAOTA — per-client stages
parallel across devices, AirComp/P2 as psums; needs a multi-device
backend, e.g. XLA_FLAGS=--xla_force_host_platform_device_count=8 on CPU;
a --clients count the devices don't divide pads with masked phantom
clients; see EXPERIMENTS.md §Sharded PAOTA round).

``--params-mode pytree`` makes the fused/sharded drivers carry the model
as its native params pytree instead of a raveled vector (EXPERIMENTS.md
§Pytree round core) — the path that places transformer/MoE client leaves
via ``repro.sharding.rules.stack_client_specs``.

``--pending-dtype bfloat16`` stores the fused/sharded carry's (K, ...)
pending/delta planes in bf16 — half the K x d working set for giant-model
clients; every reduction accumulates f32 and the globals stay f32
(EXPERIMENTS.md §Round perf).

``--group-period N`` (sharded, on a ("pod", "data") mesh from
``repro.launch.mesh.make_pod_mesh``) turns on multi-pod grouped
aggregation: intra-pod partial superpositions every period, ONE cross-pod
model-sized psum per N-period window, held partials staleness-weighted
per eq. 25 (EXPERIMENTS.md §Multi-pod grouped aggregation).

``--cohort-size m`` (fused/sharded) runs the active-cohort round: model
rows exist only for the m in-flight slots. ``--compress topk|randmask``
with ``--compress-ratio s/d`` additionally sparsifies the slot payloads
to (m, s) compressed planes with per-client error-feedback residuals
(``--no-error-feedback`` drops them), superposed by the fused
gather-superpose-decompress kernel — the dense (m, d) plane never
materializes (EXPERIMENTS.md §Compressed cohort payloads).

``--tp T`` (sharded + ``--params-mode pytree``) turns on intra-client
tensor parallelism: the mesh becomes ("pod", "data", "tp") with the tp
extent taken off the client axis, and every client replica's stacked
payload leaves TP-shard their model dims over it (per-device model-plane
carry ~1/T). The round's tree reductions psum TP partials, the AWGN
realization is drawn at full leaf shapes so every TP layout consumes the
same total noise, and the compiled program keeps exactly ONE cross-client
model-sized psum — it gathers the TP blocks in the same op
(EXPERIMENTS.md §Intra-client TP).

Fault tolerance (fused/sharded; EXPERIMENTS.md §Fault tolerance):
``--faults 'nan:0.05,start:1'`` injects counter-RNG client faults — NaN/
+Inf payload rows (``nan:``/``inf:``), Byzantine-scaled deltas (``byz:``
+ ``scale:``), deep-fade channel outliers (``fade:`` + ``gain:``), pod
blackouts in grouped sharded mode (``pods:0|2`` + ``bstart:``/
``bstop:``). ``--screen`` masks corrupt uploads out of the superposition
(per-row containment, still ONE cross-client psum) with an optional
``--screen-max-norm`` Byzantine fence; ``--divergence-factor F`` rolls
the global back to the last-good slot on a post-update norm jump beyond
F. ``--checkpoint-every N`` snapshots the FULL round carry every N
rounds (``--checkpoint-dir``); ``--resume PATH`` restores one and
continues the killed run bit-for-bit (counter RNG replays identical
streams).
"""
from examples.fl_noniid_mnist import main

if __name__ == "__main__":
    main()
