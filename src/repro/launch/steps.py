"""Distributed step builders: the PAOTA round step (train), prefill, and
decode — with in/out shardings for the production meshes.

train_step (PAOTA round, DESIGN.md §3/§4): client-stacked params (K, ...)
sharded over the client mesh axes; each client runs M local SGD steps
(lax.scan) on its own microbatches; the round ends with the AirComp
aggregation — a masked power-weighted all-reduce over the client axes with
AWGN injected at 1/varsigma scale (eqs. 6+8). Stragglers (mask=0) keep
their local params (eq. 4 semantics), exactly Algorithm 1 in SPMD form.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.aggregation import paota_aggregate_stacked
from repro.launch.mesh import client_axes_for, data_axes
from repro.launch.shapes import InputShape, shape_config
from repro.models.config import ModelConfig
from repro.models.transformer import (decode_step, forward, init_decode_state,
                                      init_model, loss_fn)
from repro.sharding.rules import (batch_specs, decode_state_specs,
                                  param_specs, stack_client_specs)


def _axis_size(mesh, axes: Tuple[str, ...]) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _named(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def runtime_config(cfg: ModelConfig, shape: Optional[InputShape] = None,
                   dtype: str = "bfloat16", remat: str = "block"):
    """Dry-run/production config: bf16 params+compute, block remat."""
    if shape is not None:
        cfg = shape_config(cfg, shape)
    return dataclasses.replace(cfg, param_dtype=dtype, compute_dtype=dtype,
                               remat=remat)


# ---------------------------------------------------------------------------
# abstract inputs (ShapeDtypeStructs — no allocation)
# ---------------------------------------------------------------------------

def abstract_params(cfg: ModelConfig, stack: int = 0):
    base = jax.eval_shape(lambda: init_model(jax.random.PRNGKey(0), cfg))
    if not stack:
        return base
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct((stack,) + s.shape, s.dtype), base)


def train_batch_struct(cfg: ModelConfig, shape: InputShape, k_clients: int,
                       local_steps: int):
    """(K, M, mb, ...) batch structs; mb = global_batch / K."""
    mb = max(shape.global_batch // max(k_clients, 1), 1)
    s = shape.seq_len
    i32 = jnp.int32
    lead = (k_clients, local_steps, mb)
    if cfg.modality == "audio":
        return {
            "frame_feats": jax.ShapeDtypeStruct(lead + (s, cfg.frontend_dim),
                                                jnp.dtype(cfg.compute_dtype)),
            "mask_indicator": jax.ShapeDtypeStruct(lead + (s,), i32),
            "targets": jax.ShapeDtypeStruct(lead + (s,), i32),
        }
    if cfg.modality == "vision_text":
        t = max(s - cfg.num_patches, 8)
        return {
            "tokens": jax.ShapeDtypeStruct(lead + (t,), i32),
            "patch_embeds": jax.ShapeDtypeStruct(
                lead + (cfg.num_patches, cfg.frontend_dim),
                jnp.dtype(cfg.compute_dtype)),
        }
    return {"tokens": jax.ShapeDtypeStruct(lead + (s,), i32)}


# ---------------------------------------------------------------------------
# PAOTA train step
# ---------------------------------------------------------------------------

def make_paota_train_step(cfg: ModelConfig, mesh, shape: InputShape, *,
                          lr: float = 1e-3, local_steps: int = 5,
                          sigma_over_varsigma: float = 1e-4,
                          client_axes: Optional[Tuple[str, ...]] = None,
                          ep_axis: Optional[str] = None,
                          seq_parallel: bool = False,
                          donate: bool = True):
    """Returns (jitted_step, in_structs, in_shardings).

    step(stacked_params, batch, powers, mask, seed) ->
        (new_stacked_params, metrics)
    """
    if client_axes is None:
        client_axes = client_axes_for(cfg, mesh)
    k = max(_axis_size(mesh, client_axes), 1)
    dp_left = tuple(a for a in data_axes(mesh) if a not in client_axes)
    # activation sharding hints (EXPERIMENTS.md §Perf iter 1): without these
    # GSPMD replicates activations inside vmap+scan.
    ep_ok = (cfg.num_experts > 0 and "data" not in client_axes
             and cfg.num_experts % mesh.shape.get("data", 1) == 0)
    cfg = dataclasses.replace(
        cfg, act_dp=dp_left,
        act_tp="model" if "model" not in client_axes else None,
        act_ep="data" if ep_ok else None,
        act_ep_size=mesh.shape.get("data", 1) if ep_ok else 0,
        seq_parallel=seq_parallel and "model" not in client_axes)

    # gradient accumulation: one local SGD step over mb sequences is
    # processed in `accum` chunks so layer-boundary activations stay
    # ~128k-tokens deep (EXPERIMENTS.md §Perf iter 3).
    mb_total = max(shape.global_batch // k, 1)
    tokens_per_step = mb_total * shape.seq_len
    accum = max(1, min(mb_total, tokens_per_step // 262144))
    while mb_total % accum:
        accum -= 1

    def local_sgd(params, mbs):
        def sgd_step(p, mb):
            if accum == 1:
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(p, mb, cfg)
                p = jax.tree_util.tree_map(
                    lambda a, b: (a - lr * b.astype(jnp.float32)).astype(a.dtype),
                    p, g)
                return p, l
            sub = jax.tree_util.tree_map(
                lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
                mb)

            def acc_body(carry, chunk):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    p, chunk, cfg)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: (a + b.astype(a.dtype)), g_acc, g)
                return (g_acc, l_acc + l), 0.0

            # bf16 accumulator: halves the accumulation buffer (the fp32
            # version alone was 12 GB/chip for llama4); loss-scale safety
            # is acceptable at accum<=8 (§Perf iter 3b)
            g0 = jax.tree_util.tree_map(
                lambda x: jnp.zeros(x.shape, jnp.bfloat16), p)
            (g_sum, l_sum), _ = jax.lax.scan(acc_body, (g0, 0.0), sub)
            p = jax.tree_util.tree_map(
                lambda a, b: (a - (lr / accum)
                              * b.astype(jnp.float32)).astype(a.dtype),
                p, g_sum)
            return p, l_sum / accum
        return jax.lax.scan(sgd_step, params, mbs)

    def step(stacked, batch, powers, mask, seed):
        new_stacked, losses = jax.vmap(local_sgd)(stacked, batch)
        # AirComp superposition via the ONE shared tree aggregation helper
        # (repro.core.aggregation) — the same per-leaf weighted reduction +
        # single flat AWGN realization the FL round core runs, with the
        # channel noise expressed at sigma = sigma_over_varsigma * varsigma
        # scale (this step's SNR knob)
        bp = (powers * mask).astype(jnp.float32)
        sigma = (sigma_over_varsigma * jnp.maximum(jnp.sum(bp), 1e-12)
                 if sigma_over_varsigma > 0 else 0.0)
        agg, varsigma = paota_aggregate_stacked(new_stacked, powers, mask,
                                                seed, sigma)

        # ready clients receive the aggregate; stragglers keep training state
        def merge(a, local):
            m = mask.reshape((k,) + (1,) * (local.ndim - 1)).astype(local.dtype)
            return m * jnp.broadcast_to(a[None], local.shape) + (1 - m) * local

        merged = jax.tree_util.tree_map(merge, agg, new_stacked)
        metrics = {"loss": jnp.mean(losses), "varsigma": varsigma,
                   "participants": jnp.sum(mask)}
        return merged, metrics

    stacked_struct = abstract_params(cfg, stack=k)
    p_specs = stack_client_specs(stacked_struct, cfg, mesh, client_axes,
                                 ep_axis=ep_axis)
    batch_s = train_batch_struct(cfg, shape, k, local_steps)
    b_specs = batch_specs(
        batch_s, dp_left,
        lead_axes=(tuple(client_axes) if client_axes else (), ()))
    vec_spec = P(None)
    in_shard = (_named(mesh, p_specs), _named(mesh, b_specs),
                _named(mesh, vec_spec), _named(mesh, vec_spec),
                _named(mesh, P(None)))

    structs = (
        abstract_params(cfg, stack=k),
        batch_s,
        jax.ShapeDtypeStruct((k,), jnp.float32),
        jax.ShapeDtypeStruct((k,), jnp.float32),
        jax.ShapeDtypeStruct((2,), jnp.uint32),
    )
    jitted = jax.jit(step, in_shardings=in_shard,
                     donate_argnums=(0,) if donate else ())
    return jitted, structs, in_shard


# ---------------------------------------------------------------------------
# prefill / decode steps
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig, mesh, shape: InputShape):
    dp = data_axes(mesh)
    ep_ok = cfg.num_experts > 0 and cfg.num_experts % mesh.shape.get("data", 1) == 0
    cfg = dataclasses.replace(cfg, act_dp=dp if shape.global_batch >= 2 else (),
                              act_tp="model", act_ep="data" if ep_ok else None,
                              act_ep_size=mesh.shape.get("data", 1) if ep_ok else 0)

    def prefill(params, batch):
        logits, aux, caches = forward(params, batch, cfg,
                                      return_cache=cfg.supports_decode)
        return logits[:, -1:, :], caches

    from repro.launch.shapes import input_specs
    specs = input_specs(cfg, shape)
    batch_struct = specs["batch"]
    p_specs = param_specs(abstract_params(cfg), cfg, mesh, ep_axis="data")
    b_specs = batch_specs(batch_struct, dp)
    in_shard = (_named(mesh, p_specs), _named(mesh, b_specs))
    structs = (abstract_params(cfg), batch_struct)
    return jax.jit(prefill, in_shardings=in_shard), structs, in_shard


def make_serve_step(cfg: ModelConfig, mesh, shape: InputShape,
                    kv_quant: bool = False):
    dp = data_axes(mesh)
    b = shape.global_batch
    # decode keeps the baseline auto-sharding: the act/EP hints measurably
    # REGRESSED decode (weights re-gathered per step; §Perf iter D refuted)
    cfg = dataclasses.replace(cfg, act_dp=(), act_tp=None, act_ep=None,
                              act_ep_size=0, kv_quant=kv_quant)

    def serve(params, tokens, state, index):
        logits, new_state = decode_step(params, tokens, state, index, cfg)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok[:, None], new_state

    state_struct = jax.eval_shape(
        lambda: init_decode_state(cfg, b, shape.seq_len))
    p_specs = param_specs(abstract_params(cfg), cfg, mesh, ep_axis="data")
    s_specs = decode_state_specs(state_struct, cfg, mesh, dp)
    tok_spec = P(dp if len(dp) != 1 else dp[0], None) if b >= 2 else P(None, None)
    in_shard = (_named(mesh, p_specs), _named(mesh, tok_spec),
                _named(mesh, s_specs), _named(mesh, P()))
    structs = (abstract_params(cfg),
               jax.ShapeDtypeStruct((b, 1), jnp.int32),
               state_struct,
               jax.ShapeDtypeStruct((), jnp.int32))
    return (jax.jit(serve, in_shardings=in_shard, donate_argnums=(2,)),
            structs, in_shard)


def build_step(cfg: ModelConfig, mesh, shape: InputShape, **kw):
    """Dispatch by shape kind. Returns (jitted, structs, shardings)."""
    cfg = runtime_config(cfg, shape)
    if shape.kind == "train":
        return make_paota_train_step(cfg, mesh, shape, **kw)
    if shape.kind == "prefill":
        return make_prefill_step(cfg, mesh, shape)
    return make_serve_step(cfg, mesh, shape, **kw)
