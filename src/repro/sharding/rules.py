"""Partition rules: name-based PartitionSpec trees for every architecture.

Strategy (baseline; §Perf iterates on it):
  * model axis ("model") = tensor parallel: attention projections sharded on
    the fused head dim, MLP on d_ff (always divisible by 16 across the
    pool), mamba2 inner dim (SSM heads), embedding/unembedding on vocab
    (GSPMD pads non-divisible vocabs).
  * expert axis: MoE expert tensors sharded over the EP axis ("data") plus
    "model" on d_ff — expert-parallel dispatch rides the all-to-all.
  * batch: ("pod","data") for sync/serving paths; in FL mode the leading
    client-stack axis takes the client axes instead.
  * decode caches: KV sharded over batch (data) and sequence ("model") —
    sequence-sharded flash-decode; SSM states sharded over SSM heads.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig


def _path_keys(path) -> Tuple[str, ...]:
    return tuple(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def _base_spec(keys: Tuple[str, ...], shape: Tuple[int, ...],
               cfg: ModelConfig, ep_axis: Optional[str],
               axis_sizes: dict, tp: Optional[str] = "model") -> tuple:
    """Spec for the TRAILING dims of one leaf (leading stack dims padded
    with None by the caller). Divisibility-aware: pjit input shardings must
    divide dims exactly, so non-divisible assignments fall back (vocab ->
    shard d instead; small expert counts -> shard expert d over the EP axis
    FSDP-style)."""
    ndim = len(shape)

    def ok(dim_from_end: int, axis) -> bool:
        if axis is None:
            return True
        size = axis_sizes.get(axis, 1)
        return shape[ndim - dim_from_end] % size == 0

    def pad(spec: tuple) -> tuple:
        spec = (None,) * (ndim - len(spec)) + spec
        # final guard: drop any non-dividing assignment
        return tuple(a if (a is None or shape[i] % axis_sizes.get(a, 1) == 0)
                     else None for i, a in enumerate(spec))

    leaf = keys[-1]
    if "moe" in keys:
        if "router" in keys:
            return pad((None, None))
        e_div = ok(3, ep_axis) if ndim >= 3 else False
        if leaf in ("gate", "up"):          # (E, d, ff)
            if e_div:
                return pad((ep_axis, None, tp))
            return pad((None, ep_axis, tp))   # FSDP d over EP axis
        if leaf == "down":                  # (E, ff, d)
            if e_div:
                return pad((ep_axis, tp, None))
            return pad((None, tp, ep_axis))
    if "mamba" in keys:
        if leaf == "in_proj":               # (d, 2*din+2gn+h)
            return pad((None, tp))
        if leaf == "conv_w":                # (K, dxbc)
            return pad((None, tp))
        if leaf == "out_proj":              # (din, d)
            return pad((tp, None))
        if leaf == "norm_scale":            # (din,)
            return pad((tp,))
        return pad(())                      # a_log/dt_bias/skip_d: replicated
    if leaf == "embed":                     # (V, d)
        if ok(2, tp):
            return pad((tp, None))
        return pad((None, tp))         # odd vocab: shard d instead
    if leaf == "unembed":                   # (d, V)
        if ok(1, tp):
            return pad((None, tp))
        return pad((tp, None))
    if ("attn" in keys or "shared_attn" in keys) and len(keys) >= 2:
        parent = keys[-2]
        if parent in ("wq", "wk", "wv"):    # (d, H*hd)
            return pad((None, tp))
        if parent == "wo":                  # (H*hd, d)
            return pad((tp, None))
    if "mlp" in keys and len(keys) >= 2:
        parent = keys[-2]
        if parent in ("gate", "up"):        # (d, ff)
            return pad((None, tp))
        if parent == "down":                # (ff, d)
            return pad((tp, None))
    # norms, projector/frontend, mask_emb, biases: replicated
    if cfg is None and tp is not None and axis_sizes.get(tp, 1) > 1:
        # structureless pytrees under an active TP axis: shard the LAST
        # tp-divisible trailing dim; nothing divides -> replicated leaf
        for i in range(ndim - 1, -1, -1):
            if shape[i] > 1 and shape[i] % axis_sizes[tp] == 0:
                return pad((None,) * i + (tp,) + (None,) * (ndim - 1 - i))
    return pad(())


def param_specs(params_shape, cfg: Optional[ModelConfig], mesh,
                ep_axis: Optional[str] = "data",
                stack_axes: Tuple = (),
                tp_axis: Optional[str] = "model") -> object:
    """PartitionSpec tree matching `params_shape` (a pytree of arrays or
    ShapeDtypeStructs). `stack_axes`: mesh axes for a leading client-stack
    dim ((), or ("data",)/("pod",)/("pod","data")).

    ``cfg=None`` is allowed for structureless pytrees (e.g. the paper's
    MLP federated as a params tree): the name-based rules still apply —
    unrecognized leaf paths simply fall through to replicated trailing
    dims, so only the leading stack axes shard."""
    ep = ep_axis if (ep_axis in mesh.axis_names) else None
    tp = tp_axis if (tp_axis in mesh.axis_names and
                     tp_axis not in stack_axes) else None
    sizes = dict(mesh.shape)
    lead = ((stack_axes if len(stack_axes) != 1 else stack_axes[0]),) \
        if stack_axes else ()

    def one(path, leaf):
        keys = _path_keys(path)
        if stack_axes:
            base = _base_spec(keys, tuple(leaf.shape[1:]), cfg, ep, sizes, tp)
            return P(*(lead + base))
        return P(*_base_spec(keys, tuple(leaf.shape), cfg, ep, sizes, tp))

    return jax.tree_util.tree_map_with_path(one, params_shape)


def stack_client_specs(params_shape, cfg: Optional[ModelConfig], mesh,
                       client_axes, ep_axis: Optional[str] = None,
                       tp_axis: Optional[str] = None):
    """Specs for client-stacked params (K, ...). Inside a client replica,
    TP over ``tp_axis`` (default: the mesh's "tp" axis when present and
    not a client axis, else the historical "model"); EP over `ep_axis`
    only if it's not a client axis. ``cfg=None``: leading client axes
    plus, under an active TP axis, the last tp-divisible trailing dim of
    each leaf (see ``param_specs``)."""
    ep = ep_axis
    if ep is None:
        ep = "data" if ("data" in mesh.axis_names
                        and "data" not in client_axes) else None
    tp = tp_axis
    if tp is None:
        tp = "tp" if ("tp" in mesh.axis_names
                      and "tp" not in client_axes) else "model"
    return param_specs(params_shape, cfg, mesh, ep_axis=ep,
                       stack_axes=tuple(client_axes), tp_axis=tp)


def batch_specs(batch_shape, dp_axes: Tuple[str, ...], lead_axes: Tuple = ()):
    """Batch pytree: leading stack dims (client K, local steps M) then the
    per-step batch dim sharded over dp_axes."""
    dp = (dp_axes if len(dp_axes) != 1 else dp_axes[0]) if dp_axes else None

    def _entry(a):
        if isinstance(a, tuple):
            if len(a) == 0:
                return None
            return a if len(a) != 1 else a[0]
        return a

    lead = tuple(_entry(a) for a in lead_axes)

    def one(leaf):
        nd = len(leaf.shape)
        spec = lead + (dp,) + (None,) * (nd - len(lead) - 1)
        return P(*spec[:nd])

    return jax.tree_util.tree_map(one, batch_shape)


def decode_state_specs(state_shape, cfg: ModelConfig, mesh,
                       dp_axes: Tuple[str, ...]):
    """KV caches (L,B,S,Hkv,hd): B over dp, S over 'model' (sequence-sharded
    flash-decode). SSM states (L,B,H,P,N): H over 'model'. conv
    (L,B,K-1,dxbc): dxbc over 'model'. Batch=1 shapes keep dp=None."""
    def one(path, leaf):
        keys = _path_keys(path)
        nd = len(leaf.shape)
        b = leaf.shape[1] if nd > 1 else 1
        dp = None
        if dp_axes and b >= 2:
            dp = dp_axes if len(dp_axes) != 1 else dp_axes[0]
        if keys[-1] in ("k", "v"):          # (L, B, S, Hkv, hd)
            return P(None, dp, "model", None, None)
        if keys[-1] in ("k_scale", "v_scale"):   # (L, B, S, Hkv)
            return P(None, dp, "model", None)
        if keys[-1] == "ssm":               # (L, B, H, P, N)
            return P(None, dp, "model", None, None)
        if keys[-1] == "conv":              # (L, B, K-1, dxbc)
            return P(None, dp, None, "model")
        return P(*((None,) * nd))

    return jax.tree_util.tree_map_with_path(one, state_shape)
