from repro.sharding.rules import (batch_specs, decode_state_specs,  # noqa: F401
                                  param_specs, stack_client_specs)
