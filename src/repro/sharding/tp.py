"""Intra-client tensor-parallel topology for the sharded PAOTA round.

The full production mesh is pods x clients x TP: the ("pod", "data")
axes shard the FEDERATION (each device group owns K_local clients) while
the "tp" axis shards each client's MODEL STORAGE — every stacked payload
leaf (pending / deltas, shape (K_local, ...)) keeps one trailing dim
split over the TP axis, so the per-device model-plane bytes drop ~1/TP.

Storage-parallel, compute-replicated: the globals stay replicated over
the TP axis and local training runs identically on every TP shard (full
leaves from the replicated global); only the carry WRITES slice the
trained leaves down to the shard's TP-local block. The round's tree
reductions then become TP-aware:

  * round stats (dots / norms) are computed on the TP-local blocks
    against a TP-sliced global direction and psum'd once over the TP
    axes (TP-replicated leaves — norms, biases, any non-dividing dim —
    are accumulated outside that psum so they count exactly once);
  * the AirComp superposition stays ONE model-sized psum: each TP shard
    embeds its local block at its position in the FULL flattened model
    vector (zeros elsewhere, TP-replicated leaves masked to the lead
    shard) and a single psum over clients x TP axes performs the
    cross-client sum and the TP gather simultaneously;
  * the AWGN draw is a function of the MODEL, not the layout: noise is
    drawn at the FULL leaf shapes from the replicated round key and
    added after that psum, so every TP extent consumes the same total
    noise and TP extent 1 is bit-identical to the flat program (the TP
    branches vanish at trace time when no topology is passed).

``TPTopology`` is a static (hashable) description threaded through
``paota_round_step`` exactly like the grouped ``GroupTopology``; the
sharded driver derives ``leaf_dims`` from the computed pend_spec tree so
slicing and GSPMD placement can never disagree.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class TPTopology(NamedTuple):
    """Static intra-client TP description (trace-time constant).

    axes:      mesh axis names the model storage is sharded over.
    extents:   mesh extent of each axis (same order).
    shards:    product of extents (> 1 when the topology is active).
    leaf_dims: per tree_flatten leaf of the params tree, the UNSTACKED
               trailing-dim index sharded over the TP axes, or -1 for a
               TP-replicated leaf (no trailing dim divides).
    """
    axes: Tuple[str, ...]
    extents: Tuple[int, ...]
    shards: int
    leaf_dims: Tuple[int, ...]


def tp_linear_index(tp: TPTopology):
    """Row-major linear index of this device along the TP axes — matches
    GSPMD's split order when a dim is sharded over the axis tuple."""
    idx = jnp.int32(0)
    for a, n in zip(tp.axes, tp.extents):
        idx = idx * n + jax.lax.axis_index(a)
    return idx


def tp_slice(leaf, dim: int, tp: TPTopology):
    """This shard's TP-local block of a TP-replicated full leaf, along
    ``dim``. ``leaf.shape[dim]`` must be divisible by ``tp.shards`` (the
    spec builder guarantees it for every sharded leaf)."""
    size = leaf.shape[dim] // tp.shards
    return jax.lax.dynamic_slice_in_dim(
        leaf, tp_linear_index(tp) * size, size, axis=dim)


def tp_mask_lead(x, tp: TPTopology):
    """Zero ``x`` on every TP shard except linear index 0 — so a psum
    over the TP axes counts a TP-replicated partial exactly once (an
    exact sum of x and zeros, no 1/shards rounding)."""
    return jnp.where(tp_linear_index(tp) == 0, x, jnp.zeros_like(x))


def tp_full_structs(stacked_leaves, tp: TPTopology):
    """Full-model ShapeDtypeStructs for TP-local stacked leaves: each
    sharded leaf's TP dim (stacked position ``leaf_dims[i] + 1``) scaled
    back up by ``tp.shards``. Shape-only stand-ins for the noise draw and
    the finalize split — f32, matching the aggregation accumulator."""
    out = []
    for leaf, dim in zip(stacked_leaves, tp.leaf_dims):
        shape = list(leaf.shape)
        if dim >= 0:
            shape[dim + 1] *= tp.shards
        out.append(jax.ShapeDtypeStruct(tuple(shape), jnp.float32))
    return out
