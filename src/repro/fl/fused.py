"""Fused on-device PAOTA: the whole aggregation period as ONE jitted
round step, scanned over R rounds in a single device call.

The host-path ``PAOTAServer`` (repro.fl.server) makes ~8 host<->device
round-trips through numpy per period — scheduler advance, rho/theta
factors, P2 solve, channel draw, power cap (7), AirComp — which caps
simulation throughput far below hardware speed at K = 1000+. Here every
stage is pure jnp over array state: the round transition itself lives in
``repro.fl.runtime.paota_round_step`` (``RoundCarry`` in, ``RoundCarry``
out — one functional core shared with the mesh-sharded driver
``repro.fl.sharded.ShardedPAOTA``), and this driver runs it single-device
with ``lax.scan`` over R rounds and zero host round-trips inside the scan.

Randomness is counter-based (repro.core.scheduler.round_tag_key): latency,
channel, noise, and minibatch draws are keyed on (seed, round, tag), never
on sequential stream state. The host server run with ``PAOTAConfig(
rng="counter", solver="waterfill_jnp")`` + ``SchedulerConfig(
rng="counter")`` consumes identical draws, which is what makes the two
implementations allclose-comparable round for round
(tests/test_fused_round.py). Relative to the default host configuration
the counter scheme is a *statistical* change only (same distributions,
different streams; minibatches are drawn i.i.d. uniform rather than
epoch-shuffled) — see EXPERIMENTS.md §Fused PAOTA round.
"""
from __future__ import annotations

import os
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import io as ckpt_io
from repro.core.aircomp import ChannelConfig, sample_channel_gains
from repro.core.aggregation import ravel
from repro.core.power_control import p2_constants
from repro.core.compress import randmask_indices
from repro.core.scheduler import (TAG_CHANNEL, TAG_COMPRESS, TAG_NOISE,
                                  TAG_QUANT, TAG_SCHED, FaultConfig,
                                  SchedulerConfig, counter_latencies,
                                  fault_channel_mask, fault_payload_masks,
                                  inject_payload_faults, round_tag_key,
                                  scenario_hyperparams, scenario_latencies,
                                  scenario_masks)
from repro.fl.engine import BatchedEngine, make_engine
from repro.fl.runtime import (RoundCarry, RoundCfg, RoundStreams,
                              init_cohort_carry, init_round_carry,
                              scan_rounds)
from repro.fl.server import PAOTAConfig

__all__ = ["FusedPAOTA", "RoundCarry"]


class FusedPAOTA:
    """PAOTA server whose round is one jitted device call.

    Same constructor shape as ``PAOTAServer``; requires the batched engine
    (the legacy per-client loop cannot live inside jit). ``advance(n)``
    runs n rounds as a single ``lax.scan``; ``round()`` is the one-round
    convenience for drop-in use in the existing drivers.

    RNG contract: the on-device scan ALWAYS runs counter-based streams —
    ``cfg.rng`` / ``sched_cfg.rng`` are ignored (host-mode sequential
    PCG64 cursors cannot live inside a scan step), so switching a host
    server with default (host-RNG) configs to this driver changes the
    random trajectory statistically, never silently mid-run. The host
    server must be EXPLICITLY put in counter mode to serve as this
    driver's draw-identical reference.

    ``params_mode``: ``"raveled"`` (default) carries the model as the
    historical flat (d,) vector / (K, d) stack — bit-identical to every
    prior release; ``"pytree"`` carries the params pytree natively (the
    round core is tree-generic, repro.fl.runtime), which is what lets the
    sharded driver place transformer/MoE client leaves on real meshes.
    The two modes consume identical RNG draws (one flat AWGN realization
    split across leaves) and agree allclose round for round — float
    reduction regrouping across leaves is the only difference
    (tests/test_pytree_round.py).

    ``pending_dtype="bfloat16"`` stores the carry's (K, ...) planes
    (pending models + their deltas) in bf16 — half the K x d working set;
    every reduction still accumulates f32 and the globals stay f32.
    ``donate=False`` disables carry donation into the scan (the default
    donates; kept as a flag for the donation-safety equivalence test).

    ``cohort_size=m`` switches the carry to the active-cohort layout: at
    most m clients in flight, model-sized rows for those m slots only —
    the (K,) scheduler/scenario state plane stays dense and tiny, so the
    carry footprint stops scaling as K x d (``None``/0 keeps the dense
    carry, bit-identical to prior releases). ``scenario`` (a
    ``repro.core.scheduler.ScenarioConfig``) runs the vectorized
    client-state simulator — availability cycles, dropouts, lognormal
    responsiveness, per-client local-step/batch heterogeneity — entirely
    inside the scan from the scheduler's counter-RNG streams; the default
    ``ScenarioConfig()`` is the identity scenario (bit-identical to
    ``scenario=None``).

    ``compress="topk"|"randmask"`` (requires ``cohort_size`` +
    ``transmit='delta'`` + raveled params) shrinks each slot row to the
    s = round(d * ``compress_ratio``) compressed plane: per-slot supports,
    error-feedback residuals handed off through a (K, s) parked plane on
    slot turnover (``error_feedback=False`` drops both residual planes),
    and ``slot_dtype`` storage for the values ("int8" = per-row absmax +
    unbiased stochastic rounding; default = ``pending_dtype``). AirComp
    decompresses inside the gather-superpose kernel — the dense (m, d)
    plane never enters the carry. ``compress=None`` (default) and the
    s = d identity compression are bit-identical to the uncompressed
    cohort program.

    Fault tolerance (all off by default — the compiled program is then
    op-for-op the historical one): ``faults`` (a ``repro.core.scheduler
    .FaultConfig``) injects NaN/Inf payload rows, Byzantine-scaled
    deltas, and deep-fade channel outliers from the counter-RNG
    ``TAG_FAULT`` streams (pod blackouts need the grouped sharded
    driver); ``screen`` masks non-finite (and, with ``screen_max_norm``,
    over-norm) uploads out of the superposition like phantom clients;
    ``divergence_factor`` arms the post-update rollback to the carry's
    last-good global; ``checkpoint_every=N`` + ``checkpoint_dir``
    snapshots the full carry every N rounds (``save_checkpoint`` /
    ``restore_checkpoint`` — resume is bit-exact thanks to counter RNG).
    """

    def __init__(self, init_params, clients, chan: ChannelConfig,
                 sched_cfg: SchedulerConfig, cfg: PAOTAConfig, *,
                 params_mode: str = "raveled",
                 pending_dtype: str = "float32", donate: bool = True,
                 cohort_size: int | None = None, scenario=None,
                 compress: str | None = None, compress_ratio: float = 1.0,
                 slot_dtype: str | None = None,
                 error_feedback: bool = True, faults: FaultConfig | None = None,
                 screen: bool = False, screen_max_norm: float = 0.0,
                 divergence_factor: float = 0.0, checkpoint_every: int = 0,
                 checkpoint_dir: str | None = None):
        if params_mode not in ("raveled", "pytree"):
            raise ValueError(f"params_mode={params_mode!r} (expected "
                             "'raveled' or 'pytree')")
        if pending_dtype not in ("float32", "bfloat16"):
            raise ValueError(f"pending_dtype={pending_dtype!r} (expected "
                             "'float32' or 'bfloat16')")
        self.params_mode = params_mode
        if cfg.use_kernel:
            raise ValueError("use_kernel routes through the host-path "
                             "server; the fused round is already one fused "
                             "device call")
        if cfg.solver not in ("waterfill", "waterfill_jnp"):
            raise ValueError(f"{type(self).__name__} solves P2 with the jnp "
                             f"water-filling solver only; solver="
                             f"{cfg.solver!r} needs the host-path server")
        engine = make_engine(clients, cfg.engine)
        if not isinstance(engine, BatchedEngine):
            raise ValueError(f"{type(self).__name__} requires the batched "
                             "engine")
        self.engine = engine
        self.chan = chan
        self.sched_cfg = sched_cfg
        self.cfg = cfg
        vec, self.unravel = ravel(init_params)
        self._init_vec = jnp.asarray(vec, jnp.float32)
        if params_mode == "pytree":
            self._init_global = jax.tree_util.tree_map(jnp.asarray,
                                                       init_params)
        else:
            self._init_global = self._init_vec
        self.d = int(vec.size)
        self.k = engine.n_clients
        self.scenario = scenario
        self.cohort_size = int(cohort_size) if cohort_size else 0
        if self.cohort_size and not 1 <= self.cohort_size <= self.k:
            raise ValueError(f"cohort_size={self.cohort_size} must lie in "
                             f"[1, K={self.k}]")
        self.compress = compress or ""
        if self.compress not in ("", "topk", "randmask"):
            raise ValueError(f"compress={compress!r} (expected None, 'topk' "
                             "or 'randmask')")
        sd = slot_dtype or ""
        if sd not in ("", "float32", "bfloat16", "int8"):
            raise ValueError(f"slot_dtype={slot_dtype!r} (expected None, "
                             "'float32', 'bfloat16' or 'int8')")
        if sd and not self.compress:
            raise ValueError("slot_dtype is compressed-slot storage; pass "
                             "compress='topk' or 'randmask' (the dense "
                             "carry's storage knob is pending_dtype)")
        self.compress_s = 0
        if self.compress:
            if not self.cohort_size:
                raise ValueError("compress needs active-cohort mode: pass "
                                 "cohort_size=m — the compressed (m, s) "
                                 "plane IS the cohort slot payload")
            if cfg.transmit != "delta":
                raise ValueError("compress rides transmit='delta': "
                                 "sparsifying full model vectors w_k makes "
                                 "no sense — compression targets the small "
                                 "local-update deltas")
            if params_mode != "raveled":
                raise NotImplementedError(
                    "compress + params_mode='pytree' is not wired yet (the "
                    "compressed plane needs per-leaf supports); use "
                    "params_mode='raveled'")
            if not 0.0 < compress_ratio <= 1.0:
                raise ValueError(f"compress_ratio={compress_ratio} (expected "
                                 "0 < ratio <= 1, the kept fraction s/d)")
            self.compress_s = min(self.d,
                                  max(1, int(round(self.d * compress_ratio))))
        if faults is not None and not isinstance(faults, FaultConfig):
            raise ValueError(f"faults={faults!r} (expected a FaultConfig "
                             "or None)")
        self.faults = faults
        if faults is not None and faults.has_blackout:
            grouping = getattr(self, "_grouping", None)
            if grouping is None:
                raise NotImplementedError(
                    f"pod_blackout={faults.pod_blackout} needs the grouped "
                    f"sharded driver (pods are a mesh topology): the nearest "
                    f"supported configuration is ShardedPAOTA with "
                    f"group_period >= 1 and pod_axes covering "
                    f"{len(faults.pod_blackout)}+ pods")
            n_pods = getattr(self, "n_pod_groups", 1)
            bad = [int(p) for p in faults.pod_blackout if int(p) >= n_pods]
            if bad:
                raise ValueError(
                    f"pod_blackout={faults.pod_blackout}: pods {bad} do not "
                    f"exist (the mesh's pod axes index {n_pods} pods)")
        if screen_max_norm < 0.0:
            raise ValueError(f"screen_max_norm={screen_max_norm} (expected "
                             ">= 0; 0 = finite-only screening)")
        if screen_max_norm > 0.0 and not screen:
            raise ValueError("screen_max_norm is the screening norm fence; "
                             "pass screen=True to enable it")
        if divergence_factor < 0.0:
            raise ValueError(f"divergence_factor={divergence_factor} "
                             "(expected >= 0; 0 = detector off)")
        self.checkpoint_every = int(checkpoint_every or 0)
        if self.checkpoint_every < 0:
            raise ValueError(f"checkpoint_every={checkpoint_every} "
                             "(expected >= 0; 0 = no periodic snapshots)")
        if self.checkpoint_every and not checkpoint_dir:
            raise ValueError("checkpoint_every without checkpoint_dir: pass "
                             "the directory the periodic snapshots go to")
        self.checkpoint_dir = checkpoint_dir
        c1, c0 = p2_constants(cfg.smooth_l, cfg.eps_bound, self.k, self.d,
                              chan.sigma_n2)
        # chan.sigma_n is a concrete float (jnp.sqrt is not callable through
        # float() in-trace), so the whole RoundCfg stays static
        self._rcfg = RoundCfg(omega=cfg.omega, c1=c1, c0=c0,
                              p_max_watts=chan.p_max_watts,
                              sigma_n=chan.sigma_n,
                              delta_t=sched_cfg.delta_t,
                              transmit_delta=cfg.transmit == "delta",
                              pending_dtype=pending_dtype,
                              cohort_size=self.cohort_size,
                              compress=self.compress,
                              compress_s=self.compress_s,
                              slot_dtype=((sd or pending_dtype)
                                          if self.compress else ""),
                              error_feedback=bool(error_feedback
                                                  and self.compress),
                              screen=bool(screen),
                              screen_max_norm=float(screen_max_norm),
                              divergence_factor=float(divergence_factor))
        self._lat_key = jax.random.PRNGKey(sched_cfg.seed)
        self._srv_key = jax.random.PRNGKey(cfg.seed)
        engine.enable_counter_plan(self._srv_key)
        if scenario is not None and (scenario.het_steps or
                                     scenario.het_batch):
            # static per-client hyperparameter traits, drawn once from the
            # scheduler's trait stream and installed on the engine
            steps_k, batch_k = scenario_hyperparams(self._lat_key, self.k,
                                                    scenario)
            engine.set_heterogeneity(steps_k, batch_k)
        self._carry: RoundCarry | None = None
        self.history: List[dict] = []
        self._jit_init = jax.jit(self._init_carry)
        # the round carry is DONATED into the scan: advance() hands its
        # K x d planes (pending/deltas stacks) back to XLA for in-place
        # reuse instead of holding them alive across the call boundary —
        # self._carry is rebound to the scan's output, so the donated
        # buffers are never read again (donate=False exists for the
        # donation-safety equivalence test)
        self._jit_scan = jax.jit(self._run_scan, static_argnames=("n_rounds",),
                                 donate_argnums=(0,) if donate else ())

    # ------------------------------------------------------------------
    # jitted pieces
    # ------------------------------------------------------------------
    def _local_train_all(self, global_state, x, y, broadcast_round):
        """All K clients run M local SGD steps from the current global
        model with the counter minibatch plan of `broadcast_round`.
        Raveled mode: (d,) vector in, (K, d) stack out; pytree mode: the
        params tree in, client-stacked tree out (same SGD ops — ravel is
        the only difference)."""
        idx = self.engine.round_plan(broadcast_round)
        steps = self.engine.steps_for()
        if self.params_mode == "pytree":
            return self.engine._train_all_tree(global_state, x, y, idx,
                                               steps)
        params = self.unravel(global_state)
        return self.engine._train_all(params, x, y, idx, steps)

    def _cohort_train(self, global_state, x, y, broadcast_round, ids):
        """Cohort twin of ``_local_train_all``: gather the (m,) scheduled
        clients' data rows and train ONLY those — each client's minibatch
        plan / heterogeneity traits key on its global id, so a client's
        trained row is identical whichever slot (or dense row) computes
        it."""
        ids = ids.astype(jnp.uint32)
        idx = self.engine.round_plan(broadcast_round, client_ids=ids,
                                     n_samples=self.engine._n_dev[ids])
        steps = self.engine.steps_for(ids)
        xs, ys = x[ids], y[ids]
        if self.params_mode == "pytree":
            return self.engine._train_all_tree(global_state, xs, ys, idx,
                                               steps)
        return self.engine._train_all(self.unravel(global_state), xs, ys,
                                      idx, steps)

    def _faulty_local_train(self, global_state, x, y, broadcast_round):
        """``_local_train_all`` with the round's payload faults injected:
        the corrupt rows are what the uplink would carry, so screening and
        the aggregate guards see exactly what a broken client emits."""
        trained = self._local_train_all(global_state, x, y, broadcast_round)
        nm, bm = fault_payload_masks(self._lat_key, broadcast_round, self.k,
                                     self.faults)
        rows = jax.tree_util.tree_leaves(trained)[0].shape[0]
        if rows > self.k:
            # sharded round-0 init runs these full-federation streams on
            # the phantom-padded engine arrays: phantoms never fault
            pad = jnp.zeros((rows - self.k,), bool)
            nm, bm = jnp.concatenate([nm, pad]), jnp.concatenate([bm, pad])
        return inject_payload_faults(trained, global_state, nm, bm,
                                     self.faults)

    def _faulty_cohort_train(self, global_state, x, y, broadcast_round, ids):
        """Cohort twin: masks are drawn full-K and gathered by the slots'
        GLOBAL client ids, so whether a client trains in a dense row or a
        cohort slot it suffers the identical fault realization."""
        trained = self._cohort_train(global_state, x, y, broadcast_round, ids)
        nm, bm = fault_payload_masks(self._lat_key, broadcast_round, self.k,
                                     self.faults)
        ids = ids.astype(jnp.uint32)
        return inject_payload_faults(trained, global_state, nm[ids], bm[ids],
                                     self.faults)

    def _faulty_channel(self, base_channel):
        """Channel stream with the deep-fade outliers applied: faded rows
        keep their draw scaled by ``deep_fade_gain`` — cap (7) then pushes
        their transmit power toward zero."""
        fc = self.faults

        def channel(t):
            h = base_channel(t)
            fade = fault_channel_mask(self._lat_key, t, self.k, fc)
            return jnp.where(fade, h * jnp.float32(fc.deep_fade_gain), h)
        return channel

    def _streams(self) -> RoundStreams:
        """Single-device streams: callbacks see the whole federation, so
        the round core's (K,) rows are the global client set. The scenario
        mask callback stays None unless the scenario can actually mask —
        the round core's dense program is then untouched at trace time
        (and the fault wrappers only exist when their fraction is > 0)."""
        sc = self.scenario
        if sc is None:
            lat = lambda r: counter_latencies(
                self._lat_key, r, self.k, self.sched_cfg.lat_lo,
                self.sched_cfg.lat_hi)
        else:
            # "uniform" responsiveness delegates to counter_latencies
            # verbatim inside scenario_latencies — bit-identical draws
            lat = lambda r: scenario_latencies(
                self._lat_key, r, self.k, self.sched_cfg.lat_lo,
                self.sched_cfg.lat_hi, sc)
        scen = None
        if sc is not None and sc.has_masks:
            scen = lambda t: scenario_masks(self._lat_key, t, self.k, sc)
        cohort_train = sched_priority = None
        if self.cohort_size:
            cohort_train = self._cohort_train
            sched_priority = lambda r: jax.random.uniform(
                round_tag_key(self._lat_key, r, TAG_SCHED), (self.k,))
        compress_mask = quant_key = None
        if self.compress == "randmask" and self.compress_s < self.d:
            compress_mask = lambda r: randmask_indices(
                round_tag_key(self._srv_key, r, TAG_COMPRESS), self.d,
                self.compress_s)
        if self._rcfg.slot_dtype == "int8":
            quant_key = lambda r: round_tag_key(self._srv_key, r, TAG_QUANT)
        fc = self.faults
        local_train = self._local_train_all
        if fc is not None and fc.has_payload_faults:
            local_train = self._faulty_local_train
            if cohort_train is not None:
                cohort_train = self._faulty_cohort_train
        channel = lambda t: sample_channel_gains(
            round_tag_key(self._srv_key, t, TAG_CHANNEL), self.k, self.chan)
        if fc is not None and fc.has_channel_faults:
            channel = self._faulty_channel(channel)
        return RoundStreams(
            local_train=local_train,
            latencies=lat,
            channel=channel,
            noise_key=lambda t: round_tag_key(self._srv_key, t, TAG_NOISE),
            scenario=scen,
            cohort_train=cohort_train,
            sched_priority=sched_priority,
            compress_mask=compress_mask,
            quant_key=quant_key,
        )

    def _init_carry(self, vec, x, y) -> RoundCarry:
        # transmit='delta' never reads the full local models: the carry is
        # the delta plane alone (half the K x d working set)
        if self.cohort_size:
            return init_cohort_carry(
                vec, x, y, streams=self._streams(), k=self.k,
                m=self.cohort_size,
                pending_dtype=self._rcfg.pending_dtype,
                keep_pending=not self._rcfg.transmit_delta,
                rcfg=self._rcfg)
        return init_round_carry(vec, x, y, streams=self._streams(),
                                pending_dtype=self._rcfg.pending_dtype,
                                keep_pending=not self._rcfg.transmit_delta,
                                rcfg=self._rcfg)

    def _run_scan(self, carry: RoundCarry, x, y, n_rounds: int):
        return scan_rounds(carry, x, y, n_rounds, rcfg=self._rcfg,
                           streams=self._streams(), axis_name=None)

    # ------------------------------------------------------------------
    # host-facing API (PAOTAServer-compatible)
    # ------------------------------------------------------------------
    @property
    def global_vec(self) -> np.ndarray:
        """Raveled view of w_g^t (np) — pytree-mode globals ravel on
        demand in the params' tree_flatten order, so the two modes are
        directly comparable."""
        carry = self._carry
        g = self._init_global if carry is None else carry.global_vec
        if self.params_mode == "pytree":
            g = ravel(g)[0]
        return np.asarray(g)

    def global_params(self):
        g = self._init_global if self._carry is None else self._carry.global_vec
        return g if self.params_mode == "pytree" else self.unravel(g)

    # ------------------------------------------------------------------
    # checkpoint / resume (bit-exact: counter RNG keys every draw on the
    # carry's own round index, so a restored carry replays the identical
    # stream the uninterrupted run would have consumed)
    # ------------------------------------------------------------------
    def _ensure_carry(self):
        if self._carry is None:
            self._carry = self._jit_init(self._init_global, self.engine._x,
                                         self.engine._y)
        return self._carry

    def save_checkpoint(self, path: str):
        """Snapshot the FULL round carry (every plane: globals, pending /
        delta stacks, cohort slots, compressed residuals, held partials,
        rollback slot) plus the history, raw-bytes bit-exact
        (``repro.checkpoint.io``). Builds the round-0 carry first if the
        driver has not advanced yet."""
        carry = self._ensure_carry()
        ckpt_io.save_checkpoint(path, jax.device_get(carry),
                                step=len(self.history),
                                extra={"history": self.history})

    def restore_checkpoint(self, path: str):
        """Rebind the driver to a snapshot: the carry planes restore
        bit-exactly against the live carry's own structure/dtypes (a
        layout mismatch — different cohort/compress/grouped planes — is an
        error), the history replaces this driver's, and the next
        ``advance`` continues the killed run bit-for-bit."""
        template = self._ensure_carry()
        carry, step, extra = ckpt_io.load_checkpoint(path, template)
        self._carry = carry
        self.history = list(extra.get("history", []))
        return step

    def _checkpoint_path(self, round_idx: int) -> str:
        return os.path.join(self.checkpoint_dir, f"round_{round_idx:06d}.npz")

    def advance(self, n_rounds: int) -> List[dict]:
        """Run ``n_rounds`` PAOTA rounds; appends and returns the per-round
        history dicts. ``checkpoint_every=N`` splits the scan at every
        N-round boundary and snapshots the carry there (the chunked scan
        consumes the identical counter-RNG streams, so checkpointing never
        perturbs the trajectory)."""
        every = self.checkpoint_every
        if not every:
            return self._advance(n_rounds)
        rows: List[dict] = []
        done = 0
        while done < n_rounds:
            at = len(self.history)
            step = min(every - at % every, n_rounds - done)
            rows.extend(self._advance(step))
            done += step
            if len(self.history) % every == 0:
                self.save_checkpoint(self._checkpoint_path(len(self.history)))
        return rows

    def _advance(self, n_rounds: int) -> List[dict]:
        """One uninterrupted ``lax.scan`` device call of ``n_rounds``."""
        self._ensure_carry()
        self._carry, outs = self._jit_scan(self._carry, self.engine._x,
                                           self.engine._y, n_rounds=n_rounds)
        outs = {k: np.asarray(v) for k, v in outs.items()}
        base = len(self.history)
        rows = [{"round": base + i,
                 "time": float(outs["time"][i]),
                 "n_participants": int(outs["n_participants"][i]),
                 "mean_staleness": float(outs["mean_staleness"][i]),
                 "beta_mean": float(outs["beta_mean"][i]),
                 "varsigma": float(outs["varsigma"][i]),
                 "p2_objective": float(outs["p2_objective"][i]),
                 "n_screened": float(outs["n_screened"][i]),
                 "rolled_back": float(outs["rolled_back"][i])}
                for i in range(n_rounds)]
        self.history.extend(rows)
        return rows

    def round(self) -> dict:
        """One round (drop-in for PAOTAServer.round — one device call of a
        length-1 scan; use ``advance`` to amortize over many rounds)."""
        return self.advance(1)[-1]
