"""Fused on-device PAOTA: the whole aggregation period as ONE jitted
round step, scanned over R rounds in a single device call.

The host-path ``PAOTAServer`` (repro.fl.server) makes ~8 host<->device
round-trips through numpy per period — scheduler advance, rho/theta
factors, P2 solve, channel draw, power cap (7), AirComp — which caps
simulation throughput far below hardware speed at K = 1000+. Here every
stage is pure jnp over array state:

  carry = (t, time, ready, busy_until, model_round,
           w_g, w_g_prev, pending models, pending starts)

  round_step(carry):
    1. scheduler advance   — repro.core.scheduler.sched_advance
    2. rho/theta factors   — staleness_factor / cosine similarity (eq. 25)
    3. P2 water-filling    — repro.core.boxqp.waterfill_beta_jnp
    4. channel + cap (7)   — sample_channel_gains / effective_power_cap
    5. AirComp (eqs. 6+8)  — masked weighted sum + AWGN / normalizer
    6. zero-uploader guard — guarded_global_update (lax.select: hold w_g
                             when the normalizer is at the clamp)
    7. broadcast + local train — counter minibatch plans + the batched
                             engine's vmap/scan SGD, masked into `pending`

and ``lax.scan`` drives R rounds with zero host round-trips inside the
scan.

Randomness is counter-based (repro.core.scheduler.round_tag_key): latency,
channel, noise, and minibatch draws are keyed on (seed, round, tag), never
on sequential stream state. The host server run with ``PAOTAConfig(
rng="counter", solver="waterfill_jnp")`` + ``SchedulerConfig(
rng="counter")`` consumes identical draws, which is what makes the two
implementations allclose-comparable round for round
(tests/test_fused_round.py). Relative to the default host configuration
the counter scheme is a *statistical* change only (same distributions,
different streams; minibatches are drawn i.i.d. uniform rather than
epoch-shuffled) — see EXPERIMENTS.md §Fused PAOTA round.
"""
from __future__ import annotations

from typing import List, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aircomp import (VARSIGMA_MIN, ChannelConfig,
                                effective_power_cap, sample_channel_gains)
from repro.core.aggregation import (guarded_global_update,
                                    paota_aggregate_stacked, ravel)
from repro.core.boxqp import waterfill_beta_jnp
from repro.core.power_control import (cosine_similarity, p2_constants,
                                      power_from_beta, similarity_factor,
                                      staleness_factor)
from repro.core.scheduler import (TAG_CHANNEL, TAG_NOISE, SchedulerConfig,
                                  counter_latencies, round_tag_key,
                                  sched_advance, sched_broadcast)
from repro.fl.engine import BatchedEngine, make_engine
from repro.fl.server import PAOTAConfig


class RoundCarry(NamedTuple):
    """Device-resident PAOTA state threaded through the scan."""
    t: jnp.ndarray            # i32 — scheduler round counter
    time: jnp.ndarray         # f32 — simulated clock (seconds)
    ready: jnp.ndarray        # (K,) bool — b_k at the aggregation slot
    busy_until: jnp.ndarray   # (K,) f32 — local-training completion times
    model_round: jnp.ndarray  # (K,) i32 — round each client trains on
    global_vec: jnp.ndarray   # (d,) — w_g^t
    prev_global: jnp.ndarray  # (d,) — w_g^{t-1} (similarity direction)
    pending: jnp.ndarray      # (K, d) — in-flight trained local models
    starts: jnp.ndarray       # (K, d) — the global each was trained from


class FusedPAOTA:
    """PAOTA server whose round is one jitted device call.

    Same constructor shape as ``PAOTAServer``; requires the batched engine
    (the legacy per-client loop cannot live inside jit). ``advance(n)``
    runs n rounds as a single ``lax.scan``; ``round()`` is the one-round
    convenience for drop-in use in the existing drivers.
    """

    def __init__(self, init_params, clients, chan: ChannelConfig,
                 sched_cfg: SchedulerConfig, cfg: PAOTAConfig):
        if cfg.use_kernel:
            raise ValueError("use_kernel routes through the host-path "
                             "server; the fused round is already one fused "
                             "device call")
        if cfg.solver not in ("waterfill", "waterfill_jnp"):
            raise ValueError(f"FusedPAOTA solves P2 with the jnp "
                             f"water-filling solver only; solver="
                             f"{cfg.solver!r} needs the host-path server")
        engine = make_engine(clients, cfg.engine)
        if not isinstance(engine, BatchedEngine):
            raise ValueError("FusedPAOTA requires the batched engine")
        self.engine = engine
        self.chan = chan
        self.sched_cfg = sched_cfg
        self.cfg = cfg
        vec, self.unravel = ravel(init_params)
        self._init_vec = jnp.asarray(vec, jnp.float32)
        self.d = int(vec.size)
        self.k = engine.n_clients
        self._c1, self._c0 = p2_constants(cfg.smooth_l, cfg.eps_bound,
                                          self.k, self.d, chan.sigma_n2)
        self._sigma_n = chan.sigma_n   # concrete float (jnp.sqrt is not
                                       # callable through float() in-trace)
        self._lat_key = jax.random.PRNGKey(sched_cfg.seed)
        self._srv_key = jax.random.PRNGKey(cfg.seed)
        engine.enable_counter_plan(self._srv_key)
        self._carry: RoundCarry | None = None
        self.history: List[dict] = []
        self._jit_init = jax.jit(self._init_carry)
        self._jit_scan = jax.jit(self._run_scan, static_argnames=("n_rounds",))

    # ------------------------------------------------------------------
    # jitted pieces
    # ------------------------------------------------------------------
    def _local_train_all(self, global_vec, x, y, broadcast_round):
        """All K clients run M local SGD steps from `global_vec` with the
        counter minibatch plan of `broadcast_round`. (K, d) raveled."""
        idx = self.engine.round_plan(broadcast_round)
        params = self.unravel(global_vec)
        return self.engine._train_all(params, x, y, idx)

    def _latency(self, broadcast_round):
        return counter_latencies(self._lat_key, broadcast_round, self.k,
                                 self.sched_cfg.lat_lo, self.sched_cfg.lat_hi)

    def _init_carry(self, vec, x, y) -> RoundCarry:
        """Round-0 kick-off: broadcast w_g^0 to everyone and precompute
        their local training (mirrors PAOTAServer.__init__)."""
        pending = self._local_train_all(vec, x, y, 0)
        return RoundCarry(
            t=jnp.int32(0),
            time=jnp.float32(0.0),
            ready=jnp.zeros((self.k,), bool),
            busy_until=self._latency(0),
            model_round=jnp.zeros((self.k,), jnp.int32),
            global_vec=vec,
            prev_global=vec,
            pending=pending,
            starts=jnp.broadcast_to(vec, (self.k, self.d)),
        )

    def _step(self, carry: RoundCarry, x, y):
        cfg, chan, sc = self.cfg, self.chan, self.sched_cfg

        # 1. scheduler advance: who finished inside this period, staleness.
        # The slot clock is recomputed as (t+1) * delta_t rather than
        # accumulated +=, so the float32 clock cannot drift from the host
        # reference's float64 one over long scans (a `busy_until <= time`
        # boundary flip would silently fork the trajectories; a residual
        # single-rounding difference remains for delta_t values inexact in
        # float32)
        time = (carry.t + 1).astype(jnp.float32) * jnp.float32(sc.delta_t)
        ready, stal = sched_advance(carry.ready, carry.busy_until,
                                    carry.model_round, time, carry.t)
        b = ready.astype(jnp.float32)
        stal = stal.astype(jnp.float32)

        # 2. staleness + gradient-similarity factors (eq. 25)
        deltas = carry.pending - carry.starts
        gdir = carry.global_vec - carry.prev_global
        gnorm = jnp.sqrt(jnp.sum(gdir * gdir))
        cos = jnp.where(gnorm < 1e-12, 0.0, cosine_similarity(deltas, gdir))
        theta = similarity_factor(cos)
        rho = staleness_factor(stal, cfg.omega)

        # 3. P2 -> beta -> powers (exact water-filling, pure jnp)
        p_max = jnp.full((self.k,), chan.p_max_watts, jnp.float32)
        beta, p2_obj = waterfill_beta_jnp(rho, theta, p_max, b,
                                          self._c1, self._c0)
        powers = power_from_beta(beta, rho, theta, p_max)

        # 4. instantaneous power constraint (7) under the sampled channel
        payload = deltas if cfg.transmit == "delta" else carry.pending
        h = sample_channel_gains(round_tag_key(self._srv_key, carry.t,
                                               TAG_CHANNEL), self.k, chan)
        w_norm2 = jnp.sum(payload * payload, axis=1)
        powers = jnp.minimum(powers, effective_power_cap(w_norm2, h,
                                                         chan.p_max_watts))

        # 5. AirComp superposition + AWGN + normalization (eqs. 6+8) —
        # the same jnp helper the host reference calls, so the two paths
        # share one reduction (bit-identical, not merely allclose)
        agg, varsigma = paota_aggregate_stacked(
            payload, powers, b,
            round_tag_key(self._srv_key, carry.t, TAG_NOISE), self._sigma_n)

        # 6. zero-uploader guard: hold w_g when nothing superposed
        new_global, new_prev = guarded_global_update(
            carry.global_vec, carry.prev_global, agg, varsigma,
            delta=cfg.transmit == "delta")

        # 7. broadcast w^{r+1}: every uploader restarts local training
        t_next = carry.t + 1
        lat = self._latency(t_next)
        n_ready, n_busy, n_model = sched_broadcast(
            ready, carry.busy_until, carry.model_round, ready, time, lat,
            t_next)
        trained = self._local_train_all(new_global, x, y, t_next)
        pending = jnp.where(ready[:, None], trained, carry.pending)
        starts = jnp.where(ready[:, None], new_global[None, :], carry.starts)

        n_upl = jnp.sum(b)
        denom = jnp.maximum(n_upl, 1.0)
        out = {
            "n_participants": n_upl,
            "time": time,
            "mean_staleness": jnp.sum(stal * b) / denom,
            "beta_mean": jnp.sum(beta * b) / denom,
            "varsigma": jnp.where(varsigma > VARSIGMA_MIN, varsigma, 0.0),
            "p2_objective": p2_obj,
        }
        carry = RoundCarry(t=t_next, time=time, ready=n_ready,
                           busy_until=n_busy, model_round=n_model,
                           global_vec=new_global, prev_global=new_prev,
                           pending=pending, starts=starts)
        return carry, out

    def _run_scan(self, carry: RoundCarry, x, y, n_rounds: int):
        def step(c, _):
            return self._step(c, x, y)
        return jax.lax.scan(step, carry, None, length=n_rounds)

    # ------------------------------------------------------------------
    # host-facing API (PAOTAServer-compatible)
    # ------------------------------------------------------------------
    @property
    def global_vec(self) -> np.ndarray:
        carry = self._carry
        vec = self._init_vec if carry is None else carry.global_vec
        return np.asarray(vec)

    def global_params(self):
        vec = self._init_vec if self._carry is None else self._carry.global_vec
        return self.unravel(vec)

    def advance(self, n_rounds: int) -> List[dict]:
        """Run ``n_rounds`` PAOTA rounds in ONE lax.scan device call;
        appends and returns the per-round history dicts."""
        if self._carry is None:
            self._carry = self._jit_init(self._init_vec, self.engine._x,
                                         self.engine._y)
        self._carry, outs = self._jit_scan(self._carry, self.engine._x,
                                           self.engine._y, n_rounds=n_rounds)
        outs = {k: np.asarray(v) for k, v in outs.items()}
        base = len(self.history)
        rows = [{"round": base + i,
                 "time": float(outs["time"][i]),
                 "n_participants": int(outs["n_participants"][i]),
                 "mean_staleness": float(outs["mean_staleness"][i]),
                 "beta_mean": float(outs["beta_mean"][i]),
                 "varsigma": float(outs["varsigma"][i]),
                 "p2_objective": float(outs["p2_objective"][i])}
                for i in range(n_rounds)]
        self.history.extend(rows)
        return rows

    def round(self) -> dict:
        """One round (drop-in for PAOTAServer.round — one device call of a
        length-1 scan; use ``advance`` to amortize over many rounds)."""
        return self.advance(1)[-1]
