"""Functional PAOTA round core — ONE implementation of the aggregation
period, shared by every driver.

The federated model is an arbitrary params PYTREE: every model-sized
quantity (globals, pending local models, their deltas) is carried
leaf-wise, and every cross-model scalar (per-client norms and cosines,
the AirComp superposition, varsigma) is computed as a tree-reduced sum —
per-leaf partials accumulated locally, then reduced ONCE (one psum per
round under sharding, never one per leaf). The raveled federation is the
trivial single-(K, d)-leaf pytree; ``waterfill_beta_jnp`` /
``power_from_beta`` stay shape-agnostic consumers of the reduced (K,)
scalars.

The delta plane is swept exactly TWICE per round (PR 5): the carry holds
the local-update deltas directly (``RoundCarry.deltas`` — the round used
to carry the per-client start models and re-derive ``pending - starts``
every period), so

* sweep 1 — ``repro.kernels.ops.round_stats``: per-client dots with the
  global direction, delta sq-norms, payload sq-norms for the power
  constraint (7), and the global-direction sq-norm, all in one fused pass
  (compiled Pallas kernel on TPU; on CPU the jnp twin's batched-dot
  formulation — never a materialized square — with XLA multi-output
  fusion doing the pass merging);
* sweep 2 — the superpose-and-normalize aggregation (eqs. 6+8), b·p
  masking + superposition + AWGN + varsigma normalization in one pass
  (``repro.kernels.aircomp_sum.superpose_normalize_pallas`` on TPU, the
  f32-accumulating einsum elsewhere; one psum under sharding).

``RoundCfg.pending_dtype`` optionally stores the carry's (K, ...) planes
(pending + deltas) in bf16 — every kernel/reduction accumulates in f32,
the globals stay f32, and the K x d working set halves for giant-model
clients. Deltas are always computed in f32 BEFORE the storage cast
(``trained - w_g``), never as a difference of rounded operands, so the
bf16 error is a relative rounding of the small delta, not a catastrophic
cancellation of two large models.

``paota_round_step`` is the pure round transition (``RoundCarry`` in,
``RoundCarry`` out): scheduler advance -> eq.-25 factors -> water-filling
P2 -> channel + instantaneous cap (7) -> AirComp -> zero-uploader-guarded
update -> broadcast + local train. It is parameterized by

* ``RoundCfg`` — the static problem constants (Theorem-1 c1/c0, channel
  power/noise, the aggregation period, the carry storage dtype), a plain
  NamedTuple of Python scalars closed over at trace time;
* ``RoundStreams`` — the per-driver data/RNG callbacks (local training,
  latency draws, channel draws, the per-round noise key). The callbacks
  are what let the same core run single-device (callbacks see all K
  clients) and mesh-sharded (callbacks see this shard's K/n slice of
  identical global draws);
* ``axis_name`` — ``None`` for the single-device form, or the mesh client
  axis name(s) under ``jax.shard_map``: per-client stages (local SGD,
  factors, channel, power) stay fully parallel — the round stats are
  shard-local by construction (their reductions run over the model dims,
  which every shard holds whole) — and only the AirComp superposition,
  the P2 water-filling reductions, and the round metrics cross shards as
  ``psum``/``pmin``/``pmax`` collectives.

Active-cohort mode (``RoundCfg.cohort_size`` m >= 1) splits the carry
into TWO planes: a dense (K,) client-state plane — scheduler bits,
staleness clocks, and the vectorized scenario simulator
(``repro.core.scheduler.ScenarioConfig``: availability cycles, dropouts,
lognormal responsiveness), all O(K) scalars advanced inside the scan —
and an (m, ...) active-cohort payload plane holding model-sized rows for
the in-flight cohort only (``slot_client`` / ``slot_live``). Freed slots
refill from the available idle pool by counter-RNG priority. The K x d
carry stops scaling with K: a K = 10^6 federation advances its state
plane on one host while only m payload rows materialize
(benchmarks/cohort_round_bench.py). ``cohort_size=0`` (the default) is
the historical dense program, bit for bit.

Consumers: ``repro.fl.fused.FusedPAOTA`` (single device, scan over
rounds, carry donated between scans), ``repro.fl.sharded.ShardedPAOTA``
(the same scan under ``shard_map`` over the mesh client axis), and the
host-path ``repro.fl.server.PAOTAServer`` whose numpy round consumes the
shared stage helpers (``eq25_factors`` / ``constraint7_powers``) so the
three implementations cannot drift apart stage by stage.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.aggregation import (guarded_global_update,
                                    paota_aggregate_compressed,
                                    paota_aggregate_stacked,
                                    paota_finalize_stacked,
                                    paota_partial_stacked)
from repro.core.aircomp import VARSIGMA_MIN, effective_power_cap
from repro.core.boxqp import waterfill_beta_jnp
from repro.core.compress import (dequantize_int8, ef_residual, gather_rows,
                                 quantize_int8_stochastic, scatter_rows,
                                 sparsify, topk_support)
from repro.core.power_control import (client_sq_norms, power_from_beta,
                                      similarity_factor, staleness_factor)
from repro.core.scheduler import sched_advance, sched_broadcast


class RoundCarry(NamedTuple):
    """Device-resident PAOTA state threaded through the scan.

    The federated model is an arbitrary params PYTREE: ``global_vec`` /
    ``prev_global`` hold one copy of the model (leaves of the params'
    natural shapes, always f32), ``pending`` / ``deltas`` hold the
    client-stacked form (every leaf with a leading K axis, stored in
    ``RoundCfg.pending_dtype``). ``deltas`` carries ``pending - start``
    directly — the local update each client would transmit — computed in
    f32 at broadcast time; the round never re-derives it from a stored
    start model (one fewer K x d sweep per period, and the bf16 storage
    mode stays a rounding of the small delta instead of a cancellation of
    two large models). The raveled federation is the trivial single-leaf
    instance — a bare (d,) vector / (K, d) matrix.

    Under the sharded driver the ``(K,)`` fields and the leading axis of
    every stacked leaf are laid over the mesh client axis (each shard
    carries its K/n rows); the scalars and the global-model leaves are
    replicated.
    """
    t: jnp.ndarray            # i32 — scheduler round counter
    time: jnp.ndarray         # f32 — simulated clock (seconds, report-only)
    ready: jnp.ndarray        # (K,) bool — b_k at the aggregation slot
    busy_lat: jnp.ndarray     # (K,) f32 — latency draw of each client's
                              # current training session; training-finished
                              # is the exact relative slot predicate
                              # lat <= (t+1 - model_round) * delta_t
                              # (repro.core.scheduler.slot_ready — no
                              # absolute-clock accumulation, so the f32
                              # scan and the host's f64 clock agree
                              # bit-for-bit at any horizon)
    model_round: jnp.ndarray  # (K,) i32 — round each client trains on
    global_vec: jnp.ndarray   # params pytree / (d,) — w_g^t
    prev_global: jnp.ndarray  # params pytree / (d,) — w_g^{t-1} (direction)
    pending: jnp.ndarray      # (K, ...)-leaf pytree — in-flight local models,
                              # or None under transmit='delta' (the round
                              # never reads the full local models there —
                              # the delta plane IS the whole carry, halving
                              # the K x d working set)
    deltas: jnp.ndarray       # (K, ...)-leaf pytree — pending - start model
    held: jnp.ndarray = None  # grouped aggregation only (group_period >= 1):
                              # (n_pod_groups, d_total + 1) f32 — the
                              # staleness-weighted intra-pod superposition
                              # partials (flattened leaf contractions + the
                              # varsigma partial) accumulated since the last
                              # cross-pod sync; sharded over the pod axes,
                              # replicated intra-pod, zeroed at every sync.
                              # None on the flat path.
    slot_client: jnp.ndarray = None  # active-cohort mode only
                              # (cohort_size m >= 1): (m,) i32 — which client
                              # occupies each payload slot (shard-LOCAL row
                              # index under sharding). The (K,) state plane
                              # stays dense and tiny; `pending`/`deltas`
                              # shrink to (m, ...) rows gathered for the
                              # in-flight cohort only, so the K x d carry
                              # stops scaling with K. None on the dense path.
    slot_live: jnp.ndarray = None    # (m,) bool — slot holds a real
                              # in-flight client (False = phantom row:
                              # b_k = 0 through every reduction, exactly the
                              # sharded drivers' phantom-client masking)
    slot_idx: jnp.ndarray = None     # compressed cohort payloads only
                              # (RoundCfg.compress): (m, s) i32 — each
                              # slot's support, the d-space coordinates its
                              # `deltas` values live on (top-k is per-row;
                              # randmask rows trained in different rounds
                              # hold different shared masks, so the support
                              # is per-slot either way). None when off.
    slot_scale: jnp.ndarray = None   # (m,) f32 — int8 slot storage only:
                              # per-row absmax dequantization factors
    slot_resid: jnp.ndarray = None   # (m, s) f32 — error-feedback residual
                              # of each in-flight slot (what the row's
                              # compression dropped), on its own support:
    slot_resid_idx: jnp.ndarray = None  # (m, s) i32. Residuals always f32.
    resid_val: jnp.ndarray = None    # (K, s) f32 — parked EF residuals:
                              # on slot turnover a departing slot scatters
                              # its residual back to the owning client's
                              # row; a re-scheduled client resumes its own
                              # accumulated error. Sharded: (K_local, s).
    resid_idx: jnp.ndarray = None    # (K, s) i32 — parked supports
    good_global: jnp.ndarray = None  # divergence rollback only
                              # (RoundCfg.divergence_factor > 0): params
                              # pytree / (d,) — the last global model that
                              # PASSED the post-update norm check; a
                              # diverged round restores w_g AND prev_global
                              # from this slot (replicated, like the
                              # globals). None when the detector is off.
    good_norm2: jnp.ndarray = None   # f32 scalar — ||good_global||^2,
                              # carried so the check never re-sweeps the
                              # last-good model


class RoundCfg(NamedTuple):
    """Static per-federation constants of the round (Python scalars only —
    closed over at trace time, never traced)."""
    omega: float              # staleness constant Omega (Sec. IV-A)
    c1: float                 # L eps^2 K   (P2 term-d scale)
    c0: float                 # 2 L d sigma_n^2 (P2 term-e numerator)
    p_max_watts: float        # per-client power budget P_max
    sigma_n: float            # channel noise std (concrete float)
    delta_t: float            # aggregation period (seconds)
    transmit_delta: bool      # True: clients transmit dw_k; False: w_k
    pending_dtype: str = "float32"   # carry storage dtype for the (K, ...)
                              # planes: "float32" | "bfloat16" (opt-in
                              # half-footprint mode; f32 accumulation)
    group_period: int = 0     # grouped aggregation window N (Air-FedGA
                              # style): 0 = flat (cross-shard sync every
                              # period); N >= 1 = intra-pod partials every
                              # period, ONE cross-pod psum every N periods
    cohort_size: int = 0      # active-cohort mode: 0 = dense (every client
                              # carries a payload row — bit-identical to the
                              # historical round); m >= 1 = at most m clients
                              # in flight, payload planes are (m, ...) slot
                              # rows (gather on schedule, scatter on upload)
    compress: str = ""        # compressed cohort payloads: "" = off (the
                              # PR 7 program, bit for bit); "topk" /
                              # "randmask" = slots carry an (m, s) plane on
                              # per-slot supports. Requires cohort_size,
                              # transmit_delta, raveled params.
    compress_s: int = 0       # static compressed width s; s == d routes
                              # the dense stats/AirComp stages statically
                              # (identity compression, bit-identical)
    slot_dtype: str = ""      # compressed slot-value storage: "" resolves
                              # to pending_dtype; "float32" | "bfloat16" |
                              # "int8" (per-row absmax + stochastic
                              # rounding, f32 accumulation downstream)
    error_feedback: bool = False  # carry per-slot EF residuals + the (K, s)
                              # parked plane; compensation a = delta +
                              # parked residual is what gets compressed
    screen: bool = False      # per-row payload screening (containment):
                              # a row whose stats sweep shows a non-finite
                              # value — or a norm beyond screen_max_norm —
                              # is masked out of the superposition exactly
                              # like a phantom client (b = 0, zeroed
                              # payload row, sanitized per-row scalars).
                              # False emits the unscreened program op for
                              # op (trace-time branch).
    screen_max_norm: float = 0.0  # Byzantine norm fence: rows with
                              # ||payload|| > screen_max_norm are screened
                              # too (0 = finite-only screening)
    divergence_factor: float = 0.0  # post-update divergence detector:
                              # roll back to the last-good global when
                              # ||w_g_new|| > factor * max(||good||,
                              # DIVERGENCE_NORM_FLOOR). 0 = off (no
                              # good-global carry slot, program unchanged)


class GroupTopology(NamedTuple):
    """Static mesh-axis split for grouped aggregation (trace-time only)."""
    pod_axes: tuple           # client axes indexing the pod groups — the
                              # cross-pod sync psums over these every
                              # group_period periods
    intra_axes: tuple         # client axes inside a pod — the per-period
                              # partial superposition psums over these
                              # (may be empty: every shard its own pod)
    intra_shards: int         # prod of intra_axes extents — the held
                              # partial's replication count, so the sync can
                              # fold held/intra_shards into the all-axes psum


class RoundStreams(NamedTuple):
    """Per-driver callbacks: how this driver's shard of clients trains and
    draws its randomness. All callbacks are traced (called inside jit /
    shard_map); under sharding each returns this shard's rows of the SAME
    global draws the single-device form makes, so trajectories agree.
    """
    local_train: Callable     # (global tree, x, y, round) -> stacked tree
                              # of (K_local, ...) leaves ((K_local, d) for
                              # the raveled single-leaf federation)
    latencies: Callable       # (round) -> (K_local,) latency draws
    channel: Callable         # (round) -> (K_local,) |h_k| draws
    noise_key: Callable       # (round) -> AWGN key (replicated)
    scenario: Callable = None # (round) -> ((K_local,) available,
                              # (K_local,) dropped) bool masks, or None —
                              # None skips the mask stage at TRACE time, so
                              # the no-scenario program stays bit-identical
    cohort_train: Callable = None  # cohort mode: (global tree, x, y, round,
                              # (m,) slot client ids) -> (m, ...) stacked
                              # trained tree — the m-row twin of local_train
    sched_priority: Callable = None  # cohort mode: (round) -> (K_local,)
                              # f32 scheduling scores; highest-score idle
                              # available clients fill freed slots. Rows
                              # pinned to -inf are never schedulable (the
                              # sharded drivers' phantom fill).
    compress_mask: Callable = None   # compress='randmask': (round) ->
                              # (s,) i32 shared support — drawn from the
                              # counter stream (TAG_COMPRESS), REPLICATED
                              # across shards so every shard re-derives
                              # the identical per-round mask
    quant_key: Callable = None       # slot_dtype='int8': (round) -> PRNG
                              # key for the stochastic-rounding dither
                              # (TAG_QUANT; sharded drivers fold in the
                              # shard offset — per-row draws must differ
                              # across shards, unlike the mask)


# ---------------------------------------------------------------------------
# shared stage helpers (host server + fused/sharded core)
# ---------------------------------------------------------------------------

def round_factors(deltas, payload, global_vec, prev_global, stal, omega,
                  eps=1e-12, tp=None):
    """Stage 2 of the round, one delta-plane sweep: eq.-25 staleness
    factors rho_k, gradient-similarity factors theta_k, and the payload
    sq-norms the power constraint (7) needs — all from ONE fused pass
    over the stacked deltas (+ payload) via ``repro.kernels.ops
    .round_stats``. ``payload=None`` means the payload IS the deltas
    (transmit='delta'), so their sq-norms are reused instead of re-swept.

    Per-client along the leading axis and shard-local under the client
    mesh axis (every reduction runs over the model dims, which each shard
    holds whole — per-leaf partials accumulate locally, no collective —
    UNLESS an intra-client ``tp`` topology is passed: each shard then
    holds only its TP-local model block and the sweep closes with one
    small psum over ``tp.axes``; see ``kernels.round_stats
    .round_stats_tp``).

    Returns (rho, theta, w_norm2)."""
    from repro.kernels.ops import round_stats
    gdir = jax.tree_util.tree_map(jnp.subtract, global_vec, prev_global)
    dots, dn2, pn2, gn2 = round_stats(deltas, gdir, payload, tp=tp)
    gnorm = jnp.sqrt(gn2)
    den = jnp.sqrt(jnp.maximum(dn2, eps) * jnp.maximum(gn2, eps))
    cos = jnp.where(gnorm < 1e-12, 0.0, dots / den)
    theta = similarity_factor(cos)
    rho = staleness_factor(stal, omega)
    return rho, theta, (dn2 if payload is None else pn2)


def eq25_factors(pending, starts, global_vec, prev_global, stal, omega,
                 use_kernel: bool = False):
    """Host-reference form of stage 2 (the ``PAOTAServer`` state is
    (pending, starts), not carried deltas): derive the deltas, then run
    the same fused one-sweep stats the on-device core uses. ``use_kernel``
    is accepted for interface compatibility; kernel-vs-jnp routing is
    backend-resolved inside ``repro.kernels.ops.round_stats``.

    Returns (deltas pytree, rho, theta)."""
    del use_kernel
    deltas = jax.tree_util.tree_map(jnp.subtract, pending, starts)
    rho, theta, _ = round_factors(deltas, None, global_vec, prev_global,
                                  stal, omega)
    return deltas, rho, theta


def constraint7_powers(powers, payload, h, p_max, w_norm2=None):
    """Stage 4 — instantaneous power constraint (7) under the sampled
    channel: p_k <- min(p_k, |h_k| sqrt(P_max / ||w_k||^2)). The fused
    core passes ``w_norm2`` straight from the stage-2 stats sweep; the
    host reference leaves it None and tree-reduces the payload here
    (same chunked accumulation — ``client_sq_norms`` — so the two paths
    agree to the float op). Per-client, shard-local."""
    if w_norm2 is None:
        w_norm2 = client_sq_norms(payload)
    return jnp.minimum(powers, effective_power_cap(w_norm2, h, p_max))


def compressed_round_factors(values, idx, resid, resid_idx, global_vec,
                             prev_global, stal, omega, scale=None,
                             eps=1e-12):
    """Stage-2 twin of ``round_factors`` for the compressed cohort plane:
    the stats sweep runs over the (m, s) transmitted values + the EF
    residuals on their supports (``repro.kernels.ops.round_stats_
    compressed``) — never a dense (m, d) row. theta sees each slot's full
    reconstruction <v + e, gdir> (exact at s = d, the sparsity
    approximation below it); the returned payload norm is ||v||^2, the
    TRANSMITTED energy, which is what the power constraint (7) actually
    caps on the air. Raveled single-leaf only.

    Returns (rho, theta, w_norm2)."""
    from repro.kernels.ops import round_stats_compressed
    gdir = global_vec - prev_global
    dots, dn2, pn2, gn2 = round_stats_compressed(values, idx, resid,
                                                 resid_idx, gdir,
                                                 scale=scale)
    gnorm = jnp.sqrt(gn2)
    den = jnp.sqrt(jnp.maximum(dn2, eps) * jnp.maximum(gn2, eps))
    cos = jnp.where(gnorm < 1e-12, 0.0, dots / den)
    theta = similarity_factor(cos)
    rho = staleness_factor(stal, omega)
    return rho, theta, pn2


# divergence detector: a global whose norm sits below this floor compares
# against the floor instead (a near-zero-init model must be allowed to
# grow — factor * ~0 would flag every first update as divergent)
DIVERGENCE_NORM_FLOOR = 1.0


def _tree_sq_norm(tree):
    """||tree||^2 as one f32 scalar (sum over leaves; model-dims only, so
    it is shard-local under client sharding — the globals are replicated)."""
    total = jnp.float32(0.0)
    for leaf in jax.tree_util.tree_leaves(tree):
        total = total + jnp.sum(jnp.square(leaf.astype(jnp.float32)))
    return total


def _screen_ok(theta, w_norm2, rcfg: RoundCfg):
    """Per-row containment verdict from the stats sweep the round already
    ran: a corrupt payload row (NaN/Inf anywhere in it) surfaces as a
    non-finite theta or sq-norm — the sweep's reductions ARE the detector,
    no extra model-plane pass — and ``screen_max_norm`` adds a Byzantine
    norm fence on top. Returns (ok mask, sanitized theta, sanitized
    w_norm2): the sanitized per-row scalars are what keep a screened row
    from poisoning the water-filling bounds (NaN * b survives b = 0)."""
    ok = jnp.isfinite(theta) & jnp.isfinite(w_norm2)
    if rcfg.screen_max_norm > 0.0:
        ok = ok & (w_norm2 <= jnp.float32(rcfg.screen_max_norm) ** 2)
    return ok, jnp.where(ok, theta, 0.0), jnp.where(ok, w_norm2, 0.0)


def _zero_rows(tree, ok):
    """Zero the failing rows of a stacked tree: a screened row superposes
    exact +0.0 into every contraction — bit-identical to a never-scheduled
    client's b = 0 contribution — instead of 0 * NaN = NaN."""
    def leaf(l):
        m = ok.reshape((ok.shape[0],) + (1,) * (l.ndim - 1))
        return jnp.where(m, l, jnp.zeros((), l.dtype))
    return jax.tree_util.tree_map(leaf, tree)


def _divergence_rollback(new_global, new_prev, carry: RoundCarry,
                         rcfg: RoundCfg):
    """Post-update divergence detector: if ||w_g^{new}|| jumped beyond
    ``divergence_factor`` times the last-good norm (or is non-finite —
    the comparison is written so NaN lands on the diverged side), restore
    BOTH w_g and prev_global from the carry's last-good slot (the
    similarity direction collapses to zero for one round — the existing
    gnorm guard maps that to cos = 0) and keep the slot; otherwise the
    accepted global becomes the new last-good. Scalar-select logic over
    replicated leaves: no collectives, ONE extra model copy in the carry.

    Returns (global, prev, good_global, good_norm2, rolled_back f32)."""
    n_new = _tree_sq_norm(new_global)
    f2 = jnp.float32(rcfg.divergence_factor) ** 2
    limit = f2 * jnp.maximum(carry.good_norm2,
                             jnp.float32(DIVERGENCE_NORM_FLOOR) ** 2)
    diverged = ~(n_new <= limit)

    def sel(gd, cand):
        return jnp.where(diverged, gd, cand)

    new_global = jax.tree_util.tree_map(sel, carry.good_global, new_global)
    new_prev = jax.tree_util.tree_map(sel, carry.good_global, new_prev)
    good_n2 = jnp.where(diverged, carry.good_norm2, n_new)
    # accepted -> good slot IS the accepted global; diverged -> unchanged
    return (new_global, new_prev, new_global, good_n2,
            diverged.astype(jnp.float32))


def _storage_dtype(rcfg: RoundCfg):
    return jnp.dtype(rcfg.pending_dtype)


def _cast_rows(tree, dtype):
    return jax.tree_util.tree_map(lambda l: l.astype(dtype), tree)


def _slot_dtype(rcfg: RoundCfg) -> str:
    """Resolved compressed slot-value storage dtype."""
    return rcfg.slot_dtype or rcfg.pending_dtype


def _compress_plane(comp, *, rcfg: RoundCfg, streams: RoundStreams, t):
    """Compress freshly trained (m, d) f32 rows (EF-compensated deltas)
    into the carry's slot planes.

    Support: s == d is statically the identity (both schemes — the carry
    holds the dense rows on an arange support, so the stats/AirComp
    stages route dense and stay bit-identical); top-k picks each row's s
    largest-|.| coordinates; randmask broadcasts the round's shared
    counter-RNG mask. Storage: f32 (exact), bf16 (round-trip), or int8
    (per-row absmax + unbiased stochastic rounding, scale kept f32). The
    EF residual is the exact f32 complement of the row against its stored
    reconstruction, re-sparsified to width s for the carry.

    Returns (stored (m, s), idx (m, s) i32, scale (m,) f32 | None,
    resid (m, s) f32 | None, resid_idx (m, s) i32 | None)."""
    m, d = comp.shape
    s = rcfg.compress_s
    if s >= d:
        idx = jnp.broadcast_to(jnp.arange(d, dtype=jnp.int32)[None], (m, d))
        vals = comp
    elif rcfg.compress == "topk":
        idx = topk_support(comp, s)
        vals = gather_rows(comp, idx)
    else:                                                   # randmask
        mask = streams.compress_mask(t)
        idx = jnp.broadcast_to(mask[None], (m, s))
        vals = gather_rows(comp, idx)
    sd = _slot_dtype(rcfg)
    scale = None
    if sd == "int8":
        stored, scale = quantize_int8_stochastic(vals, streams.quant_key(t))
        v_hat = dequantize_int8(stored, scale)
    elif sd == "bfloat16":
        stored = vals.astype(jnp.bfloat16)
        v_hat = stored.astype(jnp.float32)
    else:
        stored = v_hat = vals
    if not rcfg.error_feedback:
        return stored, idx, scale, None, None
    e = ef_residual(comp, idx, v_hat)
    e_val, e_idx = sparsify(e, s)
    return stored, idx, scale, e_val, e_idx


# ---------------------------------------------------------------------------
# the round transition
# ---------------------------------------------------------------------------

def paota_round_step(carry: RoundCarry, x, y, *, rcfg: RoundCfg,
                     streams: RoundStreams, axis_name=None,
                     grouping: GroupTopology | None = None,
                     window_j: int = 0, tp=None):
    """One PAOTA aggregation period as a pure function.

    ``axis_name=None`` is the single-device form. With a mesh axis name
    (or tuple of names), the (K,) / (K, d) carry rows are this shard's
    clients and the cross-client reductions go through collectives.

    Intra-client TP (``tp``: ``repro.sharding.tp.TPTopology``, sharded
    pytree mode only): the payload planes additionally hold only this
    device's TP-local block of each leaf. Training stays replicated
    compute over the TP axes (full leaves from the replicated global);
    the stats sweep TP-slices the global direction and psums once over
    ``tp.axes``; the superposition's single model-sized psum spans
    clients x TP (superpose + gather in one collective) with the AWGN
    drawn at FULL shapes from the replicated key; and the carry writes
    slice the trained rows down to the TP-local block. ``tp=None`` (any
    TP extent-1 mesh) is op-for-op the historical program.

    Grouped aggregation (``rcfg.group_period`` N >= 1 with a
    ``grouping`` topology): ``window_j`` is this period's static position
    in the window. Non-sync periods (j < N-1) reduce the superposition
    over the intra-pod axes only and accumulate it into ``carry.held``
    weighted by the eq.-25 staleness factor of its age at the sync,
    rho(N-1-j) = Omega / (N-1-j + Omega) — the global model holds. The
    sync period (j = N-1) folds the held window into ONE psum over ALL
    client axes (held is intra-pod-replicated, so held/intra_shards under
    the all-axes psum equals its cross-pod sum), adds the single AWGN
    realization, normalizes, and applies the guarded update. At N=1 every
    period is a sync with held == 0, and since x + 0 is exact the program
    is op-for-op the flat path — grouped N=1 equals flat by construction.

    Active-cohort mode (``rcfg.cohort_size`` m >= 1): the payload planes
    are (m, ...) slot rows instead of (K, ...) — the round gathers channel/
    staleness state for the in-flight cohort, runs the identical stats /
    water-filling / AirComp stages over m rows, and scatters the scheduler
    effects back into the dense-but-tiny (K,) state plane
    (``_cohort_round_step``). Incompatible with grouped aggregation.

    Returns (next_carry, per-round metrics dict of replicated scalars)."""
    if rcfg.cohort_size:
        if grouping is not None:
            raise NotImplementedError(
                f"active-cohort mode (cohort_size={rcfg.cohort_size}) does "
                f"not compose with grouped aggregation (group_period="
                f"{rcfg.group_period}) yet — the held cross-pod partial "
                f"would need per-slot staleness bookkeeping; the nearest "
                f"supported configurations are cohort_size="
                f"{rcfg.cohort_size} with group_period=0 (flat sync every "
                f"period) or group_period={rcfg.group_period} with "
                f"cohort_size=0 (dense payload planes)")
        if tp is not None:
            raise NotImplementedError(
                f"active-cohort mode (cohort_size={rcfg.cohort_size}) does "
                f"not compose with intra-client TP (tp axes {tp.axes}) yet "
                f"— the (m, s) slot planes are raveled and the TP split is "
                f"per-leaf; the nearest supported configurations are "
                f"cohort_size={rcfg.cohort_size} on a client-axes-only "
                f"mesh, or TP with cohort_size=0 (dense payload planes)")
        return _cohort_round_step(carry, x, y, rcfg=rcfg, streams=streams,
                                  axis_name=axis_name)
    if tp is not None and grouping is not None:
        raise NotImplementedError(
            f"grouped aggregation (group_period={rcfg.group_period}) does "
            f"not compose with intra-client TP (tp axes {tp.axes}) yet — "
            f"the held intra-pod partial is not TP-split; the nearest "
            f"supported configurations are group_period="
            f"{rcfg.group_period} with TP extent 1, or TP with "
            f"group_period=0 (flat sync every period)")
    k_local = carry.ready.shape[0]
    grouped = grouping is not None and rcfg.group_period >= 1
    sync = (not grouped) or (window_j == rcfg.group_period - 1)

    def ksum(v, axis=None):
        s = jnp.sum(v, axis=axis)
        return s if axis_name is None else jax.lax.psum(s, axis_name)

    # 1. scheduler advance: who finished inside this period, staleness.
    # The finished test is the exact relative slot predicate over the
    # carried latency draws (repro.core.scheduler.slot_ready) — one f32
    # rounding, bit-identical to the host reference's mask at any horizon;
    # `time` is report-only.
    time = (carry.t + 1).astype(jnp.float32) * jnp.float32(rcfg.delta_t)
    ready, stal = sched_advance(carry.ready, carry.busy_lat,
                                carry.model_round, carry.t, rcfg.delta_t)
    if streams.scenario is None:
        # no scenario: uploaders = restarters = the ready set — this branch
        # is the historical program, bit-identical op for op
        upl = restart = ready
    else:
        # scenario masks (trace-time branch: the callback is None unless a
        # scenario can actually mask): unavailable-but-ready clients HOLD
        # their finished update and stay ready for a later slot (staleness
        # keeps growing); dropped uploads are lost in transit but the
        # client still restarts from the fresh broadcast
        avail, drop = streams.scenario(carry.t)
        upl = ready & avail & ~drop
        restart = ready & avail
    b = upl.astype(jnp.float32)
    stal = jnp.where(upl, stal, 0).astype(jnp.float32)

    # 2. staleness + gradient-similarity factors (eq. 25) + the payload
    # norms for constraint (7): ONE sweep over the carried delta plane
    # (sweep 1 of 2)
    payload = carry.deltas if rcfg.transmit_delta else carry.pending
    rho, theta, w_norm2 = round_factors(
        carry.deltas, None if rcfg.transmit_delta else carry.pending,
        carry.global_vec, carry.prev_global, stal, rcfg.omega, tp=tp)

    # 2b. containment (trace-time branch — screen=False emits the
    # historical program op for op): rows the stats sweep exposed as
    # corrupt (non-finite) or norm-fenced are masked out of this round's
    # superposition exactly like phantom clients — b = 0, the payload row
    # zeroed so every contraction sees exact +0.0, and the per-row scalars
    # sanitized so the water-filling bounds never touch a NaN. The masking
    # is shard-local and happens BEFORE the collective, so the sharded
    # round still compiles to ONE cross-client psum.
    n_screened = jnp.float32(0.0)
    if rcfg.screen:
        ok, theta, w_norm2 = _screen_ok(theta, w_norm2, rcfg)
        n_screened = ksum(b * (~ok).astype(jnp.float32))
        b = b * ok.astype(jnp.float32)
        payload = _zero_rows(payload, ok)

    # 3. P2 -> beta -> powers (exact water-filling, pure jnp; the grid and
    # golden-section reductions over K run as psums under sharding). At a
    # grouped non-sync period only the pod's own clients superpose, so the
    # P2 reductions stay intra-pod (per-pod water level) — no cross-pod
    # collective outside the sync.
    wf_axes = axis_name if sync else (grouping.intra_axes or None)
    p_max = jnp.full((k_local,), rcfg.p_max_watts, jnp.float32)
    beta, p2_obj = waterfill_beta_jnp(rho, theta, p_max, b, rcfg.c1, rcfg.c0,
                                      axis_name=wf_axes)
    powers = power_from_beta(beta, rho, theta, p_max)

    # 4. instantaneous power constraint (7) under the sampled channel —
    # the payload norms came with the stats sweep, no extra pass
    h = streams.channel(carry.t)
    powers = constraint7_powers(powers, payload, h, rcfg.p_max_watts,
                                w_norm2=w_norm2)

    # 5+6. AirComp superposition + AWGN + normalization (eqs. 6+8, sweep 2
    # of 2) and the zero-uploader-guarded update
    held = carry.held
    if not grouped:
        # flat path: the superposition is ONE psum over the client axes
        # (or the single-device einsum) with the noise joining once after
        agg, varsigma = paota_aggregate_stacked(
            payload, powers, b, streams.noise_key(carry.t), rcfg.sigma_n,
            axis_name=axis_name, tp=tp)
        new_global, new_prev = guarded_global_update(
            carry.global_vec, carry.prev_global, agg, varsigma,
            delta=rcfg.transmit_delta)
    elif sync:
        partial = paota_partial_stacked(payload, powers, b)
        # held is replicated over the intra-pod shards, so scaling by
        # 1/intra_shards makes the all-axes psum reproduce its cross-pod
        # sum; at N=1 held == 0 and `partial + 0` is bit-exact — the sync
        # psum IS the flat path's. This is the window's ONE cross-pod
        # model-sized collective.
        scale = jnp.float32(1.0 / grouping.intra_shards)
        agg, varsigma = paota_finalize_stacked(
            partial + held[0] * scale, payload, streams.noise_key(carry.t),
            rcfg.sigma_n, axis_name=axis_name)
        new_global, new_prev = guarded_global_update(
            carry.global_vec, carry.prev_global, agg, varsigma,
            delta=rcfg.transmit_delta)
        held = jnp.zeros_like(held)
    else:
        # non-sync period: intra-pod partial only, weighted by the eq.-25
        # staleness factor of its age at the sync slot (a static Python
        # float — the window position is unrolled); the global holds.
        partial = paota_partial_stacked(payload, powers, b,
                                        axis_name=grouping.intra_axes or None)
        age = float(rcfg.group_period - 1 - window_j)
        held = held + jnp.float32(staleness_factor(age, rcfg.omega)) \
            * partial[None, :]
        varsigma = jnp.float32(0.0)
        new_global, new_prev = carry.global_vec, carry.prev_global

    # 6b. divergence rollback (trace-time branch; grouped non-sync periods
    # hold the global, so only update periods are checked) — happens BEFORE
    # the broadcast so a rolled-back round retrains from the restored model
    good, good_n2 = carry.good_global, carry.good_norm2
    rolled = jnp.float32(0.0)
    if rcfg.divergence_factor > 0.0 and sync:
        new_global, new_prev, good, good_n2, rolled = _divergence_rollback(
            new_global, new_prev, carry, rcfg)

    # 7. broadcast w^{r+1}: every restarter — uploader, or dropped uploader
    # whose update was lost in transit — begins fresh local training (at a
    # grouped non-sync period the rebroadcast model is the held global).
    # The carry's delta rows are refreshed as f32 ``trained - w_g^{r+1}``
    # BEFORE the storage cast.
    t_next = carry.t + 1
    lat = streams.latencies(t_next)
    n_ready, n_lat, n_model = sched_broadcast(
        ready, carry.busy_lat, carry.model_round, restart, lat, t_next)
    trained = streams.local_train(new_global, x, y, t_next)
    dtype = _storage_dtype(rcfg)

    def row_select(new, old):
        m = restart.reshape((k_local,) + (1,) * (new.ndim - 1))
        return jnp.where(m, new, old)

    if tp is not None:
        # TP-active carry writes: the payload planes hold only this
        # device's TP-local block of each leaf, so the (TP-replicated)
        # trained rows and new global are sliced down to the block first
        # — after this the write is the general delta form below
        from repro.sharding.tp import tp_slice
        tdef = jax.tree_util.tree_structure(carry.deltas)
        tr_l = jax.tree_util.tree_leaves(trained)
        g_l = jax.tree_util.tree_leaves(new_global)
        dl_l = jax.tree_util.tree_leaves(carry.deltas)
        p_l = (jax.tree_util.tree_leaves(carry.pending)
               if carry.pending is not None else [None] * len(tr_l))
        new_p, new_d = [], []
        for tr, g, dl, p, dim in zip(tr_l, g_l, dl_l, p_l, tp.leaf_dims):
            if dim >= 0:
                tr = tp_slice(tr, dim + 1, tp)
                g = tp_slice(g, dim, tp)
            if p is not None:
                new_p.append(row_select(tr.astype(p.dtype), p))
            new_d.append(row_select((tr - g[None]).astype(dl.dtype), dl))
        pending = (jax.tree_util.tree_unflatten(tdef, new_p)
                   if carry.pending is not None else None)
        deltas = jax.tree_util.tree_unflatten(tdef, new_d)
    else:
        pending = None if carry.pending is None else jax.tree_util.tree_map(
            lambda tr, p: row_select(tr.astype(p.dtype), p),
            trained, carry.pending)
        if dtype == jnp.float32 and pending is not None:
            # derive the delta rows from the NEW pending (identical values:
            # ready rows of `pending` ARE the trained rows) — this lets XLA
            # fuse the raveled concat straight into both carry writes
            # instead of materializing a separate (K, d) trained plane
            deltas = jax.tree_util.tree_map(
                lambda p, dl, g: row_select(p - g[None], dl),
                pending, carry.deltas, new_global)
        else:
            # bf16 storage (the delta MUST come from the f32 trained rows —
            # deriving it from the already-rounded pending would cancel two
            # large rounded models instead of rounding one small delta),
            # and the pending-less transmit='delta' carry
            deltas = jax.tree_util.tree_map(
                lambda tr, dl, g: row_select((tr - g[None]).astype(dl.dtype),
                                             dl),
                trained, carry.deltas, new_global)

    n_upl = ksum(b)
    denom = jnp.maximum(n_upl, 1.0)
    if sync:
        # a zero-uploader P2 is vacuous (every candidate t is 0 and the
        # solver's ratio degenerates to c0/clamp ~ 1e22); report inf like
        # the host reference's skipped-round branch does
        p2_metric = jnp.where(n_upl > 0, p2_obj, jnp.inf)
    else:
        # non-sync period: the water level is per-pod, so p2_obj differs
        # across pods (replicated intra-pod). Report the mean over pods
        # that had uploaders — scalar psums only, never model-sized.
        intra = grouping.intra_axes
        pod_upl = jnp.sum(b)
        if intra:
            pod_upl = jax.lax.psum(pod_upl, intra)
        pod_has = pod_upl > 0
        obj_sum = jax.lax.psum(jnp.where(pod_has, p2_obj, 0.0),
                               grouping.pod_axes)
        n_active = jax.lax.psum(pod_has.astype(jnp.float32),
                                grouping.pod_axes)
        p2_metric = jnp.where(n_upl > 0,
                              obj_sum / jnp.maximum(n_active, 1.0), jnp.inf)
    out = {
        "n_participants": n_upl,
        "time": time,
        "mean_staleness": ksum(stal * b) / denom,
        "beta_mean": ksum(beta * b) / denom,
        # at a grouped non-sync period varsigma is reported 0.0 (nothing
        # normalized this period — the window's varsigma lands at the sync)
        "varsigma": jnp.where(varsigma > VARSIGMA_MIN, varsigma, 0.0),
        "p2_objective": p2_metric,
        "n_screened": n_screened,
        "rolled_back": rolled,
    }
    carry = RoundCarry(t=t_next, time=time, ready=n_ready,
                       busy_lat=n_lat, model_round=n_model,
                       global_vec=new_global, prev_global=new_prev,
                       pending=pending, deltas=deltas, held=held,
                       good_global=good, good_norm2=good_n2)
    return carry, out


def _cohort_round_step(carry: RoundCarry, x, y, *, rcfg: RoundCfg,
                       streams: RoundStreams, axis_name=None):
    """Active-cohort form of the round: (K,) state plane + (m, d) payload
    plane.

    The scheduler/simulator state (``ready``, ``busy_lat``,
    ``model_round`` — plus the scenario masks) stays a dense (K,) plane:
    tiny, O(K) not O(K d). Model-sized rows exist ONLY for the m slots of
    the in-flight cohort (``slot_client`` maps slot -> client row,
    ``slot_live`` masks unfilled slots exactly like the sharded drivers'
    phantom clients), so the eq.-25 stats, water-filling, constraint (7),
    and AirComp stages — unchanged, shape-agnostic in their leading axis —
    run over m rows. Idle clients sit at ``busy_lat = +inf`` (the
    ``slot_ready`` predicate can never flip them), and freed slots are
    refilled from the available idle pool by counter-RNG priority
    (``streams.sched_priority``; descending ``lax.top_k``) — an O(K log K)
    sort-plane op, no Python priority queue.

    Equivalences: at m = K (every client permanently slotted) the step is
    the dense round up to slot permutation — same uploader sets, same
    per-client draws, float reduction order the only difference. An
    all-masked cohort (b = 0 everywhere) hits the same zero-uploader guard
    as the dense path, holding w_g bit-identically."""
    k_local = carry.ready.shape[0]
    occ, live = carry.slot_client, carry.slot_live
    m = occ.shape[0]

    def ksum(v, axis=None):
        s = jnp.sum(v, axis=axis)
        return s if axis_name is None else jax.lax.psum(s, axis_name)

    # 1. (K,) state plane advance + scenario masks (same stages as the
    # dense step — sched_advance only ever flips clients whose carried
    # latency draw is finite, i.e. the in-flight cohort)
    time = (carry.t + 1).astype(jnp.float32) * jnp.float32(rcfg.delta_t)
    ready, stal_k = sched_advance(carry.ready, carry.busy_lat,
                                  carry.model_round, carry.t, rcfg.delta_t)
    if streams.scenario is None:
        avail = jnp.ones((k_local,), bool)
        upl_k = depart_k = ready
    else:
        avail, drop = streams.scenario(carry.t)
        upl_k = ready & avail & ~drop
        depart_k = ready & avail

    # slot view of the (K,) state: gather by occupant, mask dead slots
    b = (live & upl_k[occ]).astype(jnp.float32)
    stal = jnp.where(live, stal_k[occ], 0).astype(jnp.float32)

    # 2-4. identical per-row stages over the m cohort rows (sweep 1: fused
    # stats; P2 water-filling; constraint (7) under the gathered channel).
    # Compressed payloads (rcfg.compress, a trace-time branch — off emits
    # the PR 7 program op for op): the stats sweep runs on the (m, s)
    # compressed rows + EF residuals; at the static s == d identity the
    # dense formulations route unchanged (bit-identity with compress off).
    payload = carry.deltas if rcfg.transmit_delta else carry.pending
    if rcfg.compress:
        d_model = carry.global_vec.shape[0]
        identity = rcfg.compress_s >= d_model
        # identity support + int8: the dense stages need the dequantized
        # rows (f32/bf16 identity rows pass through untouched — the
        # bit-identity claim is about THOSE)
        v_id = (carry.deltas if carry.slot_scale is None
                else dequantize_int8(carry.deltas, carry.slot_scale))
        if identity:
            rho, theta, w_norm2 = round_factors(
                v_id, None, carry.global_vec, carry.prev_global,
                stal, rcfg.omega)
        else:
            rho, theta, w_norm2 = compressed_round_factors(
                carry.deltas, carry.slot_idx, carry.slot_resid,
                carry.slot_resid_idx, carry.global_vec, carry.prev_global,
                stal, rcfg.omega, scale=carry.slot_scale)
    else:
        rho, theta, w_norm2 = round_factors(
            carry.deltas, None if rcfg.transmit_delta else carry.pending,
            carry.global_vec, carry.prev_global, stal, rcfg.omega)

    # 2b. containment over the cohort slots (same contract as the dense
    # step's: corrupt/fenced rows leave the superposition as exact zeros
    # — the phantom-slot masking — and the per-row scalars are sanitized
    # before water-filling; trace-time branch, screen=False is the
    # unscreened program op for op). Compressed slots zero both the value
    # rows and the dequantization scales, so an int8 slot with a NaN
    # absmax scale contributes 0 * 0, never 0 * NaN.
    n_screened = jnp.float32(0.0)
    vals_s, scale_s = carry.deltas, carry.slot_scale
    if rcfg.screen:
        ok, theta, w_norm2 = _screen_ok(theta, w_norm2, rcfg)
        n_screened = ksum(b * (~ok).astype(jnp.float32))
        b = b * ok.astype(jnp.float32)
        if rcfg.compress:
            vals_s = _zero_rows(vals_s, ok)
            if scale_s is not None:
                scale_s = jnp.where(ok, scale_s, 0.0)
            v_id = _zero_rows(v_id, ok)
        else:
            payload = _zero_rows(payload, ok)
    p_max = jnp.full((m,), rcfg.p_max_watts, jnp.float32)
    beta, p2_obj = waterfill_beta_jnp(rho, theta, p_max, b, rcfg.c1, rcfg.c0,
                                      axis_name=axis_name)
    powers = power_from_beta(beta, rho, theta, p_max)
    h = jnp.where(live, streams.channel(carry.t)[occ], 0.0)
    powers = constraint7_powers(powers, payload, h, rcfg.p_max_watts,
                                w_norm2=w_norm2)

    # 5+6. AirComp over the cohort rows (sweep 2) + the guarded update —
    # an all-masked cohort degenerates to the zero-uploader hold exactly
    # like the dense path (varsigma below the guard threshold). Compressed:
    # the gather-superpose kernel decompresses INTO the superposition
    # (eq. 8 in d-space) before the global update — the stored int8 plane
    # feeds it directly with its scale folded into the weights.
    if rcfg.compress and not identity:
        agg, varsigma = paota_aggregate_compressed(
            vals_s, carry.slot_idx, powers, b,
            streams.noise_key(carry.t), rcfg.sigma_n, d_model,
            scale=scale_s, axis_name=axis_name)
    else:
        agg, varsigma = paota_aggregate_stacked(
            v_id if rcfg.compress else payload, powers, b,
            streams.noise_key(carry.t), rcfg.sigma_n, axis_name=axis_name)
    new_global, new_prev = guarded_global_update(
        carry.global_vec, carry.prev_global, agg, varsigma,
        delta=rcfg.transmit_delta)

    # 6b. divergence rollback (trace-time branch) — before the broadcast,
    # so a rolled-back round reschedules/trains from the restored model
    good, good_n2 = carry.good_global, carry.good_norm2
    rolled = jnp.float32(0.0)
    if rcfg.divergence_factor > 0.0:
        new_global, new_prev, good, good_n2, rolled = _divergence_rollback(
            new_global, new_prev, carry, rcfg)

    # 7a. slot turnover: departing occupants (uploaded, or upload dropped
    # in transit) free their slots; available idle clients fill them in
    # priority order. `in_flight` scatters the retained occupancy back to
    # (K,); dead slots contribute nothing anywhere (live = False).
    depart = live & depart_k[occ]
    stay = live & ~depart
    in_flight = jnp.zeros((k_local,), bool).at[occ].max(stay,
                                                        mode="drop")
    prio = streams.sched_priority(carry.t)
    score = jnp.where(avail & ~in_flight, prio, -jnp.inf)
    top_score, top_ids = jax.lax.top_k(score, m)
    n_cand = jnp.sum((top_score > -jnp.inf).astype(jnp.int32))
    free = ~stay
    free_rank = jnp.cumsum(free.astype(jnp.int32)) - 1
    take = free & (free_rank < n_cand)
    new_occ = jnp.where(take, top_ids[jnp.clip(free_rank, 0, m - 1)],
                        occ).astype(jnp.int32)
    new_live = stay | take

    # 7b. (K,) plane bookkeeping: departed-but-unscheduled clients go idle
    # (busy_lat = +inf — never ready again until rescheduled), scheduled
    # clients get the fresh broadcast via the SAME sched_broadcast masked
    # update the dense path uses
    sched_k = jnp.zeros((k_local,), bool).at[new_occ].max(take, mode="drop")
    t_next = carry.t + 1
    lat_full = streams.latencies(t_next)
    departed_k = jnp.zeros((k_local,), bool).at[occ].max(depart, mode="drop")
    idle = departed_k & ~sched_k
    ready = jnp.where(idle, False, ready)
    busy = jnp.where(idle, jnp.asarray(jnp.inf, carry.busy_lat.dtype),
                     carry.busy_lat)
    n_ready, n_lat, n_model = sched_broadcast(
        ready, busy, carry.model_round, sched_k, lat_full, t_next)

    # EF residual hand-off on slot turnover (trace-time branch): FIRST
    # every departing slot parks its residual on the owning client's
    # (K, s) row (the scatter half of the tentpole's "(K, s) residual
    # row"), THEN the newly scheduled occupants pick their parked rows
    # back up (a same-round depart -> reschedule resumes the residual it
    # just parked), THEN the consumed rows zero — the parked plane only
    # ever holds errors nobody is currently training against.
    resid_val = resid_idx = pr_val = pr_idx = None
    if rcfg.compress and rcfg.error_feedback:
        park_row = jnp.where(depart, occ, k_local)      # OOB = no write
        resid_val = carry.resid_val.at[park_row].set(carry.slot_resid,
                                                     mode="drop")
        resid_idx = carry.resid_idx.at[park_row].set(carry.slot_resid_idx,
                                                     mode="drop")
        pr_val = jnp.where(take[:, None], resid_val[new_occ], 0.0)
        if rcfg.screen:
            # a screened slot's parked residual may be the corrupt row's
            # NaN complement — resuming it would re-poison every later
            # round of an otherwise-recovered client
            pr_val = jnp.where(jnp.isfinite(pr_val), pr_val, 0.0)
        pr_idx = resid_idx[new_occ]
        consumed = jnp.where(take, new_occ, k_local)
        resid_val = resid_val.at[consumed].set(0.0, mode="drop")

    # 7c. cohort training: ONLY the m slot rows materialize model-sized
    # work — the newly scheduled slots take their trained rows (f32 delta
    # before the storage cast, same rules as the dense path); retained
    # slots keep their in-flight payload; dead slots keep masked garbage
    trained = streams.cohort_train(new_global, x, y, t_next, new_occ)
    dtype = _storage_dtype(rcfg)

    def row_select(new, old):
        msk = take.reshape((m,) + (1,) * (new.ndim - 1))
        return jnp.where(msk, new, old)

    if rcfg.compress:
        # compressed store: the f32 delta rows are EF-compensated with the
        # resumed parked residuals (decompressed transiently — the carry
        # never holds an (m, d) plane), then support-selected, stored, and
        # their exact f32 residual re-sparsified. Non-take rows keep every
        # old slot plane (garbage residual gathers for them are discarded
        # here). Raveled single-leaf: `trained` is a bare (m, d) array.
        comp = trained - new_global[None]
        if pr_val is not None:
            comp = comp + scatter_rows(pr_val, pr_idx, d_model)
        stored, idx_new, scale_new, e_val, e_idx = _compress_plane(
            comp, rcfg=rcfg, streams=streams, t=t_next)
        pending = None
        deltas = row_select(stored, carry.deltas)
        slot_idx = row_select(idx_new, carry.slot_idx)
        slot_scale = (None if scale_new is None
                      else jnp.where(take, scale_new, carry.slot_scale))
        slot_resid = (None if e_val is None
                      else row_select(e_val, carry.slot_resid))
        slot_resid_idx = (None if e_idx is None
                          else row_select(e_idx, carry.slot_resid_idx))
    else:
        pending = None if carry.pending is None else jax.tree_util.tree_map(
            lambda tr, p: row_select(tr.astype(p.dtype), p),
            trained, carry.pending)
        if dtype == jnp.float32 and pending is not None:
            deltas = jax.tree_util.tree_map(
                lambda p, dl, g: row_select(p - g[None], dl),
                pending, carry.deltas, new_global)
        else:
            deltas = jax.tree_util.tree_map(
                lambda tr, dl, g: row_select((tr - g[None]).astype(dl.dtype),
                                             dl),
                trained, carry.deltas, new_global)
        slot_idx = slot_scale = slot_resid = slot_resid_idx = None

    n_upl = ksum(b)
    denom = jnp.maximum(n_upl, 1.0)
    out = {
        "n_participants": n_upl,
        "time": time,
        "mean_staleness": ksum(stal * b) / denom,
        "beta_mean": ksum(beta * b) / denom,
        "varsigma": jnp.where(varsigma > VARSIGMA_MIN, varsigma, 0.0),
        "p2_objective": jnp.where(n_upl > 0, p2_obj, jnp.inf),
        "n_screened": n_screened,
        "rolled_back": rolled,
    }
    carry = RoundCarry(t=t_next, time=time, ready=n_ready,
                       busy_lat=n_lat, model_round=n_model,
                       global_vec=new_global, prev_global=new_prev,
                       pending=pending, deltas=deltas, held=None,
                       slot_client=new_occ, slot_live=new_live,
                       slot_idx=slot_idx, slot_scale=slot_scale,
                       slot_resid=slot_resid,
                       slot_resid_idx=slot_resid_idx,
                       resid_val=resid_val, resid_idx=resid_idx,
                       good_global=good, good_norm2=good_n2)
    return carry, out


def init_round_carry(vec, x, y, *, streams: RoundStreams,
                     pending_dtype: str = "float32",
                     keep_pending: bool = True,
                     rcfg: RoundCfg | None = None) -> RoundCarry:
    """Round-0 kick-off: broadcast w_g^0 to everyone and precompute their
    local training (mirrors ``PAOTAServer.__init__``). ``vec`` is the
    params pytree (raveled = single (d,) leaf); shapes follow the streams'
    view of the federation (all K single-device; K/n per shard). The f32
    delta (``trained - w_g^0``) is formed before the optional storage
    cast. ``keep_pending=False`` (transmit='delta') carries the delta
    plane only. ``rcfg`` (only its divergence knob is read) seeds the
    last-good rollback slot from w_g^0 when the detector is on."""
    trained = streams.local_train(vec, x, y, 0)
    k_local = jax.tree_util.tree_leaves(trained)[0].shape[0]
    dtype = jnp.dtype(pending_dtype)
    diverg = bool(rcfg is not None and rcfg.divergence_factor > 0.0)
    return RoundCarry(
        t=jnp.int32(0),
        time=jnp.float32(0.0),
        ready=jnp.zeros((k_local,), bool),
        busy_lat=streams.latencies(0),
        model_round=jnp.zeros((k_local,), jnp.int32),
        global_vec=vec,
        prev_global=vec,
        pending=_cast_rows(trained, dtype) if keep_pending else None,
        deltas=jax.tree_util.tree_map(
            lambda tr, g: (tr - g[None]).astype(dtype), trained, vec),
        good_global=vec if diverg else None,
        good_norm2=_tree_sq_norm(vec) if diverg else None,
    )


def init_cohort_carry(vec, x, y, *, streams: RoundStreams, k: int, m: int,
                      n_real=None, pending_dtype: str = "float32",
                      keep_pending: bool = True,
                      rcfg: RoundCfg | None = None) -> RoundCarry:
    """Round-0 kick-off of the active-cohort carry: the first
    ``min(m, n_real)`` clients (in id order) fill the slots and receive
    the broadcast; everyone else idles at ``busy_lat = +inf`` until a slot
    frees. ``k``/``m`` are this shard's local extents under sharding;
    ``n_real`` (static or traced) caps the live slots below the phantom
    padding — phantom rows must never occupy a live slot. At m = K with
    no phantoms this is exactly ``init_round_carry`` plus the identity
    slot map, which is what makes cohort_size=K allclose to the dense
    path from round 0.

    ``rcfg`` (only its compression knobs are read) switches the payload
    plane to the compressed (m, s) form: the round-0 deltas run through
    the same ``_compress_plane`` stage the scan uses, with empty (K, s)
    parked-residual planes when error feedback is on."""
    if m > k:
        raise ValueError(f"cohort_size={m} exceeds the client-plane extent "
                         f"{k}")
    occ = jnp.arange(m, dtype=jnp.int32)
    n_real = k if n_real is None else n_real
    live = occ < jnp.minimum(jnp.asarray(m, jnp.int32),
                             jnp.asarray(n_real, jnp.int32))
    sched_k = jnp.zeros((k,), bool).at[occ].max(live, mode="drop")
    lat_full = streams.latencies(0)
    busy = jnp.where(sched_k, lat_full,
                     jnp.asarray(jnp.inf, lat_full.dtype))
    trained = streams.cohort_train(vec, x, y, 0, occ)
    dtype = jnp.dtype(pending_dtype)
    compress = bool(rcfg is not None and rcfg.compress)
    diverg = bool(rcfg is not None and rcfg.divergence_factor > 0.0)
    good = vec if diverg else None
    good_n2 = _tree_sq_norm(vec) if diverg else None
    if compress:
        # compressed payloads ride transmit='delta' (driver-enforced);
        # raveled single-leaf, so `trained` is a bare (m, d) array
        stored, idx, scale, e_val, e_idx = _compress_plane(
            trained - vec[None], rcfg=rcfg, streams=streams, t=0)
        s = stored.shape[1]
        ef = rcfg.error_feedback
        return RoundCarry(
            t=jnp.int32(0),
            time=jnp.float32(0.0),
            ready=jnp.zeros((k,), bool),
            busy_lat=busy,
            model_round=jnp.zeros((k,), jnp.int32),
            global_vec=vec,
            prev_global=vec,
            pending=None,
            deltas=stored,
            slot_client=occ,
            slot_live=live,
            slot_idx=idx,
            slot_scale=scale,
            slot_resid=e_val,
            slot_resid_idx=e_idx,
            resid_val=jnp.zeros((k, s), jnp.float32) if ef else None,
            resid_idx=jnp.zeros((k, s), jnp.int32) if ef else None,
            good_global=good,
            good_norm2=good_n2,
        )
    return RoundCarry(
        t=jnp.int32(0),
        time=jnp.float32(0.0),
        ready=jnp.zeros((k,), bool),
        busy_lat=busy,
        model_round=jnp.zeros((k,), jnp.int32),
        global_vec=vec,
        prev_global=vec,
        pending=_cast_rows(trained, dtype) if keep_pending else None,
        deltas=jax.tree_util.tree_map(
            lambda tr, g: (tr - g[None]).astype(dtype), trained, vec),
        slot_client=occ,
        slot_live=live,
        good_global=good,
        good_norm2=good_n2,
    )


def scan_rounds(carry: RoundCarry, x, y, n_rounds: int, *, rcfg: RoundCfg,
                streams: RoundStreams, axis_name=None, tp=None):
    """``lax.scan`` of ``paota_round_step`` over ``n_rounds`` periods —
    zero host round-trips inside. The scan nests cleanly under
    ``jax.shard_map`` (the sharded driver wraps THIS function, so a whole
    multi-round advance is one collective program). Drivers jit this with
    the carry donated (``donate_argnums``): the K x d planes of scan r
    are reused in place by scan r+1 instead of being copied across the
    call boundary. ``tp``: intra-client TP topology, threaded per step."""
    def step(c, _):
        return paota_round_step(c, x, y, rcfg=rcfg, streams=streams,
                                axis_name=axis_name, tp=tp)
    return jax.lax.scan(step, carry, None, length=n_rounds)


def scan_windows(carry: RoundCarry, x, y, n_windows: int, *, rcfg: RoundCfg,
                 streams: RoundStreams, axis_name, grouping: GroupTopology):
    """Grouped-aggregation scan: ``n_windows`` windows of
    ``rcfg.group_period`` periods each. The window is Python-UNROLLED
    inside the scan step (``window_j`` is static — the staleness weight and
    the sync/non-sync collective structure are baked per position), so the
    compiled scan body contains exactly ONE cross-pod model-sized
    all-reduce per window — the invariant the grouped benchmark's HLO
    check pins. Per-period metrics come back stacked (n_windows, N);
    callers reshape to the flat (n_rounds,) timeline."""
    def window(c, _):
        outs = []
        for j in range(rcfg.group_period):
            c, out = paota_round_step(c, x, y, rcfg=rcfg, streams=streams,
                                      axis_name=axis_name, grouping=grouping,
                                      window_j=j)
            outs.append(out)
        stacked = {k: jnp.stack([o[k] for o in outs]) for k in outs[0]}
        return c, stacked
    return jax.lax.scan(window, carry, None, length=n_windows)
