"""Functional PAOTA round core — ONE implementation of the aggregation
period, shared by every driver.

The federated model is an arbitrary params PYTREE: every model-sized
quantity (globals, pending local models, deltas) is carried leaf-wise,
and every cross-model scalar (per-client norms and cosines, the AirComp
superposition, varsigma) is computed as a tree-reduced sum — per-leaf
partials accumulated locally, then reduced ONCE (one psum per round under
sharding, never one per leaf). The raveled federation is the trivial
single-(K, d)-leaf pytree and executes the historical op sequence
bit-for-bit; ``waterfill_beta_jnp`` / ``power_from_beta`` stay
shape-agnostic consumers of the reduced (K,) scalars.

``paota_round_step`` is the pure round transition (``RoundCarry`` in,
``RoundCarry`` out): scheduler advance -> eq.-25 factors -> water-filling
P2 -> channel + instantaneous cap (7) -> AirComp -> zero-uploader-guarded
update -> broadcast + local train. It is parameterized by

* ``RoundCfg`` — the static problem constants (Theorem-1 c1/c0, channel
  power/noise, the aggregation period), a plain NamedTuple of Python
  scalars closed over at trace time;
* ``RoundStreams`` — the per-driver data/RNG callbacks (local training,
  latency draws, channel draws, the per-round noise key). The callbacks
  are what let the same core run single-device (callbacks see all K
  clients) and mesh-sharded (callbacks see this shard's K/n slice of
  identical global draws);
* ``axis_name`` — ``None`` for the single-device form (the exact op
  sequence ``FusedPAOTA._step`` always ran — the extraction is
  bit-identical), or the mesh client axis name(s) under ``jax.shard_map``:
  per-client stages (local SGD, factors, channel, power) stay fully
  parallel and only the AirComp superposition, the P2 water-filling
  reductions, and the round metrics cross shards as ``psum``/``pmin``/
  ``pmax`` collectives.

Consumers: ``repro.fl.fused.FusedPAOTA`` (single device, scan over
rounds), ``repro.fl.sharded.ShardedPAOTA`` (the same scan under
``shard_map`` over the mesh client axis), and the host-path
``repro.fl.server.PAOTAServer`` whose numpy round consumes the shared
stage helpers (``eq25_factors`` / ``constraint7_powers``) so the three
implementations cannot drift apart stage by stage.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.aggregation import (guarded_global_update,
                                    paota_aggregate_stacked)
from repro.core.aircomp import VARSIGMA_MIN, effective_power_cap
from repro.core.boxqp import waterfill_beta_jnp
from repro.core.power_control import (client_sq_norms, cosine_similarity,
                                      global_sq_norm, power_from_beta,
                                      similarity_factor, staleness_factor)
from repro.core.scheduler import sched_advance, sched_broadcast


class RoundCarry(NamedTuple):
    """Device-resident PAOTA state threaded through the scan.

    The federated model is an arbitrary params PYTREE: ``global_vec`` /
    ``prev_global`` hold one copy of the model (leaves of the params'
    natural shapes), ``pending`` / ``starts`` hold the client-stacked form
    (every leaf with a leading K axis). The raveled federation is the
    trivial single-leaf instance — a bare (d,) vector / (K, d) matrix —
    and executes the exact historical op sequence (a jnp array IS a
    one-leaf pytree, so nothing special-cases it).

    Under the sharded driver the ``(K,)`` fields and the leading axis of
    every stacked leaf are laid over the mesh client axis (each shard
    carries its K/n rows); the scalars and the global-model leaves are
    replicated.
    """
    t: jnp.ndarray            # i32 — scheduler round counter
    time: jnp.ndarray         # f32 — simulated clock (seconds)
    ready: jnp.ndarray        # (K,) bool — b_k at the aggregation slot
    busy_until: jnp.ndarray   # (K,) f32 — local-training completion times
    model_round: jnp.ndarray  # (K,) i32 — round each client trains on
    global_vec: jnp.ndarray   # params pytree / (d,) — w_g^t
    prev_global: jnp.ndarray  # params pytree / (d,) — w_g^{t-1} (direction)
    pending: jnp.ndarray      # (K, ...)-leaf pytree — in-flight local models
    starts: jnp.ndarray       # (K, ...)-leaf pytree — global each trained from


class RoundCfg(NamedTuple):
    """Static per-federation constants of the round (Python scalars only —
    closed over at trace time, never traced)."""
    omega: float              # staleness constant Omega (Sec. IV-A)
    c1: float                 # L eps^2 K   (P2 term-d scale)
    c0: float                 # 2 L d sigma_n^2 (P2 term-e numerator)
    p_max_watts: float        # per-client power budget P_max
    sigma_n: float            # channel noise std (concrete float)
    delta_t: float            # aggregation period (seconds)
    transmit_delta: bool      # True: clients transmit dw_k; False: w_k


class RoundStreams(NamedTuple):
    """Per-driver callbacks: how this driver's shard of clients trains and
    draws its randomness. All callbacks are traced (called inside jit /
    shard_map); under sharding each returns this shard's rows of the SAME
    global draws the single-device form makes, so trajectories agree.
    """
    local_train: Callable     # (global tree, x, y, round) -> stacked tree
                              # of (K_local, ...) leaves ((K_local, d) for
                              # the raveled single-leaf federation)
    latencies: Callable       # (round) -> (K_local,) latency draws
    channel: Callable         # (round) -> (K_local,) |h_k| draws
    noise_key: Callable       # (round) -> AWGN key (replicated)


# ---------------------------------------------------------------------------
# shared stage helpers (host server + fused/sharded core)
# ---------------------------------------------------------------------------

def eq25_factors(pending, starts, global_vec, prev_global, stal, omega,
                 use_kernel: bool = False):
    """Stage 2 of the round — eq. 25 inputs: local-update deltas, staleness
    factors rho_k, gradient-similarity factors theta_k. Pure jnp over
    params pytrees (raveled = single leaf); per-client along the leading
    axis, so it is shard-local under the client mesh axis (the cosine and
    norm reductions run over the model dims, which every shard holds whole
    — per-leaf partials accumulate locally, no collective).

    Returns (deltas pytree, rho, theta)."""
    deltas = jax.tree_util.tree_map(jnp.subtract, pending, starts)
    gdir = jax.tree_util.tree_map(jnp.subtract, global_vec, prev_global)
    gnorm = jnp.sqrt(global_sq_norm(gdir))
    cos = jnp.where(gnorm < 1e-12, 0.0,
                    cosine_similarity(deltas, gdir, use_kernel=use_kernel))
    theta = similarity_factor(cos)
    rho = staleness_factor(stal, omega)
    return deltas, rho, theta


def constraint7_powers(powers, payload, h, p_max):
    """Stage 4 — instantaneous power constraint (7) under the sampled
    channel: p_k <- min(p_k, |h_k| sqrt(P_max / ||w_k||^2)), with
    ||w_k||^2 tree-reduced over every leaf of the payload pytree.
    Per-client, shard-local."""
    w_norm2 = client_sq_norms(payload)
    return jnp.minimum(powers, effective_power_cap(w_norm2, h, p_max))


# ---------------------------------------------------------------------------
# the round transition
# ---------------------------------------------------------------------------

def paota_round_step(carry: RoundCarry, x, y, *, rcfg: RoundCfg,
                     streams: RoundStreams, axis_name=None):
    """One PAOTA aggregation period as a pure function.

    ``axis_name=None`` reproduces ``FusedPAOTA``'s historical op sequence
    bit-for-bit. With a mesh axis name (or tuple of names), the (K,) /
    (K, d) carry rows are this shard's clients and the cross-client
    reductions go through collectives.

    Returns (next_carry, per-round metrics dict of replicated scalars)."""
    k_local = carry.ready.shape[0]

    def ksum(v, axis=None):
        s = jnp.sum(v, axis=axis)
        return s if axis_name is None else jax.lax.psum(s, axis_name)

    # 1. scheduler advance: who finished inside this period, staleness.
    # The slot clock is recomputed as (t+1) * delta_t rather than
    # accumulated +=, so the float32 clock cannot drift from the host
    # reference's float64 one over long scans (a `busy_until <= time`
    # boundary flip would silently fork the trajectories; a residual
    # single-rounding difference remains for delta_t values inexact in
    # float32)
    time = (carry.t + 1).astype(jnp.float32) * jnp.float32(rcfg.delta_t)
    ready, stal = sched_advance(carry.ready, carry.busy_until,
                                carry.model_round, time, carry.t)
    b = ready.astype(jnp.float32)
    stal = stal.astype(jnp.float32)

    # 2. staleness + gradient-similarity factors (eq. 25)
    deltas, rho, theta = eq25_factors(carry.pending, carry.starts,
                                      carry.global_vec, carry.prev_global,
                                      stal, rcfg.omega)

    # 3. P2 -> beta -> powers (exact water-filling, pure jnp; the grid and
    # golden-section reductions over K run as psums under sharding)
    p_max = jnp.full((k_local,), rcfg.p_max_watts, jnp.float32)
    beta, p2_obj = waterfill_beta_jnp(rho, theta, p_max, b, rcfg.c1, rcfg.c0,
                                      axis_name=axis_name)
    powers = power_from_beta(beta, rho, theta, p_max)

    # 4. instantaneous power constraint (7) under the sampled channel
    payload = deltas if rcfg.transmit_delta else carry.pending
    h = streams.channel(carry.t)
    powers = constraint7_powers(powers, payload, h, rcfg.p_max_watts)

    # 5. AirComp superposition + AWGN + normalization (eqs. 6+8) — the
    # same jnp helper the host reference calls; under sharding the
    # superposition is a psum over the client axis with the single shared
    # noise realization joining once, after the reduction
    agg, varsigma = paota_aggregate_stacked(
        payload, powers, b, streams.noise_key(carry.t), rcfg.sigma_n,
        axis_name=axis_name)

    # 6. zero-uploader guard: hold w_g when nothing superposed
    new_global, new_prev = guarded_global_update(
        carry.global_vec, carry.prev_global, agg, varsigma,
        delta=rcfg.transmit_delta)

    # 7. broadcast w^{r+1}: every uploader restarts local training
    t_next = carry.t + 1
    lat = streams.latencies(t_next)
    n_ready, n_busy, n_model = sched_broadcast(
        ready, carry.busy_until, carry.model_round, ready, time, lat, t_next)
    trained = streams.local_train(new_global, x, y, t_next)

    def row_select(new, old):
        m = ready.reshape((k_local,) + (1,) * (new.ndim - 1))
        return jnp.where(m, new, old)

    pending = jax.tree_util.tree_map(row_select, trained, carry.pending)
    starts = jax.tree_util.tree_map(
        lambda g, s: row_select(jnp.broadcast_to(g[None], s.shape), s),
        new_global, carry.starts)

    n_upl = ksum(b)
    denom = jnp.maximum(n_upl, 1.0)
    out = {
        "n_participants": n_upl,
        "time": time,
        "mean_staleness": ksum(stal * b) / denom,
        "beta_mean": ksum(beta * b) / denom,
        "varsigma": jnp.where(varsigma > VARSIGMA_MIN, varsigma, 0.0),
        # a zero-uploader P2 is vacuous (every candidate t is 0 and the
        # solver's ratio degenerates to c0/clamp ~ 1e22); report inf like
        # the host reference's skipped-round branch does
        "p2_objective": jnp.where(n_upl > 0, p2_obj, jnp.inf),
    }
    carry = RoundCarry(t=t_next, time=time, ready=n_ready,
                       busy_until=n_busy, model_round=n_model,
                       global_vec=new_global, prev_global=new_prev,
                       pending=pending, starts=starts)
    return carry, out


def init_round_carry(vec, x, y, *, streams: RoundStreams) -> RoundCarry:
    """Round-0 kick-off: broadcast w_g^0 to everyone and precompute their
    local training (mirrors ``PAOTAServer.__init__``). ``vec`` is the
    params pytree (raveled = single (d,) leaf); shapes follow the streams'
    view of the federation (all K single-device; K/n per shard)."""
    pending = streams.local_train(vec, x, y, 0)
    k_local = jax.tree_util.tree_leaves(pending)[0].shape[0]
    return RoundCarry(
        t=jnp.int32(0),
        time=jnp.float32(0.0),
        ready=jnp.zeros((k_local,), bool),
        busy_until=streams.latencies(0),
        model_round=jnp.zeros((k_local,), jnp.int32),
        global_vec=vec,
        prev_global=vec,
        pending=pending,
        starts=jax.tree_util.tree_map(
            lambda g: jnp.broadcast_to(g[None], (k_local,) + g.shape), vec),
    )


def scan_rounds(carry: RoundCarry, x, y, n_rounds: int, *, rcfg: RoundCfg,
                streams: RoundStreams, axis_name=None):
    """``lax.scan`` of ``paota_round_step`` over ``n_rounds`` periods —
    zero host round-trips inside. The scan nests cleanly under
    ``jax.shard_map`` (the sharded driver wraps THIS function, so a whole
    multi-round advance is one collective program)."""
    def step(c, _):
        return paota_round_step(c, x, y, rcfg=rcfg, streams=streams,
                                axis_name=axis_name)
    return jax.lax.scan(step, carry, None, length=n_rounds)
