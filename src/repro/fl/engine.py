"""Federation engines: who runs the M local SGD steps for a set of clients.

``LegacyEngine`` is the seed implementation — a Python loop over
``FLClient.local_train``, one jit cache per client, ``local_steps`` host
round-trips per client per round. Kept as the reference for equivalence
tests and as the slow baseline in ``benchmarks/fl_engine_bench.py``.

``BatchedEngine`` is the scaled implementation: the whole federation's
data lives device-resident as padded ``(K, n_max, ...)`` arrays
(``repro.data.pipeline.stack_federation``), and one jitted function runs
``lax.scan`` over the M local steps inside ``jax.vmap`` over the K
clients. One compilation covers every round at every participation
pattern; the per-round host work is only the numpy batch-index planning.

Determinism/equivalence contract: both engines draw minibatch indices
from the same stateful ``ClientData.batch_indices`` stream, so with equal
seeds they train on identical sample sequences and produce global models
equal up to float-reduction reordering (verified by
tests/test_engine_equivalence.py with ``allclose``).

``BatchedEngine.enable_counter_plan`` switches to the third planning mode:
stateless counter-based ``jax.random`` plans (``repro.data.pipeline
.counter_batch_plan``) keyed on the broadcast round. This is the mode the
fused on-device round scans with — and the mode the host-path server runs
in when it serves as the fused path's reference (PAOTAConfig.rng
= "counter").

Masking semantics for a partial broadcast (only ``ids`` restart): the
batched call still executes the fused K-client computation — clients
outside ``ids`` get an all-zeros index plan and their (discarded) output
row is never read; their epoch cursors do not advance. Padding rows of
ragged clients are never gathered because index plans are drawn from
``range(n_k)`` only.
"""
from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree

from repro.core.scheduler import TAG_BATCH, round_tag_key
from repro.data.pipeline import ClientData, counter_batch_plan, stack_federation
from repro.fl.client import FLClient


class LegacyEngine:
    """Reference engine: per-client Python loop (the seed behaviour)."""

    name = "legacy"

    def __init__(self, clients: List[FLClient]):
        self.clients = clients
        self.n_clients = len(clients)
        self.n_samples = np.array([c.n_samples for c in clients], np.int64)

    def local_train(self, params, ids: Sequence[int],
                    round_idx=None) -> np.ndarray:
        """Train clients `ids` from `params`; returns (len(ids), d) raveled
        trained models, rows ordered as `ids`. An empty broadcast returns
        shape (0, d) — the model dimension is preserved so callers can
        concatenate without special-casing. ``round_idx`` is accepted for
        interface parity with the batched engine and ignored (the legacy
        loop only supports the stateful host-cursor plans)."""
        out = []
        for k in ids:
            trained = self.clients[int(k)].local_train(params)
            tv, _ = ravel_pytree(trained)
            out.append(np.asarray(tv))
        if not out:
            d = int(ravel_pytree(params)[0].size)
            return np.zeros((0, d))
        return np.stack(out)


class BatchedEngine:
    """vmap-over-clients, scan-over-steps engine: one compile per federation."""

    name = "batched"

    def __init__(self, fed: List[ClientData], loss_fn, batch_size: int = 32,
                 lr: float = 0.05, local_steps: int = 5):
        self.fed = fed  # epoch cursors (host-side batch planning) live here
        self.loss_fn = loss_fn
        self.batch_size = batch_size
        self.lr = lr
        self.local_steps = local_steps
        self.n_clients = len(fed)
        stacked = stack_federation(fed)
        self.n_samples = stacked.n_samples
        # NOTE: n_k >= batch_size is a restriction of the HOST epoch-cursor
        # planner only (it slices fixed windows from the epoch permutation)
        # and is enforced at first host-plan use — counter plans draw
        # bounded by n_k, so short-batch clients federate fine there
        self._x = jnp.asarray(stacked.x)
        self._y = jnp.asarray(stacked.y)
        self._n_dev = jnp.asarray(self.n_samples, jnp.int32)
        self._idx = np.zeros((self.n_clients, local_steps, batch_size),
                             np.int32)
        self._train = jax.jit(self._train_all)
        # stateless counter-based planning (enable_counter_plan): index
        # plans become a pure function of (plan key, round) — required by
        # the fused round and by the host reference compared against it
        self.plan = "host"
        self._plan_key = None
        # optional per-client hyperparameter heterogeneity: (K,) arrays
        # installed by set_heterogeneity (None = homogeneous — the exact
        # historical program)
        self._steps_k = None
        self._batch_k = None

    @classmethod
    def from_clients(cls, clients: List[FLClient]) -> "BatchedEngine":
        """Build from a homogeneous FLClient list (same hyperparameters)."""
        c0 = clients[0]
        for c in clients[1:]:
            if (c.loss_fn is not c0.loss_fn or c.batch_size != c0.batch_size
                    or c.lr != c0.lr or c.local_steps != c0.local_steps):
                raise ValueError("BatchedEngine requires homogeneous client "
                                 "hyperparameters; got a mixed federation")
        return cls([c.data for c in clients], c0.loss_fn,
                   batch_size=c0.batch_size, lr=c0.lr,
                   local_steps=c0.local_steps)

    # ------------------------------------------------------------------
    def set_heterogeneity(self, steps_k=None, batch_k=None) -> None:
        """Install per-client (K,) hyperparameter heterogeneity: local-step
        counts (1 <= steps_k <= local_steps — extra plan rows become no-op
        steps via a zeroed step size) and/or batch sizes (1 <= batch_k <=
        batch_size — the counter plan repeats each client's first b_k
        draws cyclically across the fixed-width row, so the averaged
        gradient is EXACTLY the b_k-minibatch gradient whenever b_k
        divides batch_size). None leaves a dimension homogeneous. The
        fused/sharded drivers install these from ``ScenarioConfig
        .het_steps`` / ``.het_batch``."""
        if steps_k is not None:
            s = np.asarray(steps_k)
            if s.shape != (self.n_clients,):
                raise ValueError(f"steps_k shape {s.shape} != "
                                 f"({self.n_clients},)")
            if s.min() < 1 or s.max() > self.local_steps:
                raise ValueError(f"steps_k must lie in [1, local_steps="
                                 f"{self.local_steps}]; got "
                                 f"[{int(s.min())}, {int(s.max())}]")
            steps_k = jnp.asarray(s, jnp.int32)
        if batch_k is not None:
            bks = np.asarray(batch_k)
            if bks.shape != (self.n_clients,):
                raise ValueError(f"batch_k shape {bks.shape} != "
                                 f"({self.n_clients},)")
            if bks.min() < 1 or bks.max() > self.batch_size:
                raise ValueError(f"batch_k must lie in [1, batch_size="
                                 f"{self.batch_size}]; got "
                                 f"[{int(bks.min())}, {int(bks.max())}]")
            batch_k = jnp.asarray(bks, jnp.int32)
        self._steps_k = steps_k
        self._batch_k = batch_k

    def steps_for(self, client_ids=None):
        """This federation's (K,) per-client step counts gathered at
        ``client_ids`` (None = all rows; returns None when homogeneous) —
        the form ``_train_all``/``_train_all_tree`` consume."""
        if self._steps_k is None:
            return None
        if client_ids is None:
            return self._steps_k
        return self._steps_k[jnp.asarray(client_ids, jnp.int32)]

    def _train_one(self, params, xc, yc, plan, n_steps=None):
        """One client's M local SGD steps from the broadcast ``params``
        pytree; returns the trained params pytree (no ravel).

        ``n_steps`` (traced scalar) masks heterogeneous step counts: plan
        rows at positions >= n_steps multiply their gradient by an exactly
        zero step size, so ``pp - 0 * gg == pp`` bit for bit and a client
        with n_steps = s equals the s-step homogeneous run on the same
        plan rows. ``None`` keeps the historical unmasked program."""
        def step(p, sel):
            batch = {"x": xc[sel], "y": yc[sel]}
            g = jax.grad(self.loss_fn)(p, batch)
            return jax.tree_util.tree_map(
                lambda pp, gg: pp - self.lr * gg, p, g), None

        def masked_step(p, inp):
            sel, i = inp
            batch = {"x": xc[sel], "y": yc[sel]}
            g = jax.grad(self.loss_fn)(p, batch)
            lr = jnp.float32(self.lr) * (i < n_steps)
            return jax.tree_util.tree_map(
                lambda pp, gg: pp - lr * gg, p, g), None
        # M is small (a handful of local steps): full unroll lets XLA
        # fuse across steps instead of paying while-loop overhead
        if n_steps is None:
            p, _ = jax.lax.scan(step, params, plan, unroll=True)
        else:
            pos = jnp.arange(plan.shape[0], dtype=jnp.int32)
            p, _ = jax.lax.scan(masked_step, params, (plan, pos),
                                unroll=True)
        return p

    def _train_all(self, params, x, y, idx, n_steps=None):
        """params: pytree of (…) broadcast to every client; x/y: padded
        (K, n_max, …) data; idx: (K, M, B) minibatch plans; ``n_steps``:
        optional (K,) heterogeneous step counts. Returns (K, d) raveled
        trained models.

        The ravel happens ONCE on the stacked result — reshape each
        (K, ...) leaf to (K, d_leaf) and concatenate in tree_flatten
        order — which is value-identical to ``ravel_pytree`` per client
        (same leaf order, same row-major ravel) but costs one (K, d)
        write instead of a vmapped per-client concatenate (~40% of the
        train call at transformer-scale d)."""
        trained = self._train_all_tree(params, x, y, idx, n_steps)
        leaves = jax.tree_util.tree_leaves(trained)
        if len(leaves) == 1:
            return leaves[0].reshape((leaves[0].shape[0], -1))
        return jnp.concatenate(
            [l.reshape((l.shape[0], -1)) for l in leaves], axis=1)

    def _train_all_tree(self, params, x, y, idx, n_steps=None):
        """Pytree twin of ``_train_all``: same local SGD, but the trained
        models come back as a client-stacked params pytree ((K, ...)
        leaves) instead of a raveled (K, d) matrix — the form the
        pytree-native round core carries (repro.fl.runtime)."""
        if n_steps is None:
            return jax.vmap(
                lambda xc, yc, plan: self._train_one(params, xc, yc, plan)
            )(x, y, idx)
        return jax.vmap(
            lambda xc, yc, plan, ns: self._train_one(params, xc, yc, plan,
                                                     ns)
        )(x, y, idx, n_steps)

    def enable_counter_plan(self, key) -> None:
        """Switch minibatch planning to the stateless counter scheme: the
        (K, M, B) plan for broadcast round r is ``counter_batch_plan``
        keyed on round_tag_key(key, r, TAG_BATCH). Epoch cursors in
        ``self.fed`` are no longer consumed."""
        self.plan = "counter"
        self._plan_key = key

    def round_plan(self, round_idx, client_ids=None, n_samples=None):
        """Counter-mode (K, M, B) index plan for broadcast round
        ``round_idx`` (host path and fused path call the same function).
        A mesh shard — or the active cohort — passes its ``client_ids``
        slice plus the matching ``n_samples`` rows and gets exactly its
        rows of the full plan (each client's draw depends only on the key
        and its own id/size). Heterogeneous batch sizes, when installed,
        gather by the same ids."""
        key = round_tag_key(self._plan_key, round_idx, TAG_BATCH)
        n = self._n_dev if n_samples is None else n_samples
        bs = None
        if self._batch_k is not None:
            bs = (self._batch_k if client_ids is None
                  else self._batch_k[jnp.asarray(client_ids, jnp.int32)])
        return counter_batch_plan(key, n, self.local_steps,
                                  self.batch_size, client_ids=client_ids,
                                  batch_sizes=bs)

    def _broadcast_plans(self, ids, round_idx):
        """(K, M, B) index plans for a broadcast of ``ids``: the full
        counter plan in counter mode, host epoch-cursor plans (zeros for
        non-broadcast rows) otherwise."""
        if self.plan == "counter":
            if round_idx is None:
                raise ValueError("counter-plan engine needs the broadcast "
                                 "round index")
            return self.round_plan(int(round_idx))
        if int(self.n_samples.min()) < self.batch_size:
            raise ValueError(
                f"host epoch-cursor plans need n_k >= batch_size for "
                f"fixed-shape minibatches (min n_k="
                f"{int(self.n_samples.min())}, batch_size="
                f"{self.batch_size}); use counter plans "
                f"(enable_counter_plan) or LegacyEngine for short-batch "
                f"clients")
        self._idx[:] = 0
        for k in ids:
            self._idx[k] = np.stack(list(
                self.fed[k].batch_indices(self.batch_size,
                                          self.local_steps)))
        return jnp.asarray(self._idx)

    def local_train_full(self, params, ids: Sequence[int],
                         round_idx=None) -> jnp.ndarray:
        """Device-resident full-federation training: the whole (K, d)
        trained stack stays on device with FIXED shapes — the host PAOTA
        server masks out the non-broadcast rows itself instead of
        gathering ``ids`` (a varying-length gather/scatter re-lowered a
        fresh XLA program for every distinct participation count, and the
        numpy round-trip was the measured host-reference ceiling at
        K ~ 10^4). Rows outside ``ids`` are untrained garbage (zero index
        plans / unconsumed counter rows) and MUST be masked by the
        caller."""
        ids = np.asarray(ids, np.int64)
        idx = self._broadcast_plans(ids, round_idx)
        return self._train(params, self._x, self._y, idx, self._steps_k)

    def local_train(self, params, ids: Sequence[int],
                    round_idx=None) -> np.ndarray:
        ids = np.asarray(ids, np.int64)
        flat = self.local_train_full(params, ids, round_idx=round_idx)
        # subset on device: only the requested rows cross to host
        return np.asarray(flat[jnp.asarray(ids)])


def make_engine(clients, kind: str = "batched"):
    """Engine factory used by the servers.

    `clients` may be an engine instance (returned unchanged), or a list of
    FLClient to wrap in the requested engine kind.
    """
    if hasattr(clients, "local_train") and hasattr(clients, "n_clients"):
        return clients
    if kind == "batched":
        return BatchedEngine.from_clients(list(clients))
    if kind == "legacy":
        return LegacyEngine(list(clients))
    raise ValueError(f"unknown engine kind: {kind!r} "
                     "(expected 'batched' or 'legacy')")
