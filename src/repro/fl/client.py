"""Edge-device client: M local SGD steps from a received global model
(eq. 3 / eq. 4 — the staleness bookkeeping lives in the scheduler; the
client always trains from whatever global model it last received)."""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import ClientData


class FLClient:
    def __init__(self, data: ClientData, loss_fn: Callable,
                 batch_size: int = 32, lr: float = 0.05, local_steps: int = 5):
        self.data = data
        self.loss_fn = loss_fn
        self.batch_size = batch_size
        self.lr = lr
        self.local_steps = local_steps
        self._step = jax.jit(self._sgd_step)

    def _sgd_step(self, params, batch):
        g = jax.grad(self.loss_fn)(params, batch)
        return jax.tree_util.tree_map(lambda p, gg: p - self.lr * gg, params, g)

    def local_train(self, params):
        """w_k = w_g - eta * sum_m grad F_k (eq. 3): M minibatch SGD steps.

        Batch selection goes through ``ClientData.batch_indices`` — the same
        index plan the batched engine consumes — so the two engines see
        identical minibatches at identical RNG state."""
        for sel in self.data.batch_indices(self.batch_size, self.local_steps):
            jb = {"x": jnp.asarray(self.data.x[sel]),
                  "y": jnp.asarray(self.data.y[sel])}
            params = self._step(params, jb)
        return params

    @property
    def n_samples(self) -> int:
        return len(self.data)
