"""Evaluation + experiment recording for the FL experiments."""
from __future__ import annotations

import csv
import os
from typing import Callable, List

import jax.numpy as jnp
import numpy as np


def evaluate(params, x_test: np.ndarray, y_test: np.ndarray,
             apply_fn: Callable, batch: int = 1024) -> dict:
    correct, total, loss_sum = 0, 0, 0.0
    for i in range(0, len(y_test), batch):
        xb = jnp.asarray(x_test[i:i + batch])
        yb = y_test[i:i + batch]
        logits = np.asarray(apply_fn(params, xb))
        pred = logits.argmax(-1)
        correct += int((pred == yb).sum())
        lse = np.log(np.exp(logits - logits.max(-1, keepdims=True)).sum(-1)) \
            + logits.max(-1)
        loss_sum += float((lse - logits[np.arange(len(yb)), yb]).sum())
        total += len(yb)
    return {"accuracy": correct / total, "loss": loss_sum / total}


def time_to_accuracy(history: List[dict], targets=(0.5, 0.6, 0.7, 0.8)):
    """Table I: first (round, time) reaching each target accuracy."""
    out = {}
    for tgt in targets:
        hit = next((h for h in history if h.get("accuracy", 0) >= tgt), None)
        out[tgt] = (hit["round"], hit["time"]) if hit else (None, None)
    return out


def write_csv(path: str, rows: List[dict]):
    if not rows:
        return
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    keys = sorted({k for r in rows for k in r})
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=keys)
        w.writeheader()
        for r in rows:
            w.writerow(r)
