from repro.fl.baselines import COTAFServer, LocalSGDServer, SyncConfig  # noqa: F401
from repro.fl.client import FLClient  # noqa: F401
from repro.fl.engine import BatchedEngine, LegacyEngine, make_engine  # noqa: F401
from repro.fl.metrics import evaluate, time_to_accuracy, write_csv  # noqa: F401
from repro.fl.server import PAOTAConfig, PAOTAServer  # noqa: F401
from repro.fl.fused import FusedPAOTA  # noqa: F401  (after server: dep order)
from repro.fl.sharded import ShardedPAOTA  # noqa: F401  (after fused)
