"""Mesh-sharded PAOTA: the fused round scanned under ``jax.shard_map``
over the mesh client axis.

``FusedPAOTA`` runs the whole aggregation period as one device call — but
on ONE device: a K = 10^4..10^5 federation serializes through a single
chip while the rest of the mesh idles. ``ShardedPAOTA`` lays the round
core's (K,) / (K, ...) carry rows and the engine's padded (K, n_max, ...)
federation over the mesh client axis (``repro.launch.mesh.data_axes`` /
``client_axes_for``; specs from ``repro.sharding.rules``) and runs the
SAME ``repro.fl.runtime`` scan inside ``shard_map``:

* per-client stages — local SGD (vmap over this shard's clients),
  latency/scheduler state, channel draw, eq.-25 factors, power cap (7) —
  are embarrassingly parallel: zero collectives;
* the AirComp superposition is ONE psum over the client axis per round
  (``repro.kernels.aircomp_sum``: the raveled form psums the flat
  accumulator, the pytree form concatenates per-leaf partials and psums
  once — never per leaf), plus the water-filling P2 grid reductions and
  the round metrics (a handful of scalar psums).

Params modes (``params_mode``): ``"raveled"`` federates the flat (K, d)
stack exactly as before; ``"pytree"`` carries the params pytree natively,
each client-stacked leaf placed by ``repro.sharding.rules
.stack_client_specs`` under the mesh client axes — so a transformer-config
client federation (e.g. a minicpm-class reduced config) runs full sharded
PAOTA rounds with its params in their natural structure.

Intra-client TP (``tp_axes``, pytree mode): on a ("pod", "data", "tp")
mesh (``make_pod_mesh(..., tp=N)``) each stacked payload leaf additionally
TP-shards one trailing dim over the TP axis — per-device model-plane
bytes drop ~1/TP, the wall that caps how large a single client can be.
Storage-parallel, compute-replicated: globals and local training stay
replicated over TP (full leaves everywhere), the stats sweep closes with
one small psum over the TP axes, the AirComp superposition stays ONE
model-sized psum (now spanning clients x TP — superpose and TP-gather in
the same collective), the AWGN is drawn at FULL shapes from the
replicated key (identical realization for every TP layout), and the
carry writes slice trained rows to the TP-local block
(``repro.sharding.tp``). TP extent 1 passes ``tp=None`` into the round —
op-for-op, bit-identical to the flat program. Any OTHER non-client mesh
axis with extent > 1 still refuses in pytree mode (name it in
``tp_axes`` — or ``client_axes`` — to use it).

Phantom-client padding: a client-axis extent that does not divide K no
longer refuses — the federation pads to the next multiple with masked
phantom clients whose ready bits are pinned False forever (busy_lat =
+inf, zero data rows, zero power). Phantoms never upload, never
broadcast, and carry b_k = 0 through every psum and metric, so the padded
trajectory equals the unpadded single-device one draw for draw
(tests/test_pytree_round.py).

Grouped aggregation (``group_period`` N >= 1, Air-FedGA style): the
client axes split into POD axes and INTRA-pod axes (``pod_axes``;
default: the first client axis indexes the pods). Every period each pod
superposes its own clients with an intra-pod psum and accumulates the
staleness-weighted partial into the carry's ``held`` slot; the cross-pod
psum — the only model-sized collective that leaves a pod — fires once
every N periods, at the window sync (``repro.fl.runtime.scan_windows``
unrolls the window inside the scan step so the compiled scan body holds
exactly ONE such all-reduce; benchmarks/grouped_round_bench.py counts
them in the HLO). ``group_period=1`` makes every period a sync with a
zero ``held``, which is op-for-op the flat program — grouped N=1 equals
flat bit-for-bit (tests/test_grouped_round.py).

Active-cohort mode (``cohort_size=m``): the slots split shard-LOCAL —
``m`` must tile the client shards, each shard runs the cohort round over
its ``m / n_shards`` slots and refills them from its OWN idle clients by
the shared counter-RNG priority draw (phantom rows are pinned to -inf and
can never win a slot). Slot refill order is therefore per-shard rather
than the fused driver's global priority order — a documented scheduling
POLICY difference (same distributions; at m = K both pin every client to
a permanent slot and the paths coincide). Round-0 cohort init also runs
inside ``shard_map``: its payload gathers use shard-local slot ids, which
plain GSPMD jit would misread as global rows. Grouped aggregation does
not compose with cohort mode yet. Compressed payloads (``compress=``)
shard the (m, s) slot planes and (K, s) parked EF residuals over the
same client axis; the randmask support is re-derived replicated on every
shard from the counter stream (no collective), the int8 dither key folds
in the shard offset, and the compressed superposition is still ONE psum
(``gather_superpose_psum`` concatenates the accumulator with the
varsigma partial).

Equivalence contract: every shard consumes its rows of the SAME global
counter-RNG draws the single-device scan makes — latency and channel
vectors are drawn full-K from the replicated round key, padded with
phantom fill, and sliced by shard offset; minibatch plans fold in GLOBAL
client ids (``counter_batch_plan(client_ids=...)``); the AWGN realization
is drawn once from the replicated noise key. The sharded trajectory is
therefore allclose to ``FusedPAOTA`` round for round (float reduction
order across shards is the only difference; zero-uploader periods hold
w_g bit-identically on every shard) — tests/test_sharded_round.py.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

try:                                    # jax >= 0.6 exports it at top level
    from jax import shard_map
except ImportError:                     # 0.4.x: experimental namespace
    from jax.experimental.shard_map import shard_map

import numpy as np

from repro.core.aircomp import ChannelConfig, sample_channel_gains
from repro.core.compress import randmask_indices
from repro.core.scheduler import (TAG_CHANNEL, TAG_COMPRESS, TAG_NOISE,
                                  TAG_QUANT, TAG_SCHED, SchedulerConfig,
                                  blackout_active, counter_latencies,
                                  fault_channel_mask, fault_payload_masks,
                                  inject_payload_faults, round_tag_key,
                                  scenario_latencies, scenario_masks)
from repro.fl.fused import FusedPAOTA
from repro.fl.runtime import (GroupTopology, RoundCarry, RoundStreams,
                              init_cohort_carry, scan_rounds, scan_windows)
from repro.fl.server import PAOTAConfig
from repro.launch.mesh import data_axes
from repro.sharding.rules import batch_specs, stack_client_specs

OUT_KEYS = ("n_participants", "time", "mean_staleness", "beta_mean",
            "varsigma", "p2_objective", "n_screened", "rolled_back")


class ShardedPAOTA(FusedPAOTA):
    """Drop-in ``FusedPAOTA`` whose scan runs sharded over the mesh client
    axis.

    ``mesh`` defaults to all local devices as one client axis
    (``repro.launch.mesh.make_client_mesh``); ``client_axes`` defaults to
    the mesh's ("pod",)/"data" axes (``data_axes``) — pass
    ``client_axes_for(model_cfg, mesh)`` to follow an architecture's
    placement policy. A client-axis extent that does not divide K pads
    the federation with masked phantom clients (never ready, zero power)
    rather than refusing.

    ``params_mode="pytree"`` + ``model_cfg``: carry the params pytree
    natively with each stacked leaf placed by ``stack_client_specs(...,
    model_cfg, mesh, client_axes)`` (``model_cfg=None`` places leading
    client axes only — the right policy for structureless pytrees like
    the MLP).

    ``group_period=N`` (N >= 1) enables grouped aggregation: the client
    axes in ``pod_axes`` (default: the first client axis) index the pods;
    non-sync periods psum intra-pod only and the cross-pod model-sized
    psum fires once per N-period window. ``advance`` then moves in whole
    windows (``n_rounds`` must be a multiple of N). N=1 is the flat
    program bit-for-bit.

    ``tp_axes`` (pytree mode): mesh axes the model storage TP-shards over
    inside each client shard (default: the mesh's "tp" axis when present).
    Extent 1 is the flat program bit-for-bit; extent > 1 slices one
    trailing dim of each stacked payload leaf (placement from
    ``stack_client_specs``; leaves with no dividing dim stay
    TP-replicated) — see the module docstring.
    """

    def __init__(self, init_params, clients, chan: ChannelConfig,
                 sched_cfg: SchedulerConfig, cfg: PAOTAConfig, *,
                 mesh=None, client_axes=None, params_mode: str = "raveled",
                 model_cfg=None, pending_dtype: str = "float32",
                 donate: bool = True, group_period: int = 0, pod_axes=None,
                 cohort_size: int | None = None, scenario=None,
                 compress: str | None = None, compress_ratio: float = 1.0,
                 slot_dtype: str | None = None,
                 error_feedback: bool = True, tp_axes=None, faults=None,
                 screen: bool = False, screen_max_norm: float = 0.0,
                 divergence_factor: float = 0.0, checkpoint_every: int = 0,
                 checkpoint_dir: str | None = None):
        if mesh is None:
            from repro.launch.mesh import make_client_mesh
            mesh = make_client_mesh()
        self.mesh = mesh
        axes = tuple(client_axes) if client_axes else data_axes(mesh)
        if not axes:
            raise ValueError(f"mesh {mesh.axis_names} has no client axis")
        self.client_axes = axes
        self.n_shards = int(math.prod(mesh.shape[a] for a in axes))
        # intra-client TP: default to the mesh's dedicated "tp" axis;
        # extent 1 (or no such axis) keeps the historical flat program
        if tp_axes is None:
            tp_ax = tuple(a for a in mesh.axis_names
                          if a == "tp" and a not in axes)
        else:
            tp_ax = tuple(tp_axes)
            bad = [a for a in tp_ax
                   if a not in mesh.axis_names or a in axes]
            if bad:
                raise ValueError(
                    f"tp_axes={tp_ax}: {bad} must be non-client mesh axes "
                    f"(mesh axes {mesh.axis_names}, client_axes={axes})")
        self.tp_axes = tp_ax
        self.tp_shards = int(math.prod(mesh.shape[a] for a in tp_ax)) \
            if tp_ax else 1
        self._tp = None
        if self.tp_shards > 1:
            if len(self.tp_axes) > 1:
                raise NotImplementedError(
                    f"tp_axes={self.tp_axes}: intra-client TP supports a "
                    f"single mesh axis (leaf dims shard over one axis); "
                    f"the nearest supported configuration merges them into "
                    f"one 'tp' axis of extent {self.tp_shards}")
            if compress:
                raise NotImplementedError(
                    f"compress='{compress}' does not compose with "
                    f"intra-client TP (tp axes {self.tp_axes}, extent "
                    f"{self.tp_shards}) yet — the (m, s) compressed slot "
                    f"planes are raveled coordinate sets with no per-leaf "
                    f"TP split; the nearest supported configurations are "
                    f"compress='{compress}' on a client-axes-only mesh, or "
                    f"TP with compress=None")
            if cohort_size:
                raise NotImplementedError(
                    f"cohort_size={cohort_size} does not compose with "
                    f"intra-client TP (tp axes {self.tp_axes}, extent "
                    f"{self.tp_shards}) yet — the cohort payload plane is "
                    f"raveled (m, d) slots; the nearest supported "
                    f"configurations are cohort_size={cohort_size} on a "
                    f"client-axes-only mesh, or TP with cohort_size=None "
                    f"(dense payload planes)")
            if group_period:
                raise NotImplementedError(
                    f"group_period={group_period} does not compose with "
                    f"intra-client TP (tp axes {self.tp_axes}, extent "
                    f"{self.tp_shards}) yet — the held intra-pod partial "
                    f"is a flat model-sized accumulator with no TP split; "
                    f"the nearest supported configurations are "
                    f"group_period={group_period} with TP extent 1, or TP "
                    f"with group_period=0 (flat sync every period)")
            if params_mode != "pytree":
                raise NotImplementedError(
                    f"params_mode='raveled' does not compose with "
                    f"intra-client TP (tp axes {self.tp_axes}, extent "
                    f"{self.tp_shards}) — the flat (K, d) stack has no "
                    f"leaf dims to TP-shard; the nearest supported "
                    f"configurations are params_mode='pytree' (per-leaf TP "
                    f"placement), or raveled on a client-axes-only mesh")
        if params_mode == "pytree":
            other = {a: mesh.shape[a] for a in mesh.axis_names
                     if a not in axes and a not in self.tp_axes
                     and mesh.shape[a] > 1}
            if other:
                named = ", ".join(f"'{a}' (extent {mesh.shape[a]})"
                                  for a in sorted(other))
                raise NotImplementedError(
                    f"params_mode='pytree' shards the client axes and the "
                    f"tp_axes only, but non-client mesh axis {named} has "
                    f"extent > 1: it would split the stacked leaves' model "
                    f"dims outside the round's TP-aware reductions. Either "
                    f"name it in tp_axes (intra-client TP — the model "
                    f"storage shards over it), use params_mode='raveled' "
                    f"(the flat (K, d) federation over the client axes), "
                    f"rebuild the mesh with extent 1 on {sorted(other)}, "
                    f"or include the axis in client_axes.")
        # grouped-aggregation topology: pod axes index the groups, the
        # remaining client axes are intra-pod
        if group_period < 0:
            raise ValueError(f"group_period={group_period} (expected >= 0)")
        if pod_axes is not None and not group_period:
            raise ValueError("pod_axes without group_period: pass "
                             "group_period=N >= 1 to enable grouped "
                             "aggregation")
        self._grouping = None
        self.n_pod_groups = 1
        if group_period:
            pods = tuple(pod_axes) if pod_axes else (axes[0],)
            bad = [a for a in pods if a not in axes]
            if bad or len(set(pods)) != len(pods):
                raise ValueError(f"pod_axes={pods} must be distinct client "
                                 f"axes (client_axes={axes})")
            intra = tuple(a for a in axes if a not in pods)
            self._grouping = GroupTopology(
                pod_axes=pods, intra_axes=intra,
                intra_shards=int(math.prod(mesh.shape[a] for a in intra)))
            self.n_pod_groups = int(math.prod(mesh.shape[a] for a in pods))
        if cohort_size and group_period:
            raise NotImplementedError(
                "active-cohort mode does not compose with grouped "
                "aggregation yet: the held-window partials are dense-plane "
                "accumulators (pass cohort_size=None or group_period=0)")
        if faults is not None and getattr(faults, "has_blackout", False):
            pods = (tuple(pod_axes) if pod_axes else (axes[0],)) \
                if group_period else ()
            if pods and pods != axes[:len(pods)]:
                raise NotImplementedError(
                    f"pod_blackout with pod_axes={pods}: the blackout's "
                    f"pod -> client-row map assumes the pod axes LEAD the "
                    f"client axes {axes} (pods own contiguous row blocks); "
                    f"the nearest supported configuration reorders "
                    f"client_axes to put {pods} first")
        # super() builds the engine, RoundCfg, keys, and jits _run_scan —
        # which the overrides below turn into the shard_map program
        super().__init__(init_params, clients, chan, sched_cfg, cfg,
                         params_mode=params_mode, pending_dtype=pending_dtype,
                         donate=donate, cohort_size=cohort_size,
                         scenario=scenario, compress=compress,
                         compress_ratio=compress_ratio,
                         slot_dtype=slot_dtype,
                         error_feedback=error_feedback, faults=faults,
                         screen=screen, screen_max_norm=screen_max_norm,
                         divergence_factor=divergence_factor,
                         checkpoint_every=checkpoint_every,
                         checkpoint_dir=checkpoint_dir)
        if group_period:
            self._rcfg = self._rcfg._replace(group_period=group_period)
            if self.checkpoint_every % group_period:
                raise ValueError(
                    f"checkpoint_every={self.checkpoint_every} must be a "
                    f"multiple of group_period={group_period}: the grouped "
                    f"scan advances whole windows, so snapshots land on "
                    f"window boundaries only")
        # phantom-client padding: pad K to the next multiple of the
        # client-axis extent with masked never-ready clients
        self.k_pad = -(-self.k // self.n_shards) * self.n_shards
        self.n_phantom = self.k_pad - self.k
        self.k_local = self.k_pad // self.n_shards
        if self.n_phantom:
            ph = self.n_phantom
            eng = self.engine
            pad0 = lambda a: jnp.concatenate(
                [jnp.asarray(a),
                 jnp.zeros((ph,) + a.shape[1:], a.dtype)])
            eng._x, eng._y = pad0(eng._x), pad0(eng._y)
            # phantom "datasets" are one zero row: minibatch plans draw
            # index 0 only, the trained output rows are never consumed
            # (ready stays False so pending never takes them)
            eng._n_dev = jnp.concatenate(
                [eng._n_dev, jnp.ones((ph,), eng._n_dev.dtype)])
            # heterogeneity traits pad with the identity hyperparameters
            # (phantom rows are never consumed, but the gathers by global
            # id must stay in bounds)
            pad1 = lambda a: jnp.concatenate(
                [a, jnp.ones((ph,), a.dtype)])
            if eng._steps_k is not None:
                eng._steps_k = pad1(eng._steps_k)
            if eng._batch_k is not None:
                eng._batch_k = pad1(eng._batch_k)
        # the cohort splits into shard-LOCAL slot sets (slot gathers and
        # the refill top_k never cross shards): m must tile the shards, and
        # each shard's slots cannot exceed its client rows. Slot refill is
        # per shard — a policy difference vs the fused driver's global
        # priority order (documented; at m = K both pin every client to a
        # permanent slot and match the dense path).
        self.m_local = 0
        if self.cohort_size:
            if self.cohort_size % self.n_shards:
                lo = (self.cohort_size // self.n_shards) * self.n_shards
                hi = lo + self.n_shards
                near = (f"{hi}" if lo == 0
                        else f"{lo} and {hi}")
                raise ValueError(
                    f"cohort_size={self.cohort_size} must be divisible by "
                    f"the {self.n_shards} client shards (slots are "
                    f"shard-local); the nearest valid cohort sizes are "
                    f"{near}")
            self.m_local = self.cohort_size // self.n_shards
            if self.m_local > self.k_local:
                raise ValueError(
                    f"cohort_size={self.cohort_size} gives {self.m_local} "
                    f"slots per shard but each shard holds only "
                    f"{self.k_local} client rows")
        ax = axes if len(axes) != 1 else axes[0]
        self._ax = ax
        if params_mode == "pytree":
            tp_on = self.tp_shards > 1
            stacked_struct = jax.tree_util.tree_map(
                lambda g: jax.ShapeDtypeStruct((self.k_pad,) + g.shape,
                                               g.dtype), self._init_global)
            pend_spec = stack_client_specs(
                stacked_struct, model_cfg, mesh, axes,
                tp_axis=(self.tp_axes[0] if tp_on else None))
            # every kept-out axis is extent 1 (guard above), so dropping
            # its trailing assignments changes nothing physically — but it
            # lets shard_map's replication checker see that the psum over
            # the client (x TP) axes fully replicates the globals. With TP
            # active the TP assignments are KEPT: they are the payload
            # placement.
            keep = axes + (self.tp_axes if tp_on else ())
            pend_spec = jax.tree_util.tree_map(
                lambda s: self._client_axes_only(s, keep), pend_spec)
            if tp_on:
                # leaf_dims come FROM the computed pend_spec, so GSPMD
                # placement and the runtime's slicing can never disagree
                self._tp = self._derive_tp(pend_spec)
            glob_spec = jax.tree_util.tree_map(lambda _: P(),
                                               self._init_global)
        else:
            pend_spec, glob_spec = P(ax, None), P()
        if self._grouping is not None:
            pods = self._grouping.pod_axes
            # held rows shard over the pod axes and replicate intra-pod
            # (the intra-pod psum that builds them replicates them there)
            held_spec = P(pods[0] if len(pods) == 1 else pods, None)
        else:
            held_spec = None
        slot_spec = P(ax) if self.cohort_size else None
        # compressed cohort planes: the (m, s) slot planes and the (K, s)
        # parked-residual planes all shard their leading (client) axis,
        # like the payload plane they replace
        comp_spec = P(ax, None) if self._rcfg.compress else None
        ef_spec = comp_spec if self._rcfg.error_feedback else None
        # the divergence detector's last-good slot replicates like the
        # globals it snapshots (None subtree when the detector is off)
        diverg = self._rcfg.divergence_factor > 0.0
        self._carry_specs = RoundCarry(
            t=P(), time=P(), ready=P(ax), busy_lat=P(ax),
            model_round=P(ax), global_vec=glob_spec, prev_global=glob_spec,
            # transmit='delta' carries no pending plane (None subtree)
            pending=None if self._rcfg.transmit_delta else pend_spec,
            # cohort mode: the payload planes' leading axis is the m slots
            # (m_local per shard) — same specs, smaller extent
            deltas=pend_spec, held=held_spec,
            slot_client=slot_spec, slot_live=slot_spec,
            slot_idx=comp_spec,
            slot_scale=(P(ax) if self._rcfg.slot_dtype == "int8" else None),
            slot_resid=ef_spec, slot_resid_idx=ef_spec,
            resid_val=ef_spec, resid_idx=ef_spec,
            good_global=glob_spec if diverg else None,
            good_norm2=P() if diverg else None)
        data_sp = batch_specs({"x": self.engine._x, "y": self.engine._y},
                              (), (axes,))
        self._x_spec, self._y_spec = data_sp["x"], data_sp["y"]
        self._out_specs = {k: P() for k in OUT_KEYS}
        # place the padded federation over the client axis ONCE — advance()
        # then never pays a reshard (the scan's in_specs match)
        self.engine._x = jax.device_put(
            self.engine._x, NamedSharding(mesh, self._x_spec))
        self.engine._y = jax.device_put(
            self.engine._y, NamedSharding(mesh, self._y_spec))

    @staticmethod
    def _client_axes_only(spec, axes):
        """Strip mesh axes outside ``axes`` from a PartitionSpec (all such
        axes are extent 1 in pytree mode — see the constructor guard;
        with TP active the TP axes are part of ``axes`` and survive)."""
        def keep(entry):
            if entry is None:
                return None
            if isinstance(entry, tuple):
                kept = tuple(a for a in entry if a in axes)
                return kept if kept else None
            return entry if entry in axes else None
        return P(*(keep(e) for e in spec))

    def _derive_tp(self, pend_spec):
        """Static ``TPTopology`` read off the computed pend_spec tree: for
        each stacked leaf, the (unstacked) trailing-dim index its spec
        assigns to the TP axes, -1 when none (TP-replicated leaf)."""
        from repro.sharding.tp import TPTopology
        tp_set = set(self.tp_axes)
        dims = []
        for sp in jax.tree_util.tree_leaves(
                pend_spec, is_leaf=lambda s: isinstance(s, P)):
            dim = -1
            for i, entry in enumerate(sp):
                names = (entry if isinstance(entry, tuple)
                         else (entry,) if entry else ())
                if not any(a in tp_set for a in names):
                    continue
                if i == 0 or (set(names) - tp_set) or dim >= 0:
                    raise NotImplementedError(
                        f"unsupported TP placement {sp}: the TP axes "
                        f"{self.tp_axes} must occupy exactly one trailing "
                        f"leaf dim, alone")
                dim = i - 1
            dims.append(dim)
        return TPTopology(
            axes=self.tp_axes,
            extents=tuple(self.mesh.shape[a] for a in self.tp_axes),
            shards=self.tp_shards, leaf_dims=tuple(dims))

    # ------------------------------------------------------------------
    # phantom-aware full-federation streams (round-0 init runs these on
    # the placed data before the scan takes over): real clients see the
    # exact unpadded draws, phantoms get busy_lat = +inf so sched_advance
    # can never flip their ready bit
    # ------------------------------------------------------------------
    def _streams(self) -> RoundStreams:
        base = super()._streams()
        if not self.n_phantom:
            return base

        def pad_fill(v, fill):
            return jnp.concatenate(
                [v, jnp.full((self.n_phantom,), fill, v.dtype)])

        scen = None
        if base.scenario is not None:
            def scen(t):
                avail, drop = base.scenario(t)
                return pad_fill(avail, False), pad_fill(drop, False)
        prio = None
        if base.sched_priority is not None:
            # -inf score = never schedulable: phantoms can win a slot in no
            # round (the refill gate is score > -inf)
            prio = lambda r: pad_fill(base.sched_priority(r), -jnp.inf)
        return RoundStreams(
            local_train=base.local_train,   # engine arrays already padded
            latencies=lambda r: pad_fill(base.latencies(r), jnp.inf),
            channel=lambda t: pad_fill(base.channel(t), 0.0),
            noise_key=base.noise_key,
            scenario=scen,
            cohort_train=base.cohort_train,  # gathers by id: already padded
            sched_priority=prio,
            compress_mask=base.compress_mask,   # slot planes are never
            quant_key=base.quant_key,           # client-indexed: no padding
        )

    # ------------------------------------------------------------------
    # shard-local streams: identical global draws, this shard's rows
    # ------------------------------------------------------------------
    def _shard_offset(self):
        """First global client id on this shard (traced, inside shard_map):
        row-major flattening of the client-axis coordinates."""
        idx = jnp.int32(0)
        for a in self.client_axes:
            idx = idx * self.mesh.shape[a] + jax.lax.axis_index(a)
        return idx * self.k_local

    def _shard_streams(self, offset) -> RoundStreams:
        k, k_loc, ph = self.k, self.k_local, self.n_phantom
        sc, chan = self.sched_cfg, self.chan
        n_dev = self.engine._n_dev          # (K_pad,) consts: replicated

        def slice_k(full):
            return jax.lax.dynamic_slice(full, (offset,), (k_loc,))

        def pad_slice(full, fill):
            """Slice this shard's rows out of a full-K draw vector, padded
            to K_pad with the phantom fill first — a shard that straddles
            the real/phantom boundary must not clamp into real rows."""
            if ph:
                full = jnp.concatenate(
                    [full, jnp.full((ph,), fill, full.dtype)])
            return slice_k(full)

        def local_train(global_state, x, y, r):
            cids = (offset.astype(jnp.uint32)
                    + jnp.arange(k_loc, dtype=jnp.uint32))
            idx = self.engine.round_plan(r, client_ids=cids,
                                         n_samples=slice_k(n_dev))
            steps = self.engine.steps_for(cids)
            if self.params_mode == "pytree":
                return self.engine._train_all_tree(global_state, x, y, idx,
                                                   steps)
            return self.engine._train_all(self.unravel(global_state), x, y,
                                          idx, steps)

        def cohort_train(global_state, x, y, r, ids):
            # slot ids are shard-LOCAL rows of (x, y); every draw keys on
            # the GLOBAL client id, so a client's trained row is identical
            # whichever shard/slot computes it
            gids = (offset.astype(jnp.uint32) + ids.astype(jnp.uint32))
            idx = self.engine.round_plan(r, client_ids=gids,
                                         n_samples=n_dev[gids])
            steps = self.engine.steps_for(gids)
            xs, ys = x[ids], y[ids]
            if self.params_mode == "pytree":
                return self.engine._train_all_tree(global_state, xs, ys, idx,
                                                   steps)
            return self.engine._train_all(self.unravel(global_state), xs, ys,
                                          idx, steps)

        scn = self.scenario
        if scn is None:
            lat = lambda r: pad_slice(counter_latencies(
                self._lat_key, r, k, sc.lat_lo, sc.lat_hi), jnp.inf)
        else:
            lat = lambda r: pad_slice(scenario_latencies(
                self._lat_key, r, k, sc.lat_lo, sc.lat_hi, scn), jnp.inf)
        scen_cb = None
        if scn is not None and scn.has_masks:
            def scen_cb(t):
                avail, drop = scenario_masks(self._lat_key, t, k, scn)
                return pad_slice(avail, False), pad_slice(drop, False)
        prio = None
        if self.cohort_size:
            # the SAME full-K priority draw the fused driver makes, this
            # shard's rows, phantoms pinned -inf (never schedulable); the
            # refill top_k itself is shard-local — a documented policy
            # difference vs the fused driver's global priority order
            prio = lambda r: pad_slice(jax.random.uniform(
                round_tag_key(self._lat_key, r, TAG_SCHED), (k,)), -jnp.inf)
        compress_mask = quant_key = None
        if self.compress == "randmask" and self.compress_s < self.d:
            # the SAME replicated mask the fused driver draws: every shard
            # re-derives it from the counter stream, no collective needed
            compress_mask = lambda r: randmask_indices(
                round_tag_key(self._srv_key, r, TAG_COMPRESS), self.d,
                self.compress_s)
        if self._rcfg.slot_dtype == "int8":
            # fold the shard offset into the dither key so shard-local
            # draws are independent across shards (same shape, own stream)
            quant_key = lambda r: jax.random.fold_in(
                round_tag_key(self._srv_key, r, TAG_QUANT), offset)
        channel = lambda t: pad_slice(sample_channel_gains(
            round_tag_key(self._srv_key, t, TAG_CHANNEL), k, chan), 0.0)

        # fault streams: the SAME full-K draws the fused driver makes,
        # sliced to this shard's rows (phantoms never fault)
        fc = self.faults
        if fc is not None and fc.has_payload_faults:
            base_local, base_cohort = local_train, cohort_train

            def local_train(global_state, x, y, r):          # noqa: F811
                trained = base_local(global_state, x, y, r)
                nm, bm = fault_payload_masks(self._lat_key, r, k, fc)
                return inject_payload_faults(
                    trained, global_state, pad_slice(nm, False),
                    pad_slice(bm, False), fc)

            def cohort_train(global_state, x, y, r, ids):    # noqa: F811
                trained = base_cohort(global_state, x, y, r, ids)
                nm, bm = fault_payload_masks(self._lat_key, r, k, fc)
                if ph:
                    # slot gids reach into the phantom pad: extend the
                    # masks with never-faulting rows before the gather
                    pad = jnp.zeros((ph,), bool)
                    nm = jnp.concatenate([nm, pad])
                    bm = jnp.concatenate([bm, pad])
                gids = offset.astype(jnp.uint32) + ids.astype(jnp.uint32)
                return inject_payload_faults(trained, global_state,
                                             nm[gids], bm[gids], fc)
        if fc is not None and fc.has_channel_faults:
            base_chan = channel

            def channel(t):                                  # noqa: F811
                h = base_chan(t)
                fade = pad_slice(fault_channel_mask(self._lat_key, t, k, fc),
                                 False)
                return jnp.where(fade, h * jnp.float32(fc.deep_fade_gain), h)
        if fc is not None and fc.has_blackout:
            # pod blackout composes into the scenario availability mask:
            # the pod axes lead the client axes (constructor guard), so
            # pod p owns the contiguous rows [p, p+1) * k_pad / n_pods
            rows_per_pod = self.k_pad // self.n_pod_groups
            blk_full = jnp.asarray(np.isin(
                np.arange(self.k_pad) // rows_per_pod,
                [int(p) for p in fc.pod_blackout]))
            base_scen = scen_cb

            def scen_cb(t):                                  # noqa: F811
                blk = blackout_active(fc, t) & jax.lax.dynamic_slice(
                    blk_full, (offset,), (k_loc,))
                if base_scen is None:
                    return ~blk, jnp.zeros_like(blk)
                avail, drop = base_scen(t)
                return avail & ~blk, drop

        return RoundStreams(
            local_train=local_train,
            latencies=lat,
            channel=channel,
            noise_key=lambda t: round_tag_key(self._srv_key, t, TAG_NOISE),
            scenario=scen_cb,
            cohort_train=cohort_train if self.cohort_size else None,
            sched_priority=prio,
            compress_mask=compress_mask,
            quant_key=quant_key,
        )

    # ------------------------------------------------------------------
    # the sharded scan (replaces FusedPAOTA's single-device _run_scan;
    # per-client init math has no cross-client reduction, so GSPMD runs
    # _init_carry row-parallel over the same placed data — the grouped
    # override below only adds the zeroed held slot)
    # ------------------------------------------------------------------
    def _init_carry(self, vec, x, y) -> RoundCarry:
        if self.cohort_size:
            # cohort init gathers data/payload rows by shard-LOCAL slot ids,
            # so it must run INSIDE shard_map (under plain GSPMD jit those
            # gathers would read global rows). Each shard seeds its first
            # m_local slots from its own real clients; a shard whose rows
            # are all phantom padding starts with every slot dead.
            glob_spec = self._carry_specs.global_vec

            def body(v, xs, ys):
                offset = self._shard_offset()
                n_real = jnp.clip(jnp.int32(self.k) - offset, 0,
                                  self.k_local)
                return init_cohort_carry(
                    v, xs, ys, streams=self._shard_streams(offset),
                    k=self.k_local, m=self.m_local, n_real=n_real,
                    pending_dtype=self._rcfg.pending_dtype,
                    keep_pending=not self._rcfg.transmit_delta,
                    rcfg=self._rcfg)

            smap = shard_map(body, self.mesh,
                             in_specs=(glob_spec, self._x_spec,
                                       self._y_spec),
                             out_specs=self._carry_specs,
                             check_rep=True)
            return smap(vec, x, y)
        carry = super()._init_carry(vec, x, y)
        if self._grouping is not None:
            carry = carry._replace(held=jnp.zeros(
                (self.n_pod_groups, self.d + 1), jnp.float32))
        return carry

    def _run_scan(self, carry: RoundCarry, x, y, n_rounds: int):
        axes = self.client_axes
        grouping, n = self._grouping, self._rcfg.group_period

        def body(c, xs, ys):
            streams = self._shard_streams(self._shard_offset())
            if grouping is None:
                return scan_rounds(c, xs, ys, n_rounds, rcfg=self._rcfg,
                                   streams=streams, axis_name=axes,
                                   tp=self._tp)
            return scan_windows(c, xs, ys, n_rounds // n, rcfg=self._rcfg,
                                streams=streams, axis_name=axes,
                                grouping=grouping)

        if grouping is not None and n_rounds % n:
            raise ValueError(
                f"grouped aggregation advances whole windows: n_rounds="
                f"{n_rounds} is not a multiple of group_period={n}")
        smap = shard_map(body, self.mesh,
                         in_specs=(self._carry_specs, self._x_spec,
                                   self._y_spec),
                         out_specs=(self._carry_specs, self._out_specs),
                         check_rep=True)
        carry, outs = smap(carry, x, y)
        if grouping is not None:
            # window-stacked (n_windows, N) metrics back to the flat
            # (n_rounds,) timeline the driver's history expects
            outs = {k: v.reshape((n_rounds,)) for k, v in outs.items()}
        return carry, outs

    def compiled_scan_hlo(self, n_rounds: int) -> str:
        """Compiled HLO of the n-round advance (builds the round-0 carry
        if needed, does NOT run the scan) — what the grouped benchmark's
        cross-pod collective count inspects."""
        if self._carry is None:
            self._carry = self._jit_init(self._init_global, self.engine._x,
                                         self.engine._y)
        return self._jit_scan.lower(self._carry, self.engine._x,
                                    self.engine._y,
                                    n_rounds=n_rounds).compile().as_text()
