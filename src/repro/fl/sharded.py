"""Mesh-sharded PAOTA: the fused round scanned under ``jax.shard_map``
over the mesh client axis.

``FusedPAOTA`` runs the whole aggregation period as one device call — but
on ONE device: a K = 10^4..10^5 federation serializes through a single
chip while the rest of the mesh idles. ``ShardedPAOTA`` lays the round
core's (K,) / (K, d) carry rows and the engine's padded (K, n_max, ...)
federation over the mesh client axis (``repro.launch.mesh.data_axes`` /
``client_axes_for``; specs from ``repro.sharding.rules.batch_specs``) and
runs the SAME ``repro.fl.runtime`` scan inside ``shard_map``:

* per-client stages — local SGD (vmap over this shard's clients),
  latency/scheduler state, channel draw, eq.-25 factors, power cap (7) —
  are embarrassingly parallel: zero collectives;
* the AirComp superposition is ONE psum over the client axis per round
  (``repro.kernels.aircomp_sum.aircomp_sum_psum`` — the TPU-native
  realization of the wireless MAC), plus the water-filling P2 grid
  reductions and the round metrics (a handful of scalar psums).

Equivalence contract: every shard consumes its rows of the SAME global
counter-RNG draws the single-device scan makes — latency and channel
vectors are drawn full-K from the replicated round key and sliced by
shard offset; minibatch plans fold in GLOBAL client ids
(``counter_batch_plan(client_ids=...)``); the AWGN realization is drawn
once from the replicated noise key. The sharded trajectory is therefore
allclose to ``FusedPAOTA`` round for round (float reduction order across
shards is the only difference; zero-uploader periods hold w_g
bit-identically on every shard) — tests/test_sharded_round.py.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

try:                                    # jax >= 0.6 exports it at top level
    from jax import shard_map
except ImportError:                     # 0.4.x: experimental namespace
    from jax.experimental.shard_map import shard_map

from repro.core.aircomp import ChannelConfig, sample_channel_gains
from repro.core.scheduler import (TAG_CHANNEL, TAG_NOISE, SchedulerConfig,
                                  counter_latencies, round_tag_key)
from repro.fl.fused import FusedPAOTA
from repro.fl.runtime import RoundCarry, RoundStreams, scan_rounds
from repro.fl.server import PAOTAConfig
from repro.launch.mesh import data_axes
from repro.sharding.rules import batch_specs

OUT_KEYS = ("n_participants", "time", "mean_staleness", "beta_mean",
            "varsigma", "p2_objective")


class ShardedPAOTA(FusedPAOTA):
    """Drop-in ``FusedPAOTA`` whose scan runs sharded over the mesh client
    axis.

    ``mesh`` defaults to all local devices as one client axis
    (``repro.launch.mesh.make_client_mesh``); ``client_axes`` defaults to
    the mesh's ("pod",)/"data" axes (``data_axes``) — pass
    ``client_axes_for(model_cfg, mesh)`` to follow an architecture's
    placement policy. The client-axis extent must divide K (no client
    padding: a fractional shard would silently skew the AirComp psum).
    """

    def __init__(self, init_params, clients, chan: ChannelConfig,
                 sched_cfg: SchedulerConfig, cfg: PAOTAConfig, *,
                 mesh=None, client_axes=None):
        if mesh is None:
            from repro.launch.mesh import make_client_mesh
            mesh = make_client_mesh()
        self.mesh = mesh
        axes = tuple(client_axes) if client_axes else data_axes(mesh)
        if not axes:
            raise ValueError(f"mesh {mesh.axis_names} has no client axis")
        self.client_axes = axes
        self.n_shards = int(math.prod(mesh.shape[a] for a in axes))
        # super() builds the engine, RoundCfg, keys, and jits _run_scan —
        # which the overrides below turn into the shard_map program
        super().__init__(init_params, clients, chan, sched_cfg, cfg)
        if self.k % self.n_shards:
            raise ValueError(
                f"client-axis extent {self.n_shards} must divide K="
                f"{self.k} clients (mesh {dict(mesh.shape)}, client axes "
                f"{axes}); pad or regroup the federation")
        self.k_local = self.k // self.n_shards
        ax = axes if len(axes) != 1 else axes[0]
        self._ax = ax
        self._carry_specs = RoundCarry(
            t=P(), time=P(), ready=P(ax), busy_until=P(ax),
            model_round=P(ax), global_vec=P(), prev_global=P(),
            pending=P(ax, None), starts=P(ax, None))
        data_sp = batch_specs({"x": self.engine._x, "y": self.engine._y},
                              (), (axes,))
        self._x_spec, self._y_spec = data_sp["x"], data_sp["y"]
        self._out_specs = {k: P() for k in OUT_KEYS}
        # place the padded federation over the client axis ONCE — advance()
        # then never pays a reshard (the scan's in_specs match)
        self.engine._x = jax.device_put(
            self.engine._x, NamedSharding(mesh, self._x_spec))
        self.engine._y = jax.device_put(
            self.engine._y, NamedSharding(mesh, self._y_spec))

    # ------------------------------------------------------------------
    # shard-local streams: identical global draws, this shard's rows
    # ------------------------------------------------------------------
    def _shard_offset(self):
        """First global client id on this shard (traced, inside shard_map):
        row-major flattening of the client-axis coordinates."""
        idx = jnp.int32(0)
        for a in self.client_axes:
            idx = idx * self.mesh.shape[a] + jax.lax.axis_index(a)
        return idx * self.k_local

    def _shard_streams(self, offset) -> RoundStreams:
        k, k_loc = self.k, self.k_local
        sc, chan = self.sched_cfg, self.chan
        n_dev = self.engine._n_dev          # (K,) consts: replicated, tiny

        def slice_k(full):
            return jax.lax.dynamic_slice(full, (offset,), (k_loc,))

        def local_train(global_vec, x, y, r):
            cids = (offset.astype(jnp.uint32)
                    + jnp.arange(k_loc, dtype=jnp.uint32))
            idx = self.engine.round_plan(r, client_ids=cids,
                                         n_samples=slice_k(n_dev))
            return self.engine._train_all(self.unravel(global_vec), x, y, idx)

        return RoundStreams(
            local_train=local_train,
            latencies=lambda r: slice_k(counter_latencies(
                self._lat_key, r, k, sc.lat_lo, sc.lat_hi)),
            channel=lambda t: slice_k(sample_channel_gains(
                round_tag_key(self._srv_key, t, TAG_CHANNEL), k, chan)),
            noise_key=lambda t: round_tag_key(self._srv_key, t, TAG_NOISE),
        )

    # ------------------------------------------------------------------
    # the sharded scan (replaces FusedPAOTA's single-device _run_scan;
    # _init_carry is inherited — per-client init math has no cross-client
    # reduction, so GSPMD runs it row-parallel over the same placed data)
    # ------------------------------------------------------------------
    def _run_scan(self, carry: RoundCarry, x, y, n_rounds: int):
        axes = self.client_axes

        def body(c, xs, ys):
            streams = self._shard_streams(self._shard_offset())
            return scan_rounds(c, xs, ys, n_rounds, rcfg=self._rcfg,
                               streams=streams, axis_name=axes)

        smap = shard_map(body, self.mesh,
                         in_specs=(self._carry_specs, self._x_spec,
                                   self._y_spec),
                         out_specs=(self._carry_specs, self._out_specs),
                         check_rep=True)
        return smap(carry, x, y)
