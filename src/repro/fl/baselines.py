"""Baselines from Section IV-B:

(1) Local SGD [McMahan et al., AISTATS'17] — ideal synchronous FedAvg:
    lossless transmission, exact D_k/D-weighted average; round time is the
    MAX participant latency (bottleneck node — this is what PAOTA beats on
    wall-clock).

(2) COTAF [Sery & Cohen, TSP'20] — synchronous AirComp: clients transmit
    model UPDATES through the MAC with time-varying precoding
    alpha_t = P / max_k ||dw_k||^2 so the strongest update meets the power
    budget; the server receives the superposition plus AWGN scaled by
    1/(K sqrt(alpha_t)).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aircomp import ChannelConfig
from repro.core.aggregation import ravel
from repro.core.scheduler import SchedulerConfig, SemiAsyncScheduler
from repro.fl.engine import make_engine


@dataclass
class SyncConfig:
    n_select: int = 50           # participants per round (fairness:
                                 # matched to PAOTA's mean participation)
    engine: str = "batched"      # local-training engine: batched|legacy
    seed: int = 0


class _SyncServerBase:
    def __init__(self, init_params, clients: List, sched_cfg: SchedulerConfig,
                 cfg: SyncConfig):
        self.engine = make_engine(clients, cfg.engine)
        self.cfg = cfg
        self.scheduler = SemiAsyncScheduler(sched_cfg)
        vec, self.unravel = ravel(init_params)
        self.global_vec = np.asarray(vec)
        self.rng = np.random.default_rng(cfg.seed)
        self.time = 0.0
        self.round_idx = 0
        self.history: List[dict] = []

    def global_params(self):
        return self.unravel(jnp.asarray(self.global_vec))

    def _select(self):
        n = min(self.cfg.n_select, self.engine.n_clients)
        return self.rng.choice(self.engine.n_clients, size=n, replace=False)

    def _train_selected(self, sel):
        """One fused device call under the batched engine (K-client vmap)."""
        params = self.unravel(jnp.asarray(self.global_vec))
        outs = self.engine.local_train(params, sel)
        weights = self.engine.n_samples[np.asarray(sel, np.int64)]
        return outs, np.asarray(weights, float)

    def _advance_clock(self, n):
        # synchronous: wait for the slowest selected client (bottleneck)
        self.time += self.scheduler.sync_round_time(n)
        self.round_idx += 1


class LocalSGDServer(_SyncServerBase):
    """Ideal synchronous FedAvg (no transmission loss)."""

    def round(self) -> dict:
        sel = self._select()
        stacked, w = self._train_selected(sel)
        w = w / w.sum()
        self.global_vec = w @ stacked
        self._advance_clock(len(sel))
        info = {"round": self.round_idx, "time": self.time,
                "n_participants": len(sel)}
        self.history.append(info)
        return info


class COTAFServer(_SyncServerBase):
    """Synchronous AirComp with time-varying precoding [3]."""

    def __init__(self, init_params, clients, sched_cfg, cfg: SyncConfig,
                 chan: ChannelConfig):
        super().__init__(init_params, clients, sched_cfg, cfg)
        self.chan = chan
        self.key = jax.random.PRNGKey(cfg.seed + 77)

    def round(self) -> dict:
        sel = self._select()
        stacked, _ = self._train_selected(sel)
        deltas = stacked - self.global_vec[None, :]
        k = len(sel)
        # precoding: scale so max-energy update meets the power budget
        max_e = max(float(np.max(np.sum(deltas * deltas, axis=1))), 1e-12)
        alpha_t = self.chan.p_max_watts / max_e
        self.key, sub = jax.random.split(self.key)
        noise = (self.chan.sigma_n / (k * np.sqrt(alpha_t))
                 * np.asarray(jax.random.normal(sub, (deltas.shape[1],))))
        self.global_vec = self.global_vec + deltas.mean(axis=0) + noise
        self._advance_clock(k)
        info = {"round": self.round_idx, "time": self.time,
                "n_participants": k, "alpha_t": alpha_t}
        self.history.append(info)
        return info
