"""PAOTA server — Algorithm 1.

Per aggregation period (every delta_t seconds of simulated time):
  1. collect uploads from clients whose local training finished (b_k=1),
     with staleness s_k;
  2. compute staleness factors rho_k (eq. 25) and gradient-similarity
     factors theta_k = (cos(dw_k, w_g^t - w_g^{t-1}) + 1)/2;
  3. solve P2 for beta (Dinkelbach/MILP, PGD, or exact water-filling) and
     set transmit powers p_k = p_max(beta_k rho_k + (1-beta_k) theta_k),
     clipped by the instantaneous power constraint (7);
  4. AirComp-aggregate the stacked local models with AWGN (eqs. 6+8);
  5. broadcast w_g^{r+1} to the uploaders, who restart local training.

A period in which NO client finished (b_k = 0 for all k) is a no-op: the
global model and its previous-direction are held unchanged and the history
records varsigma = 0.0 — aggregating would divide pure channel noise by the
~0 normalizer (see repro.core.aggregation.guarded_global_update).

This class is the host reference: host-Python control flow per stage,
with the model-sized (K, d) state device-resident and the two stage
pipelines jitted once (the host<->device copies and per-round XLA
re-lowerings — not the math — were the reference's scale ceiling; see
EXPERIMENTS.md §Pytree round core). The fully fused,
single-device-call form of the same round lives in
``repro.fl.fused.FusedPAOTA``; with ``PAOTAConfig(rng="counter",
solver="waterfill_jnp")`` and ``SchedulerConfig(rng="counter")`` this
server consumes the exact RNG streams the fused scan does and serves as
its allclose reference (tests/test_fused_round.py).

Local training is delegated to a federation engine (repro.fl.engine):
the default ``BatchedEngine`` runs all broadcast clients in one jitted
vmap/scan call; ``engine="legacy"`` restores the seed's per-client loop
(same minibatch streams — the two are allclose-equivalent, see
tests/test_engine_equivalence.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aircomp import (VARSIGMA_MIN, ChannelConfig,
                                sample_channel_gains)
from repro.core.aggregation import (guarded_global_update,
                                    paota_aggregate_stacked, ravel)
from repro.core.dinkelbach import solve_p2
from repro.core.power_control import build_p2
from repro.core.scheduler import (TAG_CHANNEL, TAG_NOISE, SchedulerConfig,
                                  SemiAsyncScheduler, round_tag_key)
from repro.fl.engine import BatchedEngine, make_engine
from repro.fl.runtime import constraint7_powers, eq25_factors


@dataclass
class PAOTAConfig:
    omega: float = 3.0            # staleness constant Omega (Sec. IV-A)
    solver: str = "waterfill"     # p2 solver: waterfill|waterfill_jnp|pgd|
                                  # milp|exhaustive
    smooth_l: float = 10.0        # L (Sec. IV-A)
    eps_bound: float = 0.05       # epsilon (Assumption 3)
    use_kernel: bool = False      # route aggregation through Pallas kernel
    engine: str = "batched"       # local-training engine: batched|legacy
    transmit: str = "model"       # "model" (paper, eq. 6: clients transmit
                                  # w_k) | "delta" (beyond-paper: transmit
                                  # local updates; the power constraint (7)
                                  # then caps p by the much smaller ||dw||,
                                  # restoring SNR in harsh channels — see
                                  # EXPERIMENTS.md §Repro notes + ablation)
    rng: str = "host"             # "host": sequential key splits + stateful
                                  # minibatch cursors (seed behaviour);
                                  # "counter": per-round fold_in keys +
                                  # counter minibatch plans — the reference
                                  # mode for the fused on-device round
                                  # (repro.fl.fused); requires the batched
                                  # engine and SchedulerConfig(rng="counter")
    seed: int = 0


class PAOTAServer:
    def __init__(self, init_params, clients, chan: ChannelConfig,
                 sched_cfg: SchedulerConfig, cfg: PAOTAConfig):
        self.engine = make_engine(clients, cfg.engine)
        self.chan = chan
        self.cfg = cfg
        if cfg.rng == "counter":
            if not isinstance(self.engine, BatchedEngine):
                raise ValueError("rng='counter' needs the batched engine "
                                 "(counter minibatch plans)")
            if sched_cfg.rng != "counter":
                raise ValueError("rng='counter' needs SchedulerConfig("
                                 "rng='counter') so latency draws match")
            self.engine.enable_counter_plan(jax.random.PRNGKey(cfg.seed))
        self.scheduler = SemiAsyncScheduler(sched_cfg)
        # concrete Python floats, resolved OUTSIDE any jit trace (the
        # ChannelConfig.sigma_n property calls float(jnp.sqrt(...)))
        self._sigma_n = chan.sigma_n
        vec, self.unravel = ravel(init_params)
        # model-sized state is DEVICE-resident (jnp): the (K, d) pending
        # stacks and the globals used to round-trip through numpy every
        # round, and those host<->device copies — not the math — were the
        # host reference's scale ceiling (~1.2 s/round of np.asarray at
        # K = 4000). Host-facing reads go through the np properties below.
        self._global = jnp.asarray(vec, jnp.float32)
        self._prev = self._global
        self.d = int(self._global.shape[0])
        self.key = jax.random.PRNGKey(cfg.seed)
        k_tot = self.engine.n_clients
        # in-flight local results: trained model + the global it started from
        self._pending_models = jnp.tile(self._global, (k_tot, 1))
        self._pending_starts = jnp.tile(self._global, (k_tot, 1))
        # the two device stage pipelines, jitted ONCE per server: eager
        # per-round dispatch re-lowered ~10 programs and multi-passed the
        # (K, d) operands every round — the other half of the host-path
        # scale ceiling. The jitted bodies call the exact shared stage
        # helpers, so this changes scheduling, never math.
        self._jit_eq25 = jax.jit(eq25_factors,
                                 static_argnames=("omega", "use_kernel"))
        self._jit_finish = jax.jit(self._finish_round)
        self._kick_off(np.arange(k_tot))
        self.history: List[dict] = []

    # ------------------------------------------------------------------
    @property
    def global_vec(self) -> np.ndarray:
        """w_g^t as a host numpy vector (the historical attribute)."""
        return np.asarray(self._global)

    @property
    def prev_global(self) -> np.ndarray:
        """w_g^{t-1} as a host numpy vector."""
        return np.asarray(self._prev)

    def _kick_off(self, ids):
        """Broadcast current global model to `ids`; precompute their local
        training result (deterministic — consumed when their latency ends).
        One fused device call under the batched engine; the trained rows
        stay on device when the engine supports it."""
        ids = np.asarray(ids, dtype=np.int64)
        start = self._global
        broadcast_round = self.scheduler.round   # the round `ids` train on
        self.scheduler.start_round(ids)
        if ids.size == 0:
            return
        params = self.unravel(start)
        if hasattr(self.engine, "local_train_full"):
            # fixed-shape path: full (K, d) stack on device, broadcast rows
            # selected by a host-built mask (a varying-length gather /
            # scatter would re-lower one XLA program per participation
            # count)
            flat = self.engine.local_train_full(params, ids,
                                                round_idx=broadcast_round)
            m = np.zeros(self.engine.n_clients, bool)
            m[ids] = True
            sel = jnp.asarray(m)[:, None]
            self._pending_models = jnp.where(
                sel, flat.astype(self._pending_models.dtype),
                self._pending_models)
            self._pending_starts = jnp.where(sel, start[None, :],
                                             self._pending_starts)
        else:
            trained = jnp.asarray(self.engine.local_train(
                params, ids, round_idx=broadcast_round))
            idx = jnp.asarray(ids)
            self._pending_models = self._pending_models.at[idx].set(
                trained.astype(self._pending_models.dtype))
            self._pending_starts = self._pending_starts.at[idx].set(start)

    def global_params(self):
        return self.unravel(self._global)

    def _finish_round(self, payload, powers, b, h, noise_key, global_vec,
                      prev_global):
        """Jitted tail of the round: constraint-(7) cap -> AirComp ->
        guarded global update, via the same shared stage helpers the
        fused/sharded core runs. Returns (new_global, new_prev, varsigma)."""
        powers = constraint7_powers(powers, payload, h,
                                    self.chan.p_max_watts)
        agg, varsigma = paota_aggregate_stacked(
            payload, powers, b, noise_key, self._sigma_n,
            use_kernel=self.cfg.use_kernel)
        new_global, new_prev = guarded_global_update(
            global_vec, prev_global, agg, varsigma,
            delta=self.cfg.transmit == "delta")
        return new_global, new_prev, varsigma

    def _round_key(self, round_idx: int, tag: int):
        """Per-consumer subkey: counter mode derives it from (round, tag)
        so draws are reproducible without sequential state; host mode keeps
        the seed's split chain."""
        if self.cfg.rng == "counter":
            return round_tag_key(self.key, round_idx, tag)
        self.key, sub = jax.random.split(self.key)
        return sub

    # ------------------------------------------------------------------
    def round(self) -> dict:
        upl, stal = self.scheduler.advance_to_aggregation()
        r = self.scheduler.round - 1          # this aggregation's index
        k_tot = self.engine.n_clients
        b = np.zeros(k_tot)
        b[upl] = 1.0

        if b.sum() == 0:
            # Zero-uploader period: every client is still mid-training
            # (routine at small K or lat_lo >> delta_t). Nothing superposes,
            # so the received y is pure AWGN and eq. (8)'s normalizer is 0 —
            # running AirComp would divide noise by the 1e-12 clamp and
            # overwrite w_g with ~1e12-amplified garbage. Hold the global
            # (and its direction) and skip P2/channel/AirComp entirely.
            info = {"round": r,
                    "time": self.scheduler.time,
                    "n_participants": 0,
                    "mean_staleness": 0.0,
                    "beta_mean": 0.0,
                    "varsigma": 0.0,
                    "p2_objective": float("inf")}
            self.history.append(info)
            return info

        stacked = self._pending_models

        # staleness + similarity factors (eq. 25) — the SAME stage helper
        # the fused/sharded round core runs (repro.fl.runtime), so the host
        # reference cannot drift from the on-device implementations. The
        # (K, d) operands are already device-resident; only the (K,)
        # factors cross to host for the numpy P2 problem builder.
        deltas, rho, theta = self._jit_eq25(
            stacked, self._pending_starts, self._global, self._prev,
            jnp.asarray(stal, jnp.float32), omega=self.cfg.omega,
            use_kernel=self.cfg.use_kernel)
        rho, theta = np.asarray(rho, float), np.asarray(theta, float)

        # P2 -> beta -> powers
        p_max = np.full(k_tot, self.chan.p_max_watts)
        prob = build_p2(rho, theta, p_max, b, smooth_l=self.cfg.smooth_l,
                        eps_bound=self.cfg.eps_bound, model_dim=self.d,
                        sigma_n2=self.chan.sigma_n2)
        res = solve_p2(prob, self.cfg.solver)
        powers = prob.power(res.beta)

        # payload: full models (paper, eq. 6) or local updates (beyond-paper)
        payload = deltas if self.cfg.transmit == "delta" else stacked

        # instantaneous power constraint (7) under the sampled channel,
        # AirComp aggregation (eqs. 6+8), and the degenerate-normalizer
        # guard (if the capped powers somehow sum to ~0, hold the global
        # rather than assign amplified noise — same select as the fused
        # path): one jitted device call over the shared stage helpers
        h = sample_channel_gains(self._round_key(r, TAG_CHANNEL), k_tot,
                                 self.chan)
        self._global, self._prev, varsigma = self._jit_finish(
            payload, jnp.asarray(powers, jnp.float32),
            jnp.asarray(b, jnp.float32), h, self._round_key(r, TAG_NOISE),
            self._global, self._prev)

        # uploaders receive the new model and restart (Fig. 2 workflow)
        self._kick_off(upl)

        varsigma = float(varsigma)
        info = {"round": r,
                "time": self.scheduler.time,
                "n_participants": int(b.sum()),
                "mean_staleness": float(stal[upl].mean()) if len(upl) else 0.0,
                "beta_mean": float(np.mean(res.beta[b > 0])) if b.sum() else 0.0,
                "varsigma": varsigma if varsigma > VARSIGMA_MIN else 0.0,
                "p2_objective": res.objective}
        self.history.append(info)
        return info
