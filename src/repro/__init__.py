"""PAOTA: semi-asynchronous federated edge learning via over-the-air computation — production-grade JAX reproduction (see README.md)."""
