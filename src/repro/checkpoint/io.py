"""npz-based distributed-agnostic checkpointing: the pytree is flattened to
path-keyed arrays; restore rebuilds against a template tree (so sharding /
device placement is the caller's choice). Atomic via temp-file rename.

Dtype fidelity: ``np.savez`` silently degrades any non-native dtype — an
ml_dtypes ``bfloat16`` plane comes back as a void ``|V2`` array with its
type identity gone — so every leaf is stored as RAW BYTES (a flat uint8
buffer) with its true dtype string and shape recorded in the JSON index,
and restore views the buffer back. Save -> load is bit-identical for
every plane a ``RoundCarry`` holds (f32 globals, bf16 pending leaves,
int8 compressed slots, i32 scheduler fields, bool ready masks;
tests/test_checkpoint_roundtrip.py). Templates only contribute tree
structure and an expected dtype — ``jax.eval_shape`` ShapeDtypeStruct
leaves work (no materialization); a dtype mismatch between the file and
the template is an error, never a silent cast."""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any

import jax
import numpy as np


def _paths(tree) -> list:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out


def save_checkpoint(path: str, tree: Any, step: int = 0, extra: dict = None):
    flat = _paths(tree)
    arrays, dtypes, shapes = {}, [], []
    for i, (_, v) in enumerate(flat):
        a = np.asarray(v)
        dtypes.append(str(a.dtype))
        shapes.append(list(a.shape))
        # raw-bytes storage: np.savez round-trips uint8 exactly, and the
        # true dtype lives in the index — this is what keeps bf16 (and any
        # other non-native dtype) bit-identical through the npz container
        arrays[f"arr_{i}"] = np.frombuffer(
            np.ascontiguousarray(a).tobytes(), dtype=np.uint8)
    index = {"keys": [k for k, _ in flat], "dtypes": dtypes,
             "shapes": shapes, "step": step, "extra": extra or {}}
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    # the .npz suffix keeps np.savez writing THIS file (it appends .npz to
    # any other name, which would leak the mkstemp placeholder)
    fd, tmp = tempfile.mkstemp(suffix=".npz", dir=os.path.dirname(path) or ".")
    os.close(fd)
    np.savez(tmp, __index__=json.dumps(index), **arrays)
    os.replace(tmp, path)


def _leaf_dtype(t) -> np.dtype:
    """Template leaf dtype WITHOUT materializing the leaf — jax Arrays and
    ``jax.eval_shape`` ShapeDtypeStructs expose .dtype; plain scalars fall
    back through np.asarray. (The old ``np.asarray(template)`` path both
    gathered sharded templates to host and turned ShapeDtypeStructs into
    garbage object arrays.)"""
    dt = getattr(t, "dtype", None)
    return np.dtype(dt) if dt is not None else np.asarray(t).dtype


def load_checkpoint(path: str, template: Any):
    z = np.load(path, allow_pickle=False)
    index = json.loads(str(z["__index__"]))
    leaves_t, treedef = jax.tree_util.tree_flatten(template)
    if len(index["keys"]) != len(leaves_t):
        raise ValueError(
            f"checkpoint {path!r} holds {len(index['keys'])} leaves but the "
            f"template flattens to {len(leaves_t)} — the carry layout "
            "changed (different cohort/compress/grouped planes?)")
    restored = []
    for i, t in enumerate(leaves_t):
        dt = np.dtype(index["dtypes"][i])
        want = _leaf_dtype(t)
        if dt != want:
            raise ValueError(
                f"checkpoint leaf {index['keys'][i]!r} is {dt} but the "
                f"template expects {want} — refusing a silent cast")
        restored.append(np.frombuffer(z[f"arr_{i}"].tobytes(), dtype=dt)
                        .reshape(index["shapes"][i]).copy())
    return (jax.tree_util.tree_unflatten(treedef, restored),
            index["step"], index["extra"])
