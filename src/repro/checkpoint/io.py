"""npz-based distributed-agnostic checkpointing: the pytree is flattened to
path-keyed arrays; restore rebuilds against a template tree (so sharding /
device placement is the caller's choice). Atomic via temp-file rename."""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any

import jax
import numpy as np


def _paths(tree) -> list:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out


def save_checkpoint(path: str, tree: Any, step: int = 0, extra: dict = None):
    arrays = {f"arr_{i}": np.asarray(v) for i, (_, v) in enumerate(_paths(tree))}
    index = {"keys": [k for k, _ in _paths(tree)], "step": step,
             "extra": extra or {}}
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".")
    os.close(fd)
    np.savez(tmp, __index__=json.dumps(index), **arrays)
    os.replace(tmp + ".npz" if os.path.exists(tmp + ".npz") else tmp, path)


def load_checkpoint(path: str, template: Any):
    z = np.load(path, allow_pickle=False)
    index = json.loads(str(z["__index__"]))
    leaves_t, treedef = jax.tree_util.tree_flatten(template)
    arrays = [z[f"arr_{i}"] for i in range(len(leaves_t))]
    restored = [np.asarray(a, dtype=np.asarray(t).dtype)
                for a, t in zip(arrays, leaves_t)]
    return (jax.tree_util.tree_unflatten(treedef, restored),
            index["step"], index["extra"])
