"""Registry of assigned architectures (public pool) + the paper's own model.

``get_config("<arch-id>")`` accepts the dashed ids from the assignment
(e.g. "llama4-maverick-400b-a17b") and returns the exact published config;
``get_reduced("<arch-id>")`` returns the smoke-test variant (<=2 layers,
d_model<=128, <=4 experts).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

_MODULES = {
    "llama4-maverick-400b-a17b": "repro.configs.llama4_maverick_400b_a17b",
    "smollm-135m": "repro.configs.smollm_135m",
    "mamba2-370m": "repro.configs.mamba2_370m",
    "olmo-1b": "repro.configs.olmo_1b",
    "internvl2-1b": "repro.configs.internvl2_1b",
    "minicpm-2b": "repro.configs.minicpm_2b",
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "hubert-xlarge": "repro.configs.hubert_xlarge",
    "zamba2-7b": "repro.configs.zamba2_7b",
    "granite-3-8b": "repro.configs.granite_3_8b",
}

ARCH_IDS: List[str] = list(_MODULES)


def _norm(name: str) -> str:
    return name.replace("_", "-")


def get_config(name: str) -> ModelConfig:
    key = _norm(name)
    if key not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {ARCH_IDS}")
    return importlib.import_module(_MODULES[key]).CONFIG


def get_reduced(name: str) -> ModelConfig:
    key = _norm(name)
    if key not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {ARCH_IDS}")
    return importlib.import_module(_MODULES[key]).REDUCED


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
