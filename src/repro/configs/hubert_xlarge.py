"""hubert-xlarge [audio] — encoder-only, wav2vec2-style backbone.
[arXiv:2106.07447] 48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504
(k-means codebook targets).

Audio frontend (mel-spectrogram + conv feature extractor) is a STUB per
instructions: input_specs() provides precomputed 512-d frame features.
Encoder-only: decode_32k / long_500k are skipped (no decode step) —
recorded in DESIGN.md §4.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    causal=False,
    encoder_only=True,
    modality="audio",
    frontend_dim=512,     # conv feature extractor output dim (stubbed)
    mask_prob=0.08,
    tie_embeddings=False,
    source="arXiv:2106.07447 (HuBERT X-Large)",
)

REDUCED = CONFIG.reduced()
