"""minicpm-2b [dense] — WSD schedule, depth-scaled residuals. [arXiv:2404.06395]
40L d_model=2304 36H (GQA kv=36) d_ff=5760 vocab=122753.

The WSD (warmup-stable-decay) learning-rate schedule is implemented in
repro.optim.schedules and selected by this config's training recipe.
"""
import math

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    num_layers=40,
    d_model=2304,
    num_heads=36,
    num_kv_heads=36,
    head_dim=64,
    d_ff=5760,
    vocab_size=122753,
    residual_scale=1.4 / math.sqrt(40),   # MiniCPM scale_depth=1.4
    tie_embeddings=True,
    source="arXiv:2404.06395 (MiniCPM-2B)",
)

REDUCED = CONFIG.reduced()
