"""olmo-1b [dense] — non-parametric LayerNorm. [arXiv:2402.00838]
16L d_model=2048 16H (GQA kv=16) d_ff=8192 vocab=50304."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=8192,
    vocab_size=50304,
    norm="nonparam_ln",   # OLMo: LayerNorm without learnable affine params
    tie_embeddings=True,
    source="arXiv:2402.00838 (OLMo-1B)",
)

REDUCED = CONFIG.reduced()
