"""mixtral-8x22b [moe] — 8 experts top-2, sliding-window attention.
[arXiv:2401.04088] 56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768,
MoE 8e top-2. SWA makes long_500k decode O(window) natively.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    num_experts=8,
    experts_per_token=2,
    moe_layer_period=1,
    sliding_window=4096,
    tie_embeddings=False,
    rope_theta=1000000.0,
    source="arXiv:2401.04088 (Mixtral-8x22B)",
)

REDUCED = CONFIG.reduced()
