"""mamba2-370m [ssm] — SSD (state-space duality). [arXiv:2405.21060]
48L d_model=1024 (attention-free) vocab=50280, ssm_state=128."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=0,          # attention-free
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,      # d_inner=2048 -> 32 SSM heads
    ssm_ngroups=1,
    conv_kernel=4,
    tie_embeddings=True,
    source="arXiv:2405.21060 (Mamba2-370m)",
)

REDUCED = CONFIG.reduced()
