"""The paper's own experiment model (Section IV-A): an MLP with two hidden
layers of 10 nodes for 10-class 28x28 digit classification, trained by the
FL runtime (repro.fl) on the non-IID federation.

Not part of the transformer zoo — exposed here so every model the framework
trains has a config module. Build with repro.models.mlp.init_mlp_params.
"""
from dataclasses import dataclass


@dataclass(frozen=True)
class MLPConfig:
    d_in: int = 784          # 28x28
    hidden: int = 10         # "two hidden layers with 10 nodes"
    n_layers: int = 2
    n_classes: int = 10
    source: str = "PAOTA paper Sec. IV-A (MLP on MNIST)"


CONFIG = MLPConfig()
