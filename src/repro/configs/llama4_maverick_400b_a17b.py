"""llama4-maverick-400b-a17b [moe] — MoE, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E] (assigned spec: 48L d_model=5120 40H
GQA kv=8 d_ff=8192 vocab=202048, MoE 128 experts top-1).

long_500k: full-attention MoE — run with the framework's sliding-window
variant (sliding_window=8192 override applied by launch.shapes for that
shape only; flagged beyond-paper, see DESIGN.md §4).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    num_experts=128,
    experts_per_token=1,
    moe_layer_period=1,
    tie_embeddings=False,
    rope_theta=500000.0,
    source="hf:meta-llama/Llama-4-Scout-17B-16E (assigned pool spec)",
)

REDUCED = CONFIG.reduced()
