"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242] 81L d_model=3584 32H (kv=32) d_ff=14336 vocab=32000,
ssm_state=64. One shared attention+MLP block is reused every 6 layers
(Zamba-style depth weight sharing). Sub-quadratic: runs long_500k.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    head_dim=112,
    d_ff=14336,           # shared attention block MLP width
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,      # d_inner=7168 -> 112 SSM heads
    ssm_ngroups=1,
    conv_kernel=4,
    shared_attn_period=6,
    sliding_window=4096,  # shared attn block uses SWA for long-context decode
    tie_embeddings=False,
    source="arXiv:2411.15242 (Zamba2-7B)",
)

REDUCED = CONFIG.reduced()
