"""internvl2-1b [vlm] — InternViT + InternLM2/Qwen2 backbone. [arXiv:2404.16821]
24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655.

Vision frontend is a STUB per instructions: input_specs() provides
precomputed InternViT patch embeddings (frontend_dim=1024, 256 patches);
the learned projector + language decoder are fully implemented.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151655,
    modality="vision_text",
    frontend_dim=1024,    # InternViT-300M hidden size
    num_patches=256,
    tie_embeddings=True,
    rope_theta=1000000.0,
    source="arXiv:2404.16821 (InternVL2-1B, Qwen2-0.5B backbone)",
)

REDUCED = CONFIG.reduced()
