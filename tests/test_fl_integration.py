"""End-to-end FL integration tests: PAOTA + baselines on a small synthetic
non-IID federation (system behaviour, not unit mechanics)."""
import jax
import numpy as np
import pytest

from repro.core import ChannelConfig, SchedulerConfig
from repro.data.partition import heterogeneity_stats, partition_noniid
from repro.data.pipeline import build_federation
from repro.data.synthetic import make_mnist_like
from repro.fl import (COTAFServer, FLClient, LocalSGDServer, PAOTAConfig,
                      PAOTAServer, SyncConfig, evaluate, time_to_accuracy)
from repro.models.mlp import init_mlp_params, mlp_apply, mlp_loss


@pytest.fixture(scope="module")
def world():
    x_tr, y_tr, x_te, y_te = make_mnist_like(n_train=3000, n_test=800)
    parts = partition_noniid(y_tr, n_clients=12, seed=0)
    fed = build_federation(x_tr, y_tr, parts)
    clients = [FLClient(d, mlp_loss, batch_size=32, lr=0.1, local_steps=5)
               for d in fed]
    params = init_mlp_params(jax.random.PRNGKey(0))
    return clients, params, (x_tr, y_tr, x_te, y_te)


def test_partition_respects_paper_constraints():
    _, y_tr, _, _ = make_mnist_like(n_train=3000, n_test=10)
    parts = partition_noniid(y_tr, n_clients=30, seed=1)
    stats = heterogeneity_stats(parts, y_tr)
    assert stats["classes_max"] <= 5          # at most 5 digit classes
    assert stats["sizes_min"] >= 1


def test_paota_learns(world):
    clients, params, (x_tr, y_tr, x_te, y_te) = world
    srv = PAOTAServer(params, clients, ChannelConfig(),
                      SchedulerConfig(n_clients=12, seed=1), PAOTAConfig())
    acc0 = evaluate(srv.global_params(), x_te, y_te, mlp_apply)["accuracy"]
    for _ in range(10):
        info = srv.round()
    acc1 = evaluate(srv.global_params(), x_te, y_te, mlp_apply)["accuracy"]
    assert acc1 > acc0 + 0.15
    assert info["time"] == pytest.approx(10 * 8.0)      # periodic clock
    assert 0 < info["n_participants"] <= 12


def test_paota_semi_async_state_machine(world):
    clients, params, _ = world
    srv = PAOTAServer(params, clients, ChannelConfig(),
                      SchedulerConfig(n_clients=12, seed=3), PAOTAConfig())
    saw_straggler = False
    for _ in range(8):
        info = srv.round()
        if info["mean_staleness"] > 0:
            saw_straggler = True
    assert saw_straggler


def test_paota_noise_robustness_at_paper_operating_point(world):
    """Fig. 3's claim: at the paper's high-noise setting (-74 dBm/Hz) PAOTA's
    noise-aware power control keeps convergence close to the clean-channel
    (-174 dBm/Hz) run. (Far harsher noise eventually breaks the full-model
    AirComp uplink for every scheme — see EXPERIMENTS.md notes.)"""
    clients, params, (x_tr, y_tr, x_te, y_te) = world
    accs = {}
    for n0 in (-174.0, -74.0):
        chan = ChannelConfig(n0_dbm_hz=n0)
        p = PAOTAServer(params, clients, chan,
                        SchedulerConfig(n_clients=12, seed=5), PAOTAConfig())
        for _ in range(8):
            p.round()
        accs[n0] = evaluate(p.global_params(), x_te, y_te,
                            mlp_apply)["accuracy"]
    assert accs[-74.0] >= accs[-174.0] - 0.08


def test_sync_baselines_learn_and_cost_more_time(world):
    clients, params, (x_tr, y_tr, x_te, y_te) = world
    srv = LocalSGDServer(params, clients, SchedulerConfig(n_clients=12, seed=2),
                         SyncConfig(n_select=6))
    for _ in range(10):
        srv.round()
    acc = evaluate(srv.global_params(), x_te, y_te, mlp_apply)["accuracy"]
    assert acc > 0.4
    assert srv.time / 10 > 8.0               # sync rounds slower than delta_t


def test_time_to_accuracy_helper():
    hist = [{"round": 1, "time": 8, "accuracy": 0.4},
            {"round": 2, "time": 16, "accuracy": 0.55},
            {"round": 3, "time": 24, "accuracy": 0.72}]
    tta = time_to_accuracy(hist, targets=(0.5, 0.7, 0.9))
    assert tta[0.5] == (2, 16)
    assert tta[0.7] == (3, 24)
    assert tta[0.9] == (None, None)
