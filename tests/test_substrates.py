"""Substrate tests: sharding rules (divisibility for all 10 archs x both
meshes), optimizers/schedules, checkpointing, data pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced

HAS_512 = "xla_force_host_platform_device_count" in os.environ.get("XLA_FLAGS", "")


def _mesh_shapes(multi):
    return ((2, 16, 16), ("pod", "data", "model")) if multi \
        else ((16, 16), ("data", "model"))


class _FakeMesh:
    """Shape-only stand-in so sharding rules can be tested without 512
    devices (the real mesh is exercised by launch.dryrun)."""

    def __init__(self, multi):
        shape, names = _mesh_shapes(multi)
        self.axis_names = names
        self.shape = dict(zip(names, shape))
        self.size = int(np.prod(shape))


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("multi", [False, True])
def test_param_specs_divisible_all_archs(arch, multi):
    """Every sharded dim must divide by its mesh-axis size — the exact
    constraint pjit enforces on in_shardings (this caught the odd-vocab and
    8-expert cases)."""
    import dataclasses
    from repro.launch.steps import abstract_params
    from repro.sharding.rules import param_specs

    cfg = dataclasses.replace(get_config(arch), param_dtype="bfloat16")
    mesh = _FakeMesh(multi)
    tree = abstract_params(cfg)
    specs = param_specs(tree, cfg, mesh, ep_axis="data")

    leaves_s, _ = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    leaves_t = jax.tree_util.tree_leaves(tree)
    assert len(leaves_s) == len(leaves_t)
    n_sharded = 0
    for spec, leaf in zip(leaves_s, leaves_t):
        for i, ax in enumerate(tuple(spec)):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = int(np.prod([mesh.shape[a] for a in axes]))
            assert leaf.shape[i] % size == 0, (arch, spec, leaf.shape)
            n_sharded += 1
    assert n_sharded > 0   # the model is actually distributed


@pytest.mark.parametrize("arch", ["granite-3-8b", "mamba2-370m",
                                  "mixtral-8x22b", "zamba2-7b"])
def test_decode_state_specs_divisible(arch):
    from repro.launch.shapes import SHAPES, shape_config
    from repro.models.transformer import init_decode_state
    from repro.sharding.rules import decode_state_specs
    import dataclasses

    shape = SHAPES["decode_32k"]
    cfg = dataclasses.replace(shape_config(get_config(arch), shape),
                              param_dtype="bfloat16")
    mesh = _FakeMesh(False)
    state = jax.eval_shape(
        lambda: init_decode_state(cfg, shape.global_batch, shape.seq_len))
    specs = decode_state_specs(state, cfg, mesh, ("data",))
    leaves_s = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    leaves_t = jax.tree_util.tree_leaves(state)
    for spec, leaf in zip(leaves_s, leaves_t):
        for i, ax in enumerate(tuple(spec)):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = int(np.prod([mesh.shape[a] for a in axes]))
            assert leaf.shape[i] % size == 0, (arch, spec, leaf.shape)


# ---------------------------------------------------------------------------
# optimizers / schedules
# ---------------------------------------------------------------------------

def test_sgd_matches_manual():
    from repro.optim import sgd
    from repro.optim.optimizers import apply_updates
    opt = sgd(0.1)
    p = {"w": jnp.ones(3)}
    st = opt.init(p)
    g = {"w": jnp.full(3, 2.0)}
    upd, st = opt.update(g, st, p)
    np.testing.assert_allclose(np.asarray(apply_updates(p, upd)["w"]), 0.8)


def test_adamw_decreases_quadratic():
    from repro.optim import adamw
    from repro.optim.optimizers import apply_updates
    opt = adamw(0.1)
    p = {"w": jnp.asarray([3.0, -2.0])}
    st = opt.init(p)
    for _ in range(100):
        g = {"w": 2 * p["w"]}
        upd, st = opt.update(g, st, p)
        p = apply_updates(p, upd)
    assert float(jnp.sum(p["w"] ** 2)) < 0.2


def test_wsd_schedule_phases():
    from repro.optim import wsd
    f = wsd(peak=1.0, warmup=10, stable=20, decay=10, floor_frac=0.1)
    assert float(f(jnp.int32(5))) == pytest.approx(0.5)
    assert float(f(jnp.int32(20))) == pytest.approx(1.0)
    assert float(f(jnp.int32(40))) == pytest.approx(0.1, rel=0.01)


# ---------------------------------------------------------------------------
# checkpoint / data
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import load_checkpoint, save_checkpoint
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones(4, jnp.int32)}}
    path = str(tmp_path / "ck.npz")
    save_checkpoint(path, tree, step=7, extra={"note": "x"})
    restored, step, extra = load_checkpoint(path, tree)
    assert step == 7 and extra["note"] == "x"
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(restored["b"]["c"]),
                                  np.asarray(tree["b"]["c"]))


def test_client_data_batches_cycle_and_reshuffle():
    from repro.data.pipeline import ClientData
    x = np.arange(40).reshape(20, 2).astype(np.float32)
    y = np.arange(20).astype(np.int32)
    cd = ClientData(x, y, client_id=0)
    batches = list(cd.batches(8, 5))         # needs 40 samples from 20 -> cycle
    assert len(batches) == 5
    assert all(len(b["y"]) == 8 for b in batches)


def test_token_stream_learnable_structure():
    from repro.data.synthetic import token_stream
    b = next(token_stream(97, 4, 64, 1, seed=0))
    toks = b["tokens"]
    pred = (toks[:, :-1] * (31 % 97) + 7) % 97
    frac = (pred == toks[:, 1:]).mean()
    assert frac > 0.7                        # mostly Markov, some noise
