"""Slot-clock precision: the host scheduler and the fused scan must decide
"training finished by this aggregation slot" IDENTICALLY at any horizon.

The old fused formulation accumulated an absolute f32 clock and compared
``busy_until <= time``; at delta_t values inexact in binary (0.1) the f32
products drift from the host's f64 clock by a growing ulp and eventually
flip a slot boundary — silently forking the two trajectories mid-run. The
fix carries the raw latency DRAW and evaluates the exact relative
predicate ``lat <= (round + 1 - model_round) * delta_t`` (one IEEE
rounding in the draw's own dtype) on both sides — ``repro.core.scheduler
.slot_ready`` — so the masks are bit-identical, not approximately close.

The regression here runs delta_t = 0.1 for >= 1000 rounds with draws
tight around small slot multiples (the regime where absolute-clock
rounding reliably flips boundaries) and pins the host counter-mode
scheduler against the pure-jnp scan transition bit for bit.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SchedulerConfig, SemiAsyncScheduler
from repro.core.scheduler import (counter_latencies, sched_advance,
                                  sched_broadcast, slot_ready)

K, R, DELTA_T = 64, 2000, 0.1
LAT_LO, LAT_HI = 0.15, 0.35      # finishes land 2-4 slots out — every
                                 # draw sits near a small slot boundary


def _device_masks(seed):
    """The fused-round scheduler transition alone (sched_advance +
    sched_broadcast in a lax.scan over counter draws) — exactly what
    ``paota_round_step`` stages 1 and 7 run."""
    key = jax.random.PRNGKey(seed)

    def step(c, t):
        ready, busy_lat, model_round = c
        rdy, stal = sched_advance(ready, busy_lat, model_round, t, DELTA_T)
        lat = counter_latencies(key, t + 1, K, LAT_LO, LAT_HI)
        nxt = sched_broadcast(rdy, busy_lat, model_round, rdy, lat, t + 1)
        return nxt, (rdy, stal)

    init = (jnp.zeros((K,), bool),
            counter_latencies(key, 0, K, LAT_LO, LAT_HI),
            jnp.zeros((K,), jnp.int32))
    _, (ready, stal) = jax.lax.scan(step, init, jnp.arange(R))
    return np.asarray(ready), np.asarray(stal)


def _host_masks(seed):
    """The host reference: SemiAsyncScheduler in counter mode (f32 draws,
    f64 host arithmetic everywhere else)."""
    sched = SemiAsyncScheduler(SchedulerConfig(
        n_clients=K, delta_t=DELTA_T, lat_lo=LAT_LO, lat_hi=LAT_HI,
        seed=seed, rng="counter"))
    sched.start_round(range(K))
    ready = np.zeros((R, K), bool)
    stal = np.zeros((R, K), np.int64)
    for r in range(R):
        uploaders, s = sched.advance_to_aggregation()
        ready[r, uploaders] = True
        stal[r] = s
        sched.start_round(uploaders)
    return ready, stal


def test_host_and_fused_masks_bit_identical_long_horizon():
    dev_ready, dev_stal = _device_masks(seed=0)
    host_ready, host_stal = _host_masks(seed=0)
    # every client participates and goes back busy many times — the masks
    # are exercised, not vacuously all-True/all-False
    flips = np.sum(dev_ready[1:] != dev_ready[:-1])
    assert flips > R                # thousands of boundary decisions
    np.testing.assert_array_equal(dev_ready, host_ready)
    np.testing.assert_array_equal(dev_stal.astype(np.int64), host_stal)


def test_absolute_f32_clock_would_flip_boundaries():
    """The failure mode the relative predicate removes, reconstructed as
    the OLD formulation computed it: the fused carry stored
    ``busy_until = f32(broadcast_time) + f32(lat)`` and compared it to the
    f32 slot clock, while the host compared the same quantities in f64.
    Over delta_t = 0.1 horizons the two absolute forms disagree on real
    draws — which is exactly why the carry now stores the raw draw and
    both sides evaluate ``slot_ready`` (documents the bug; fails if this
    regression scenario ever goes stale)."""
    key = jax.random.PRNGKey(0)
    disagree = 0
    for r in range(R):                  # broadcast rounds across the horizon
        lat = np.asarray(counter_latencies(key, r, K, LAT_LO, LAT_HI))
        busy32 = np.float32(r) * np.float32(DELTA_T) + lat  # old fused carry
        busy64 = r * float(DELTA_T) + lat.astype(np.float64)  # host clock
        for m in range(1, 5):
            slot32 = np.float32(r + m) * np.float32(DELTA_T)
            slot64 = (r + m) * float(DELTA_T)
            disagree += int(np.sum((busy32 <= slot32) != (busy64 <= slot64)))
        # the NEW predicate agrees with itself by construction on the same
        # draws: one rounding, same dtype on both sides
        mr = np.zeros(K, np.int64) + r
        for m in range(1, 5):
            host = slot_ready(lat, mr, r + m - 1, DELTA_T)
            dev = np.asarray(slot_ready(jnp.asarray(lat),
                                        jnp.asarray(mr, jnp.int32),
                                        jnp.int32(r + m - 1), DELTA_T))
            np.testing.assert_array_equal(host, dev)
    assert disagree > 0


def test_slot_ready_matches_between_numpy_and_jnp():
    """The predicate itself is one shared function evaluated over numpy on
    the host and jnp on device — same dtype, same ops, same bits."""
    rng = np.random.default_rng(3)
    lat = rng.uniform(LAT_LO, LAT_HI, 256).astype(np.float32)
    model_round = rng.integers(0, 1000, 256)
    for round_idx in (0, 7, 999, 10_000, 100_000):
        host = slot_ready(lat, model_round, round_idx, DELTA_T)
        dev = np.asarray(slot_ready(jnp.asarray(lat),
                                    jnp.asarray(model_round, jnp.int32),
                                    jnp.int32(round_idx), DELTA_T))
        valid = model_round <= round_idx + 1
        np.testing.assert_array_equal(host[valid], dev[valid])
