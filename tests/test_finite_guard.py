"""Non-finite-aggregate guard (ISSUE-10 satellite): a client whose upload
carries NaN/Inf must never write into w_g.

Pre-fix, ``guarded_global_update`` only guarded the ~0 normalizer: a
non-finite aggregate (deep-fade overflow, a NaN local delta) sailed past
the varsigma check and destroyed the global model. The fixed guard treats
a poisoned period exactly like a zero-uploader period — w_g AND
prev_global hold bit-identically — on the host, fused, and sharded
drivers, in both transmit modes (mirrors tests/test_zero_uploader.py).

The NaN source here is organic: one client's training data is poisoned
with NaN, so its local SGD emits NaN weights and the uplink carries them
— no screening configured, the aggregate-level guard is the only line of
defense.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ChannelConfig, SchedulerConfig
from repro.core.aggregation import guarded_global_update
from repro.data.partition import partition_noniid
from repro.data.pipeline import build_federation
from repro.data.synthetic import make_mnist_like
from repro.fl import FLClient, FusedPAOTA, PAOTAConfig, PAOTAServer
from repro.models.mlp import init_mlp_params, mlp_loss

K = 8


@pytest.fixture(scope="module")
def world():
    x, y, _, _ = make_mnist_like(n_train=2000, n_test=10)
    parts = partition_noniid(y, n_clients=K, seed=0)
    return x, y, parts


def _clients(world, poison: bool):
    x, y, parts = world
    fed = build_federation(x, y, parts)
    if poison:
        fed[0].x = np.full_like(fed[0].x, np.nan)
    return [FLClient(d, mlp_loss, batch_size=32, lr=0.1, local_steps=5)
            for d in fed]


def _params():
    return init_mlp_params(jax.random.PRNGKey(0))


# fast latencies: every client (including the poisoned one) uploads every
# period, so the guard faces a non-finite aggregate from round 1 on
FAST_SCHED = dict(n_clients=K, delta_t=8.0, lat_lo=0.5, lat_hi=3.0)


# ---------------------------------------------------------------------------
# unit level
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("delta", [False, True])
@pytest.mark.parametrize("bad", [np.nan, np.inf, -np.inf])
def test_guard_holds_on_nonfinite_aggregate(delta, bad):
    g = jnp.arange(4, dtype=jnp.float32)
    pg = g - 1.0
    agg = g.at[2].set(bad)
    ng, npg = guarded_global_update(g, pg, agg, jnp.float32(1.0),
                                    delta=delta)
    np.testing.assert_array_equal(np.asarray(ng), np.asarray(g))
    np.testing.assert_array_equal(np.asarray(npg), np.asarray(pg))


@pytest.mark.parametrize("delta", [False, True])
def test_guard_passes_finite_aggregate(delta):
    g = jnp.arange(4, dtype=jnp.float32)
    pg = g - 1.0
    agg = jnp.full((4,), 0.5, jnp.float32)
    ng, npg = guarded_global_update(g, pg, agg, jnp.float32(1.0),
                                    delta=delta)
    want = g + agg if delta else agg
    np.testing.assert_array_equal(np.asarray(ng), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(npg), np.asarray(g))


def test_guard_nonfinite_pytree_leaf():
    """One NaN leaf anywhere in a pytree aggregate holds EVERY leaf."""
    g = {"w": jnp.ones((3,)), "b": jnp.zeros((2,))}
    pg = {"w": jnp.zeros((3,)), "b": jnp.ones((2,))}
    agg = {"w": jnp.full((3,), 2.0), "b": jnp.array([1.0, jnp.nan])}
    ng, npg = guarded_global_update(g, pg, agg, jnp.float32(1.0))
    for k in g:
        np.testing.assert_array_equal(np.asarray(ng[k]), np.asarray(g[k]))
        np.testing.assert_array_equal(np.asarray(npg[k]), np.asarray(pg[k]))


# ---------------------------------------------------------------------------
# driver level: host / fused / sharded, both transmit modes
# ---------------------------------------------------------------------------

def _assert_held(srv, n_rounds=3):
    g0 = np.array(srv.global_vec, copy=True)
    uploads = 0
    for _ in range(n_rounds):
        uploads += srv.round()["n_participants"]
    assert uploads > 0          # the guard engaged, not a zero-uploader gap
    np.testing.assert_array_equal(srv.global_vec, g0)
    assert np.isfinite(srv.global_vec).all()


@pytest.mark.parametrize("transmit", ["model", "delta"])
def test_host_holds_global_on_nan_client(world, transmit):
    srv = PAOTAServer(_params(), _clients(world, poison=True),
                      ChannelConfig(), SchedulerConfig(seed=1, **FAST_SCHED),
                      PAOTAConfig(transmit=transmit, engine="batched"))
    _assert_held(srv)


@pytest.mark.parametrize("transmit", ["model", "delta"])
def test_fused_holds_global_on_nan_client(world, transmit):
    srv = FusedPAOTA(_params(), _clients(world, poison=True),
                     ChannelConfig(), SchedulerConfig(seed=1, **FAST_SCHED),
                     PAOTAConfig(transmit=transmit))
    _assert_held(srv)


@pytest.mark.multidevice
@pytest.mark.parametrize("transmit", ["model", "delta"])
def test_sharded_holds_global_on_nan_client(world, transmit, client_mesh_8):
    from repro.fl import ShardedPAOTA
    srv = ShardedPAOTA(_params(), _clients(world, poison=True),
                       ChannelConfig(), SchedulerConfig(seed=1, **FAST_SCHED),
                       PAOTAConfig(transmit=transmit), mesh=client_mesh_8)
    _assert_held(srv)


def test_clean_run_still_progresses(world):
    """Control: the same config without the poisoned client must update
    w_g (the guard is a non-finite select, not a freeze)."""
    srv = FusedPAOTA(_params(), _clients(world, poison=False),
                     ChannelConfig(), SchedulerConfig(seed=1, **FAST_SCHED),
                     PAOTAConfig())
    g0 = np.array(srv.global_vec, copy=True)
    srv.advance(2)
    assert not np.array_equal(srv.global_vec, g0)
    assert np.isfinite(srv.global_vec).all()
