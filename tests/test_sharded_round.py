"""Mesh-sharded PAOTA round: the shard_map'd scan must reproduce the
single-device fused scan round for round (same counter streams, float
reduction order across shards the only difference), on an 8-virtual-device
CPU mesh (tests/conftest.py forces the devices)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ChannelConfig, SchedulerConfig
from repro.data.partition import partition_noniid
from repro.data.pipeline import build_federation
from repro.data.synthetic import make_mnist_like
from repro.fl import FLClient, FusedPAOTA, PAOTAConfig, ShardedPAOTA
from repro.models.mlp import init_mlp_params, mlp_loss

pytestmark = pytest.mark.multidevice

K = 8


@pytest.fixture(scope="module")
def data():
    x, y, _, _ = make_mnist_like(n_train=2000, n_test=10)
    parts = partition_noniid(y, n_clients=K, seed=0)
    return x, y, parts


def _clients(data):
    x, y, parts = data
    return [FLClient(d, mlp_loss, batch_size=32, lr=0.1, local_steps=5)
            for d in build_federation(x, y, parts)]


def _params():
    return init_mlp_params(jax.random.PRNGKey(0))


def test_sharded_matches_fused_over_rounds(data, client_mesh_8):
    """Acceptance: ShardedPAOTA on the 8-device mesh is allclose to the
    single-device FusedPAOTA round for round over >= 3 rounds — identical
    counter streams (latency, channel, noise, minibatch plans), psum'd
    AirComp vs single-device einsum."""
    fused = FusedPAOTA(_params(), _clients(data), ChannelConfig(),
                       SchedulerConfig(n_clients=K, seed=1), PAOTAConfig())
    shard = ShardedPAOTA(_params(), _clients(data), ChannelConfig(),
                         SchedulerConfig(n_clients=K, seed=1),
                         PAOTAConfig(), mesh=client_mesh_8)
    assert shard.n_shards == 8 and shard.k_local == 1
    for rf, rs in zip(fused.advance(4), shard.advance(4)):
        assert rf["n_participants"] == rs["n_participants"]
        assert rf["time"] == rs["time"]
        assert rf["mean_staleness"] == pytest.approx(rs["mean_staleness"],
                                                     rel=1e-5)
        assert rf["varsigma"] == pytest.approx(rs["varsigma"], rel=1e-5)
        np.testing.assert_allclose(fused.global_vec, shard.global_vec,
                                   rtol=1e-4, atol=1e-5)


def test_sharded_chunked_scan_parity(data, client_mesh_8):
    """Counter RNG is position-based: one 6-round scan and 3+3 chunked
    scans land on the same sharded trajectory."""
    one = ShardedPAOTA(_params(), _clients(data), ChannelConfig(),
                       SchedulerConfig(n_clients=K, seed=1),
                       PAOTAConfig(), mesh=client_mesh_8)
    two = ShardedPAOTA(_params(), _clients(data), ChannelConfig(),
                       SchedulerConfig(n_clients=K, seed=1),
                       PAOTAConfig(), mesh=client_mesh_8)
    rows = one.advance(6)
    two.advance(3)
    two.advance(3)
    assert any(r["n_participants"] > 0 for r in rows)
    np.testing.assert_allclose(one.global_vec, two.global_vec,
                               rtol=1e-5, atol=1e-6)


def test_sharded_pads_non_divisible_k_with_phantoms(client_mesh_8):
    """A client-axis extent that does not divide K pads the federation
    with masked phantom clients (never ready, zero power) instead of
    refusing; the padded run completes with only real participants.
    (Draw-for-draw invariance vs the unsharded run is pinned in
    tests/test_pytree_round.py.)"""
    x, y, _, _ = make_mnist_like(n_train=1500, n_test=10)
    parts = partition_noniid(y, n_clients=6, seed=0)
    clients = [FLClient(d, mlp_loss, batch_size=32, lr=0.1, local_steps=2)
               for d in build_federation(x, y, parts)]
    srv = ShardedPAOTA(_params(), clients, ChannelConfig(),
                       SchedulerConfig(n_clients=6, seed=1), PAOTAConfig(),
                       mesh=client_mesh_8)
    assert (srv.k, srv.k_pad, srv.n_phantom, srv.k_local) == (6, 8, 2, 1)
    rows = srv.advance(4)
    assert all(r["n_participants"] <= 6 for r in rows)
    assert any(r["n_participants"] > 0 for r in rows)
    assert np.isfinite(srv.global_vec).all()


def test_shard_aware_kernel_entries_match_reference(client_mesh_8):
    """The kernels' shard-aware entry points (aircomp psum reduction,
    shard-local cosines) inside shard_map equal the single-device
    reductions on the gathered arrays."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.core.power_control import cosine_similarity
    from repro.kernels.aircomp_sum import aircomp_sum_psum
    from repro.kernels.cosine_sim import cosine_sim_shard

    k, d = 16, 96
    key = jax.random.PRNGKey(3)
    stacked = jax.random.normal(key, (k, d), jnp.float32)
    bp = jax.random.uniform(jax.random.fold_in(key, 1), (k,))
    noise = jax.random.normal(jax.random.fold_in(key, 2), (d,))
    g = jax.random.normal(jax.random.fold_in(key, 3), (d,))

    def body(s, b, n, gg):
        agg, varsigma = aircomp_sum_psum(s, b, n, "data")
        cos = cosine_sim_shard(s, gg, "data")
        return agg, varsigma, cos

    smap = jax.jit(shard_map(
        body, client_mesh_8,
        in_specs=(P("data"), P("data"), P(), P()),
        out_specs=(P(), P(), P("data"))))
    agg, varsigma, cos = smap(stacked, bp, noise, g)

    ref_vs = jnp.sum(bp)
    ref_agg = (jnp.einsum("k,kd->d", bp, stacked) + noise) / ref_vs
    np.testing.assert_allclose(np.asarray(varsigma), float(ref_vs), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(agg), np.asarray(ref_agg),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(cos),
                               np.asarray(cosine_similarity(stacked, g)),
                               rtol=1e-5, atol=1e-6)


def test_sharded_waterfill_matches_single_device(client_mesh_8):
    """P2 water-filling with psum'd grid reductions returns the same beta
    (each shard its slice) and objective as the single-device solve."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.core.boxqp import waterfill_beta_jnp

    k = 24
    rng = np.random.default_rng(0)
    rho = jnp.asarray(rng.uniform(0.2, 1.0, k), jnp.float32)
    theta = jnp.asarray(rng.uniform(0.0, 1.0, k), jnp.float32)
    p_max = jnp.full((k,), 15.0, jnp.float32)
    b = jnp.asarray((rng.random(k) < 0.7).astype(np.float32))
    c1, c0 = 8.0, 1e-4

    beta_ref, obj_ref = waterfill_beta_jnp(rho, theta, p_max, b, c1, c0)

    smap = jax.jit(shard_map(
        lambda r, t, p, m: waterfill_beta_jnp(r, t, p, m, c1, c0,
                                              axis_name="data"),
        client_mesh_8,
        in_specs=(P("data"), P("data"), P("data"), P("data")),
        out_specs=(P("data"), P())))
    beta_sh, obj_sh = smap(rho, theta, p_max, b)

    # near the optimum the P2 objective is flat in tau, so the refined tau
    # (and thus beta) is only determined to ~sqrt(eps_f32) under a changed
    # reduction order; the objective itself pins much tighter
    np.testing.assert_allclose(np.asarray(beta_sh), np.asarray(beta_ref),
                               atol=2e-3)
    assert float(obj_sh) == pytest.approx(float(obj_ref), rel=1e-5)
