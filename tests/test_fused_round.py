"""Fused on-device PAOTA round: a single jitted lax.scan must reproduce
the host-path PAOTAServer (run in its counter-RNG reference mode) round
for round, and the scan must execute 20+ rounds in one device call."""
import jax
import numpy as np
import pytest

from repro.core import ChannelConfig, SchedulerConfig
from repro.data.partition import partition_noniid
from repro.data.pipeline import build_federation
from repro.data.synthetic import make_mnist_like
from repro.fl import (FLClient, FusedPAOTA, LegacyEngine, PAOTAConfig,
                      PAOTAServer)
from repro.models.mlp import init_mlp_params, mlp_loss

K = 8


@pytest.fixture(scope="module")
def data():
    x, y, _, _ = make_mnist_like(n_train=2000, n_test=10)
    parts = partition_noniid(y, n_clients=K, seed=0)
    return x, y, parts


def _clients(data):
    x, y, parts = data
    return [FLClient(d, mlp_loss, batch_size=32, lr=0.1, local_steps=5)
            for d in build_federation(x, y, parts)]


def _params():
    return init_mlp_params(jax.random.PRNGKey(0))


def _fused(data, **sched_kw):
    return FusedPAOTA(_params(), _clients(data), ChannelConfig(),
                      SchedulerConfig(n_clients=K, seed=1, **sched_kw),
                      PAOTAConfig())


def test_fused_matches_host_reference_over_rounds(data):
    """Acceptance: fused scan allclose-equivalent to the host-path
    PAOTAServer over >= 4 rounds at equal seeds (host in counter-RNG mode
    with the same jnp water-filling solver — identical draws, identical
    math, different orchestration)."""
    host = PAOTAServer(_params(), _clients(data), ChannelConfig(),
                       SchedulerConfig(n_clients=K, seed=1, rng="counter"),
                       PAOTAConfig(rng="counter", solver="waterfill_jnp"))
    fused = _fused(data)
    for _ in range(5):
        ih, if_ = host.round(), fused.round()
        assert ih["n_participants"] == if_["n_participants"]
        assert ih["time"] == if_["time"]
        assert ih["varsigma"] == pytest.approx(if_["varsigma"], rel=1e-5)
        np.testing.assert_allclose(host.global_vec, fused.global_vec,
                                   rtol=1e-4, atol=1e-5)


def test_fused_scan_20_rounds_single_call(data):
    """Acceptance: one lax.scan covers >= 20 rounds with zero host
    round-trips inside; chunking the same 20 rounds into two scans lands
    on the same trajectory (counter RNG is position-, not call-, based)."""
    one_shot = _fused(data)
    rows = one_shot.advance(20)
    assert len(rows) == 20
    assert [r["round"] for r in rows] == list(range(20))
    assert np.isfinite(one_shot.global_vec).all()
    assert rows[-1]["time"] == pytest.approx(20 * 8.0)
    assert any(r["n_participants"] > 0 for r in rows)
    assert any(r["mean_staleness"] > 0 for r in rows)   # semi-async state

    chunked = _fused(data)
    chunked.advance(12)
    chunked.advance(8)
    np.testing.assert_allclose(one_shot.global_vec, chunked.global_vec,
                               rtol=1e-5, atol=1e-6)


def test_fused_zero_uploader_rounds_hold_global(data):
    """Regression (fused path): periods where no client finished must leave
    w_g bit-identical, then training resumes once uploads arrive."""
    fused = _fused(data, delta_t=8.0, lat_lo=30.0, lat_hi=40.0)
    g0 = fused.global_vec.copy()
    rows = fused.advance(3)             # t in {8,16,24} < lat_lo: nobody done
    assert all(r["n_participants"] == 0 for r in rows)
    assert all(r["varsigma"] == 0.0 for r in rows)
    np.testing.assert_array_equal(fused.global_vec, g0)
    rows = fused.advance(3)             # t up to 48 >= lat_hi: uploads land
    assert any(r["n_participants"] > 0 for r in rows)
    assert not np.array_equal(fused.global_vec, g0)


def test_fused_requires_batched_engine(data):
    with pytest.raises(ValueError):
        FusedPAOTA(_params(), LegacyEngine(_clients(data)), ChannelConfig(),
                   SchedulerConfig(n_clients=K, seed=1), PAOTAConfig())


def test_host_counter_mode_guards():
    """Counter RNG mode must be wired consistently or refused."""
    x, y, _, _ = make_mnist_like(n_train=600, n_test=10)
    parts = partition_noniid(y, n_clients=3, seed=0)
    clients = [FLClient(d, mlp_loss, batch_size=32, lr=0.1, local_steps=2)
               for d in build_federation(x, y, parts)]
    with pytest.raises(ValueError):     # scheduler left in host mode
        PAOTAServer(_params(), clients, ChannelConfig(),
                    SchedulerConfig(n_clients=3, seed=1),
                    PAOTAConfig(rng="counter"))
    with pytest.raises(ValueError):     # legacy engine has no counter plans
        PAOTAServer(_params(), clients, ChannelConfig(),
                    SchedulerConfig(n_clients=3, seed=1, rng="counter"),
                    PAOTAConfig(rng="counter", engine="legacy"))
