"""Per-kernel validation: shape/dtype sweeps, assert_allclose against the
ref.py pure-jnp oracles (kernels run in interpret mode on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.aircomp_sum import aircomp_sum_pallas
from repro.kernels.cosine_sim import cosine_partials_pallas
from repro.kernels.swa_attention import swa_attention_pallas

RNG = np.random.default_rng(42)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("k,d", [(4, 64), (37, 1111), (100, 8070), (1, 513)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_aircomp_sum_sweep(k, d, dtype):
    x = jnp.asarray(RNG.normal(size=(k, d)), dtype)
    bp = jnp.asarray(RNG.random(k), jnp.float32)
    n = jnp.asarray(RNG.normal(size=d), dtype)
    got = aircomp_sum_pallas(x, bp, n, interpret=True)
    want = ref.aircomp_sum_ref(x, bp, n)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_aircomp_sum_bf16_payload_f32_aggregate():
    """Regression: a bf16 payload must come back as an f32 aggregate with
    the AWGN joining the f32 accumulator UN-rounded. The kernel wrapper
    used to cast the noise to the payload dtype and emit the aggregate in
    it, so a bf16 carry re-rounded the received y (the global update plane)
    to 8 mantissa bits every round."""
    k, d = 24, 1111
    x32 = jnp.asarray(RNG.normal(size=(k, d)), jnp.float32)
    x = x32.astype(jnp.bfloat16)
    bp = jnp.asarray(RNG.random(k), jnp.float32)
    n = jnp.asarray(RNG.normal(size=d), jnp.float32)
    got = aircomp_sum_pallas(x, bp, n, interpret=True)
    assert got.dtype == jnp.float32
    # oracle on the SAME rounded payload but full-precision noise path: the
    # only error left is the bf16 storage rounding of x, not of the output
    want = ref.aircomp_sum_ref(x.astype(jnp.float32), bp, n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_aircomp_sum_masked_clients_ignored():
    x = jnp.asarray(RNG.normal(size=(8, 256)), jnp.float32)
    bp = jnp.asarray([1.0, 0, 2.0, 0, 0, 0.5, 0, 0], jnp.float32)
    n = jnp.zeros(256, jnp.float32)
    got = aircomp_sum_pallas(x, bp, n, interpret=True)
    want = (1.0 * x[0] + 2.0 * x[2] + 0.5 * x[5]) / 3.5
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-6,
                               atol=2e-6)


@pytest.mark.parametrize("k,d", [(3, 128), (50, 2048), (100, 8070)])
@pytest.mark.parametrize("block_d", [128, 512])
def test_cosine_partials_sweep(k, d, block_d):
    x = jnp.asarray(RNG.normal(size=(k, d)), jnp.float32)
    g = jnp.asarray(RNG.normal(size=d), jnp.float32)
    got = cosine_partials_pallas(x, g, block_d=block_d, interpret=True)
    want = ref.cosine_partials_ref(x, g)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-4)


@pytest.mark.parametrize("t,s,d,window,causal,bq,bk", [
    (128, 128, 64, None, True, 64, 64),
    (200, 200, 32, 64, True, 64, 64),
    (256, 256, 64, 96, True, 128, 64),
    (256, 256, 128, 128, True, 128, 128),
    (64, 64, 16, None, False, 32, 32),     # encoder (bidirectional)
    (96, 96, 64, 32, True, 32, 32),
    (130, 130, 64, 64, True, 64, 64),      # non-multiple seq (padding path)
])
def test_swa_attention_sweep(t, s, d, window, causal, bq, bk):
    q = jnp.asarray(RNG.normal(size=(3, t, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(3, s, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(3, s, d)), jnp.float32)
    got = swa_attention_pallas(q, k, v, window=window, causal=causal,
                               block_q=bq, block_k=bk, interpret=True)
    want = ref.swa_attention_ref(q, k, v, window=window, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("dtype", [jnp.bfloat16])
def test_swa_attention_bf16(dtype):
    q = jnp.asarray(RNG.normal(size=(2, 128, 64)), dtype)
    k = jnp.asarray(RNG.normal(size=(2, 128, 64)), dtype)
    v = jnp.asarray(RNG.normal(size=(2, 128, 64)), dtype)
    got = swa_attention_pallas(q, k, v, window=64, block_q=64, block_k=64,
                               interpret=True)
    want = ref.swa_attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                                 v.astype(jnp.float32), window=64)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=3e-2,
                               atol=3e-2)


def test_swa_window_exploits_structure():
    """The windowed kernel must visit O(window/block) kv stripes per query
    block, not O(T/block) — check the grid arithmetic (perf contract)."""
    window, bq, bk, t = 96, 64, 64, 4096
    n_j = (window + bq) // bk + 1
    assert n_j == 3
    assert n_j < t // bk  # much fewer stripes than full attention


def test_model_attention_matches_kernel():
    """GQA path in models.layers vs the Pallas kernel wrapper."""
    from repro.kernels.ops import swa_attention
    from repro.models import layers as L
    from repro.configs import get_reduced
    cfg = get_reduced("smollm-135m")
    rng = np.random.default_rng(0)
    b, t = 2, 96
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.asarray(rng.normal(size=(b, t, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, t, hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, t, hkv, hd)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    mask = L.causal_window_mask(pos, pos, None)[:, None, None]
    want = L._attend(q, k, v, mask, cfg)
    got = swa_attention(q, k, v, window=None, causal=True,
                        block_q=32, block_k=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# Mamba2 SSD intra-chunk kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("g,q,n,d", [(4, 32, 16, 32), (8, 64, 128, 64),
                                     (2, 256, 64, 64), (3, 128, 64, 32)])
def test_ssd_intra_chunk_sweep(g, q, n, d):
    from repro.kernels.ref import ssd_intra_chunk_ref
    from repro.kernels.ssd_chunk import ssd_intra_chunk_pallas
    rng = np.random.default_rng(g + q)
    cum = -jnp.asarray(np.cumsum(0.05 + 0.2 * rng.random((g, q)),
                                 axis=1).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(g, q, n)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(g, q, n)), jnp.float32)
    xdt = jnp.asarray(rng.normal(size=(g, q, d)), jnp.float32)
    got = ssd_intra_chunk_pallas(cum, b, c, xdt, interpret=True)
    want = ssd_intra_chunk_ref(cum, b, c, xdt)
    for a, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(w),
                                   rtol=2e-5, atol=2e-5)


def test_ssd_chunked_kernel_backend_matches_jnp():
    """Full SSD forward with the Pallas intra-chunk backend must equal the
    pure-jnp path (and therefore the naive recurrence)."""
    import dataclasses
    from repro.configs import get_reduced
    from repro.models.ssm import ssd_chunked
    cfg = dataclasses.replace(get_reduced("mamba2-370m"), ssm_chunk=16)
    rng = np.random.default_rng(5)
    bz, t, h, p, g, n = 2, 49, 4, 8, 1, 16
    x = jnp.asarray(rng.normal(size=(bz, t, h, p)), jnp.float32)
    dt = jnp.asarray(0.1 + 0.5 * rng.random((bz, t, h)), jnp.float32)
    a = -jnp.asarray(0.5 + rng.random(h), jnp.float32)
    B = jnp.asarray(rng.normal(size=(bz, t, g, n)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(bz, t, g, n)), jnp.float32)
    y0, s0 = ssd_chunked(x, dt, a, B, C, cfg, use_kernel=False)
    y1, s1 = ssd_chunked(x, dt, a, B, C, cfg, use_kernel=True)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1),
                               rtol=2e-5, atol=2e-5)
