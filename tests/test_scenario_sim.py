"""Vectorized client-state scenario simulator (availability cycles,
dropouts, responsiveness models, hyperparameter heterogeneity).

The load-bearing claims:

* the host scheduler and a pure-jnp replica of the fused scan's state
  transition consume IDENTICAL counter-RNG draws — the per-round
  availability/dropout masks and the uploader/restart sets are equal
  draw for draw at every round (satellite of the active-cohort PR);
* the DEFAULT ``ScenarioConfig()`` is the identity scenario: running the
  fused driver with it is bit-identical to ``scenario=None``;
* heterogeneity is exact, not approximate: a client capped at n local
  steps matches the n-step-truncated plan run, and a cyclic small-batch
  plan reproduces the b_k-minibatch gradient when b_k divides B.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp_compat import given, settings, st

from repro.core import (ChannelConfig, ScenarioConfig, SchedulerConfig,
                        scenario_hyperparams, scenario_latencies,
                        scenario_masks)
from repro.core.scheduler import (SemiAsyncScheduler, sched_advance,
                                  sched_broadcast)
from repro.data.partition import partition_noniid
from repro.data.pipeline import build_federation, counter_batch_plan
from repro.data.synthetic import make_mnist_like
from repro.fl import FLClient, FusedPAOTA, PAOTAConfig
from repro.models.mlp import init_mlp_params, mlp_loss

K = 8

SCENARIO = ScenarioConfig(availability="cycle", avail_period=4,
                          avail_duty=0.5, dropout_prob=0.25,
                          responsiveness="lognormal")


@pytest.fixture(scope="module")
def world():
    x, y, _, _ = make_mnist_like(n_train=1500, n_test=10)
    parts = partition_noniid(y, n_clients=K, seed=0)
    return x, y, parts


def _clients(world, **kw):
    x, y, parts = world
    kw = dict(batch_size=32, lr=0.1, local_steps=5) | kw
    return [FLClient(d, mlp_loss, **kw) for d in build_federation(x, y, parts)]


def _fused(world, **kw):
    return FusedPAOTA(init_mlp_params(jax.random.PRNGKey(0)),
                      _clients(world), ChannelConfig(),
                      SchedulerConfig(n_clients=K, seed=1),
                      PAOTAConfig(), **kw)


# ---------------------------------------------------------------------------
# host scheduler == jnp state transition, draw for draw
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scenario", [
    SCENARIO,
    ScenarioConfig(availability="bernoulli", avail_prob=0.6,
                   dropout_prob=0.1),
])
def test_host_and_jnp_simulators_draw_identical_masks(scenario):
    """The host ``SemiAsyncScheduler(scenario=...)`` and a pure-jnp replica
    of the fused scan's transition (``sched_advance`` + ``scenario_masks``
    + ``sched_broadcast``) produce bit-identical upload/restart masks and
    scheduler state at EVERY round — they key the same counter streams."""
    cfg = SchedulerConfig(n_clients=K, seed=3, delta_t=8.0, rng="counter")
    sch = SemiAsyncScheduler(cfg, scenario=scenario)
    key = jax.random.PRNGKey(cfg.seed)

    # jnp replica of the carry state (mirrors the fused round's fields)
    ready = jnp.zeros((K,), bool)
    busy = jnp.zeros((K,), jnp.float32)
    model_round = jnp.zeros((K,), jnp.int32)

    # round-0 broadcast to everyone (the servers' __init__ contract)
    sch.start_round(np.arange(K))
    lat = scenario_latencies(key, 0, K, cfg.lat_lo, cfg.lat_hi, scenario)
    ready, busy, model_round = sched_broadcast(
        ready, busy, model_round, jnp.ones((K,), bool), lat, jnp.int32(0))
    np.testing.assert_array_equal(np.asarray(busy), sch.busy_lat)

    for r in range(12):
        uploaders, _ = sch.advance_to_aggregation()
        ready, _ = sched_advance(ready, busy, model_round, jnp.int32(r),
                                 cfg.delta_t)
        avail, drop = scenario_masks(key, r, K, scenario)
        upl = ready & avail & ~drop
        restart = ready & avail
        np.testing.assert_array_equal(np.flatnonzero(np.asarray(upl)),
                                      uploaders)
        np.testing.assert_array_equal(np.flatnonzero(np.asarray(restart)),
                                      sch.restart_ids)
        sch.start_round(sch.restart_ids)
        lat = scenario_latencies(key, r + 1, K, cfg.lat_lo, cfg.lat_hi,
                                 scenario)
        ready, busy, model_round = sched_broadcast(
            ready, busy, model_round, restart, lat, jnp.int32(r + 1))
        np.testing.assert_array_equal(np.asarray(ready), sch.ready)
        np.testing.assert_array_equal(np.asarray(busy), sch.busy_lat)
        np.testing.assert_array_equal(np.asarray(model_round),
                                      sch.model_round)


def test_scenario_requires_counter_rng():
    with pytest.raises(ValueError, match="counter"):
        SemiAsyncScheduler(SchedulerConfig(n_clients=4, rng="host"),
                           scenario=SCENARIO)


def test_scenario_config_validation():
    with pytest.raises(ValueError):
        ScenarioConfig(availability="sometimes")
    with pytest.raises(ValueError):
        ScenarioConfig(responsiveness="gamma")
    with pytest.raises(ValueError):
        ScenarioConfig(dropout_prob=1.0)
    assert not ScenarioConfig().has_masks
    assert ScenarioConfig(dropout_prob=0.1).has_masks
    assert ScenarioConfig(availability="cycle").has_masks


# ---------------------------------------------------------------------------
# identity scenario == no scenario, bit for bit
# ---------------------------------------------------------------------------

def test_default_scenario_is_identity_bitwise(world):
    plain = _fused(world)
    ident = _fused(world, scenario=ScenarioConfig())
    plain.advance(5)
    ident.advance(5)
    np.testing.assert_array_equal(plain.global_vec, ident.global_vec)
    assert [r["n_participants"] for r in plain.history] == \
        [r["n_participants"] for r in ident.history]


def test_masking_scenario_changes_participation(world):
    """A masking scenario must actually gate uploads: fewer cumulative
    participants than the unmasked run, global still finite and sane."""
    plain = _fused(world)
    masked = _fused(world, scenario=ScenarioConfig(
        availability="cycle", avail_period=4, avail_duty=0.5))
    hp = plain.advance(8)
    hm = masked.advance(8)
    assert sum(r["n_participants"] for r in hm) < \
        sum(r["n_participants"] for r in hp)
    assert np.isfinite(masked.global_vec).all()


# ---------------------------------------------------------------------------
# responsiveness models
# ---------------------------------------------------------------------------

def test_uniform_responsiveness_is_counter_latencies_bitwise():
    from repro.core.scheduler import counter_latencies
    key = jax.random.PRNGKey(7)
    sc = ScenarioConfig()      # responsiveness="uniform"
    for r in range(3):
        np.testing.assert_array_equal(
            np.asarray(scenario_latencies(key, r, 32, 5.0, 15.0, sc)),
            np.asarray(counter_latencies(key, r, 32, 5.0, 15.0)))


def test_lognormal_latencies_shape_and_location():
    key = jax.random.PRNGKey(7)
    sc = ScenarioConfig(responsiveness="lognormal", lat_shift=2.0,
                        lat_sigma=0.3, lat_mu_spread=0.5)
    draws = np.stack([np.asarray(scenario_latencies(key, r, 256, 5.0, 15.0,
                                                    sc))
                      for r in range(64)])
    assert np.isfinite(draws).all()
    assert (draws > sc.lat_shift).all()
    # per-client medians spread around the (lo+hi)/2 target (mu_k traits)
    med = np.median(draws, axis=0)
    assert 5.0 < np.median(med) < 15.0
    assert med.std() > 0.5    # heterogeneous device classes, not one speed


# ---------------------------------------------------------------------------
# hyperparameter heterogeneity: exact, not approximate
# ---------------------------------------------------------------------------

def test_het_steps_equals_truncated_plan(world):
    """A client capped at n local steps produces EXACTLY the params of
    running the first n rows of its minibatch plan — the masked-lr scan
    is a bit-exact truncation, not a re-draw."""
    from repro.fl.engine import BatchedEngine
    x, y, parts = world
    eng = BatchedEngine(build_federation(x, y, parts), mlp_loss,
                        batch_size=16, lr=0.1, local_steps=5)
    eng.enable_counter_plan(jax.random.PRNGKey(2))
    params = init_mlp_params(jax.random.PRNGKey(0))
    plan = eng.round_plan(0)
    full = eng._train_one(params, eng._x[0], eng._y[0], plan[0],
                          n_steps=jnp.int32(2))
    trunc = eng._train_one(params, eng._x[0], eng._y[0], plan[0, :2])
    for a, b in zip(jax.tree_util.tree_leaves(full),
                    jax.tree_util.tree_leaves(trunc)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 8), st.integers(0, 10_000))
def test_cyclic_batch_plan_property(bk, seed):
    """Property: with per-client batch size b_k, column j of the plan is
    draw j mod b_k of the homogeneous plan — and b_k = B reproduces the
    homogeneous plan bit for bit."""
    key = jax.random.fold_in(jax.random.PRNGKey(11), seed)
    n_samples = np.array([37, 52, 64])
    base = np.asarray(counter_batch_plan(key, n_samples, 3, 8))
    bks = np.array([bk, 8, max(1, bk // 2)])
    het = np.asarray(counter_batch_plan(key, n_samples, 3, 8,
                                        batch_sizes=bks))
    cols = np.arange(8)
    for k in range(3):
        np.testing.assert_array_equal(het[k], base[k][:, cols % bks[k]])
    np.testing.assert_array_equal(het[1], base[1])


def test_scenario_hyperparams_draws_from_choices():
    key = jax.random.PRNGKey(5)
    sc = ScenarioConfig(het_steps=(1, 3, 5), het_batch=(8, 16))
    steps_k, batch_k = scenario_hyperparams(key, 64, sc)
    assert set(np.asarray(steps_k)) <= {1, 3, 5}
    assert set(np.asarray(batch_k)) <= {8, 16}
    none_s, none_b = scenario_hyperparams(key, 64, ScenarioConfig())
    assert none_s is None and none_b is None


def test_het_end_to_end_fused(world):
    """Full fused run under hyperparameter heterogeneity: converging,
    finite, and actually different from the homogeneous trajectory."""
    het = _fused(world, scenario=ScenarioConfig(het_steps=(2, 5),
                                                het_batch=(16, 32)))
    hom = _fused(world)
    het.advance(5)
    hom.advance(5)
    assert np.isfinite(het.global_vec).all()
    assert not np.array_equal(het.global_vec, hom.global_vec)
