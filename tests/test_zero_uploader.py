"""Zero-uploader regression (host path) + power-constraint (7) property.

The pre-fix behaviour: a period with no finished client (b.sum() == 0,
routine at small K or lat_lo >> delta_t) ran AirComp on an all-zero mask,
dividing pure AWGN by the 1e-12 normalizer clamp and overwriting the
global model with ~1e12-amplified noise. The fixed server holds the global
bit-identical, reports varsigma = 0.0, and resumes once uploads arrive.
"""
import jax
import numpy as np
import pytest
from _hyp_compat import given, settings, st

from repro.core import ChannelConfig, SchedulerConfig
from repro.core.aircomp import effective_power_cap
from repro.data.partition import partition_noniid
from repro.data.pipeline import build_federation
from repro.data.synthetic import make_mnist_like
from repro.fl import FLClient, PAOTAConfig, PAOTAServer
from repro.models.mlp import init_mlp_params, mlp_loss

K = 6

# all latencies far beyond the aggregation period: the first several
# periods are guaranteed zero-uploader rounds
STRAGGLER_SCHED = dict(n_clients=K, delta_t=1.0, lat_lo=50.0, lat_hi=60.0)


@pytest.fixture(scope="module")
def world():
    x, y, _, _ = make_mnist_like(n_train=1500, n_test=10)
    parts = partition_noniid(y, n_clients=K, seed=0)
    return x, y, parts


def _server(world, transmit, engine="batched", **sched_kw):
    x, y, parts = world
    clients = [FLClient(d, mlp_loss, batch_size=32, lr=0.1, local_steps=5)
               for d in build_federation(x, y, parts)]
    return PAOTAServer(init_mlp_params(jax.random.PRNGKey(0)), clients,
                       ChannelConfig(),
                       SchedulerConfig(seed=1, **sched_kw),
                       PAOTAConfig(transmit=transmit, engine=engine))


@pytest.mark.parametrize("transmit", ["model", "delta"])
def test_zero_uploader_round_holds_global_bit_identical(world, transmit):
    srv = _server(world, transmit, **STRAGGLER_SCHED)
    g0 = srv.global_vec.copy()
    p0 = srv.prev_global.copy()
    for _ in range(3):
        info = srv.round()
        assert info["n_participants"] == 0
        assert info["varsigma"] == 0.0
        assert info["p2_objective"] == float("inf")
    np.testing.assert_array_equal(srv.global_vec, g0)
    np.testing.assert_array_equal(srv.prev_global, p0)
    assert np.isfinite(srv.global_vec).all()


def test_training_resumes_after_zero_uploader_gap(world):
    """After the stragglers finally finish, aggregation must pick up with
    finite values (the pre-fix server had already destroyed w_g by then)."""
    srv = _server(world, "model", delta_t=8.0, n_clients=K,
                  lat_lo=30.0, lat_hi=40.0)
    g0 = srv.global_vec.copy()
    infos = [srv.round() for _ in range(6)]   # t=8..48; uploads from t=32
    assert any(i["n_participants"] == 0 for i in infos)
    assert any(i["n_participants"] > 0 for i in infos)
    assert not np.array_equal(srv.global_vec, g0)
    assert np.isfinite(srv.global_vec).all()
    # the recovered model is a sane aggregate, not amplified noise
    assert float(np.abs(srv.global_vec).max()) < 1e3


def test_zero_uploader_legacy_engine(world):
    """The guard is engine-independent (legacy per-client loop path)."""
    srv = _server(world, "model", engine="legacy", **STRAGGLER_SCHED)
    g0 = srv.global_vec.copy()
    info = srv.round()
    assert info["n_participants"] == 0 and info["varsigma"] == 0.0
    np.testing.assert_array_equal(srv.global_vec, g0)


@pytest.mark.multidevice
@pytest.mark.parametrize("transmit", ["model", "delta"])
def test_zero_uploader_sharded_round_holds_global(world, transmit):
    """The guard survives shard_map: a zero-uploader period on a
    multi-device mesh (every shard's psum sees an all-zero mask) holds w_g
    bit-identical on every shard and resumes cleanly once uploads land."""
    from conftest import require_host_devices
    from repro.fl import ShardedPAOTA
    require_host_devices(2)     # K=6 shards over a (2, 1) client mesh
    x, y, parts = world
    clients = [FLClient(d, mlp_loss, batch_size=32, lr=0.1, local_steps=5)
               for d in build_federation(x, y, parts)]
    from repro.launch.mesh import make_cpu_mesh
    srv = ShardedPAOTA(init_mlp_params(jax.random.PRNGKey(0)), clients,
                       ChannelConfig(),
                       SchedulerConfig(seed=1, delta_t=8.0, n_clients=K,
                                       lat_lo=30.0, lat_hi=40.0),
                       PAOTAConfig(transmit=transmit),
                       mesh=make_cpu_mesh(data=2, model=1))
    g0 = srv.global_vec.copy()
    rows = srv.advance(3)                # t in {8,16,24} < lat_lo
    assert all(r["n_participants"] == 0 for r in rows)
    assert all(r["varsigma"] == 0.0 for r in rows)
    np.testing.assert_array_equal(srv.global_vec, g0)
    rows = srv.advance(3)                # t up to 48 >= lat_hi
    assert any(r["n_participants"] > 0 for r in rows)
    assert not np.array_equal(srv.global_vec, g0)
    assert np.isfinite(srv.global_vec).all()


@pytest.mark.parametrize("transmit", ["model", "delta"])
def test_zero_uploader_cohort_round_holds_global(world, transmit):
    """Active-cohort twin of the guard: a cohort whose slots never become
    ready (straggler latencies), and then an all-phantom cohort (every
    slot dead, m_eff = 0), both hold w_g bit-identically and report
    varsigma = 0.0 — the all-masked superposition hits the exact same
    normalizer clamp the dense path guards."""
    import jax.numpy as jnp

    from repro.fl import FusedPAOTA
    x, y, parts = world
    clients = [FLClient(d, mlp_loss, batch_size=32, lr=0.1, local_steps=5)
               for d in build_federation(x, y, parts)]
    srv = FusedPAOTA(init_mlp_params(jax.random.PRNGKey(0)), clients,
                     ChannelConfig(),
                     SchedulerConfig(seed=1, **STRAGGLER_SCHED),
                     PAOTAConfig(transmit=transmit), cohort_size=3)
    g0 = srv.global_vec.copy()
    rows = srv.advance(3)             # t in {1,2,3} << lat_lo: nobody ready
    assert all(r["n_participants"] == 0 for r in rows)
    assert all(r["varsigma"] == 0.0 for r in rows)
    np.testing.assert_array_equal(srv.global_vec, g0)
    # kill every slot: the m_eff = 0 step must also hold bit-identically
    srv._carry = srv._carry._replace(
        slot_live=jnp.zeros_like(srv._carry.slot_live))
    rows = srv.advance(2)
    assert all(r["n_participants"] == 0 for r in rows)
    assert all(r["varsigma"] == 0.0 for r in rows)
    np.testing.assert_array_equal(srv.global_vec, g0)
    assert np.isfinite(srv.global_vec).all()


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 64), st.integers(0, 100_000))
def test_capped_powers_satisfy_constraint_7(k, seed):
    """Property: after the cap, every client satisfies the instantaneous
    power constraint (7): p_k <= |h_k| sqrt(P_max / ||w_k||^2), i.e. the
    precoded transmit energy p_k^2 ||w_k||^2 / |h_k|^2 never exceeds
    P_max — across random channel and payload draws."""
    rng = np.random.default_rng(seed)
    p_max = 15.0
    payload = rng.normal(scale=rng.uniform(0.01, 30.0),
                         size=(k, 32)).astype(np.float32)
    h = rng.rayleigh(scale=rng.uniform(0.1, 2.0), size=k).astype(np.float32)
    powers = rng.uniform(0.0, p_max, size=k).astype(np.float32)
    w_norm2 = np.sum(payload.astype(np.float64) ** 2, axis=1)
    cap = np.asarray(effective_power_cap(w_norm2, h, p_max))
    capped = np.minimum(powers, cap)
    energy = capped ** 2 * w_norm2 / np.maximum(h, 1e-30) ** 2
    assert np.all(capped <= h * np.sqrt(p_max / np.maximum(w_norm2, 1e-12))
                  * (1 + 1e-5))
    assert np.all(energy <= p_max * (1 + 1e-4))
