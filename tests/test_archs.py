"""Per-architecture smoke tests (deliverable f): each assigned arch is
instantiated in its REDUCED variant (<=2 layers, d_model<=128, <=4 experts)
and runs one forward + one SGD train step on CPU, asserting output shapes
and absence of NaNs. Decode paths are checked for parity with the full
forward (teacher-forced token-by-token)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.launch.shapes import SHAPES, make_batch
from repro.models import (decode_step, forward, init_decode_state, init_model,
                          loss_fn, param_count)

pytestmark = pytest.mark.slow  # arch-zoo/serving/integration tier (scripts/ci.sh)

ALL = list(ARCH_IDS)


@pytest.mark.parametrize("arch", ALL)
def test_full_config_exact_spec(arch):
    cfg = get_config(arch)
    spec = {
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "smollm-135m": (30, 576, 9, 3, 1536, 49152),
        "mamba2-370m": (48, 1024, 0, 0, 0, 50280),
        "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
        "minicpm-2b": (40, 2304, 36, 36, 5760, 122753),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "granite-3-8b": (40, 4096, 32, 8, 12800, 49155),
    }[arch]
    assert (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
            cfg.d_ff, cfg.vocab_size) == spec
    assert cfg.source, "config must cite its source"


@pytest.mark.parametrize("arch", ALL)
def test_smoke_forward_and_train_step(arch):
    cfg = get_reduced(arch)
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    if cfg.num_experts:
        assert cfg.num_experts <= 4
    params = init_model(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, SHAPES["train_4k"], batch_override=2, seq_override=32)

    loss, metrics = loss_fn(params, batch, cfg)
    assert loss.shape == ()
    assert jnp.isfinite(loss)

    grads = jax.grad(lambda p: loss_fn(p, batch, cfg)[0])(params)
    flat = jax.tree_util.tree_leaves(grads)
    assert all(jnp.all(jnp.isfinite(g)) for g in flat)
    new_params = jax.tree_util.tree_map(lambda p, g: p - 0.1 * g, params, grads)
    loss2, _ = loss_fn(new_params, batch, cfg)
    assert jnp.isfinite(loss2)


@pytest.mark.parametrize("arch", ALL)
def test_smoke_decode_step(arch):
    cfg = get_reduced(arch)
    if not cfg.supports_decode:
        pytest.skip("encoder-only: no decode step (DESIGN.md §4)")
    params = init_model(jax.random.PRNGKey(0), cfg)
    state = init_decode_state(cfg, 2, 64)
    logits, new_state = decode_step(
        params, jnp.zeros((2, 1), jnp.int32), state, jnp.int32(3), cfg)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits))
    jax.tree_util.tree_map(lambda a, b: None, state, new_state)  # same treedef


@pytest.mark.parametrize("arch",
                         ["smollm-135m", "mamba2-370m", "zamba2-7b",
                          "mixtral-8x22b", "olmo-1b", "internvl2-1b"])
def test_decode_matches_forward(arch):
    """Teacher-forced token-by-token decode must reproduce the full forward
    logits (MoE: dropless capacity so routing is identical)."""
    rng = np.random.default_rng(1)
    cfg = get_reduced(arch)
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = init_model(jax.random.PRNGKey(0), cfg)
    t = 17
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, t)).astype(np.int32))
    if cfg.modality == "vision_text":
        patches = jnp.asarray(rng.normal(
            size=(2, cfg.num_patches, cfg.frontend_dim)).astype(np.float32))
        full, _, _ = forward(params, {"tokens": toks, "patch_embeds": patches}, cfg)
        pytest.skip("vlm decode requires prefilled patch cache; covered in "
                    "test_serving integration")
    full, _, _ = forward(params, {"tokens": toks}, cfg)
    state = init_decode_state(cfg, 2, 64)
    outs = []
    for i in range(t):
        lg, state = decode_step(params, toks[:, i:i + 1], state, jnp.int32(i), cfg)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               atol=2e-3, rtol=2e-3)


def test_sliding_window_ring_buffer_matches_full_history():
    """Decode with a ring buffer of size W must equal attention over the
    last W tokens of an unbounded cache."""
    rng = np.random.default_rng(2)
    cfg = dataclasses.replace(get_reduced("smollm-135m"), sliding_window=8)
    params = init_model(jax.random.PRNGKey(0), cfg)
    t = 25
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, t)).astype(np.int32))
    full, _, _ = forward(params, {"tokens": toks}, cfg)  # windowed full forward
    state = init_decode_state(cfg, 1, 64)
    assert state["k"].shape[2] == 8  # ring buffer is window-sized
    outs = []
    for i in range(t):
        lg, state = decode_step(params, toks[:, i:i + 1], state, jnp.int32(i), cfg)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               atol=2e-3, rtol=2e-3)


def test_ssd_chunked_matches_naive_recurrence():
    rng = np.random.default_rng(1)
    cfg = dataclasses.replace(get_reduced("mamba2-370m"), ssm_chunk=16)
    bz, t, h, p, g, n = 2, 67, 4, 8, 1, 16
    from repro.models.ssm import ssd_chunked
    x = jnp.asarray(rng.normal(size=(bz, t, h, p)).astype(np.float32))
    dt = jnp.asarray((0.1 + 0.5 * rng.random((bz, t, h))).astype(np.float32))
    a = -jnp.asarray((0.5 + rng.random(h)).astype(np.float32))
    B = jnp.asarray(rng.normal(size=(bz, t, g, n)).astype(np.float32))
    C = jnp.asarray(rng.normal(size=(bz, t, g, n)).astype(np.float32))
    y, fs = ssd_chunked(x, dt, a, B, C, cfg)

    Bh = jnp.repeat(B, h // g, axis=2)
    Ch = jnp.repeat(C, h // g, axis=2)
    S = jnp.zeros((bz, h, p, n))
    ys = []
    for i in range(t):
        decay = jnp.exp(dt[:, i] * a[None, :])
        S = S * decay[:, :, None, None] + jnp.einsum(
            "bhn,bhp->bhpn", Bh[:, i], x[:, i] * dt[:, i][..., None])
        ys.append(jnp.einsum("bhn,bhpn->bhp", Ch[:, i], S))
    yn = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yn), atol=3e-4, rtol=3e-4)
    np.testing.assert_allclose(np.asarray(fs), np.asarray(S), atol=3e-4, rtol=3e-4)


def test_moe_router_weights_simplex():
    from repro.models.moe import router_topk
    cfg = get_reduced("mixtral-8x22b")
    logits = jnp.asarray(np.random.default_rng(0).normal(size=(64, cfg.num_experts)),
                         jnp.float32)
    w, aux = router_topk(logits, cfg)
    np.testing.assert_allclose(np.asarray(jnp.sum(w, -1)), 1.0, atol=1e-5)
    assert (np.asarray((w > 0).sum(-1)) == cfg.experts_per_token).all()
    assert float(aux) >= 1.0 - 1e-5  # E * sum f*p >= 1 by Cauchy-Schwarz
