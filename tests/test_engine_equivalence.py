"""Batched-engine equivalence: the vmap/scan engine must reproduce the
legacy per-client loop numerically — same seeds, same minibatch streams,
allclose local models and global trajectories."""
import jax
import numpy as np
import pytest

from repro.core import ChannelConfig, SchedulerConfig
from repro.data.partition import partition_noniid
from repro.data.pipeline import build_federation, stack_federation
from repro.data.synthetic import make_mnist_like
from repro.fl import (BatchedEngine, FLClient, LegacyEngine, PAOTAConfig,
                      PAOTAServer, make_engine)
from repro.models.mlp import init_mlp_params, mlp_loss

K = 8


@pytest.fixture(scope="module")
def data():
    x, y, _, _ = make_mnist_like(n_train=2000, n_test=10)
    parts = partition_noniid(y, n_clients=K, seed=0)
    return x, y, parts


def _clients(data, **kw):
    x, y, parts = data
    fed = build_federation(x, y, parts)
    kw = {"batch_size": 32, "lr": 0.1, "local_steps": 5, **kw}
    return [FLClient(d, mlp_loss, **kw) for d in fed]


def test_federation_is_ragged(data):
    """The parity tests below only mean something if client sizes differ."""
    x, y, parts = data
    stacked = stack_federation(build_federation(x, y, parts))
    assert len(np.unique(stacked.n_samples)) > 1
    assert stacked.x.shape == (K, stacked.n_samples.max(), x.shape[1])
    # padding is zero and the mask marks exactly the real rows
    for k in range(K):
        n_k = stacked.n_samples[k]
        assert stacked.mask[k, :n_k].all() and not stacked.mask[k, n_k:].any()
        assert not stacked.x[k, n_k:].any()


def test_local_train_parity_on_ragged_data(data):
    params = init_mlp_params(jax.random.PRNGKey(0))
    legacy = LegacyEngine(_clients(data))
    batched = BatchedEngine.from_clients(_clients(data))
    ids = np.arange(K)
    np.testing.assert_allclose(legacy.local_train(params, ids),
                               batched.local_train(params, ids),
                               rtol=1e-5, atol=1e-6)


def test_local_train_parity_subset_and_epoch_state(data):
    """Repeated partial broadcasts: only the trained clients' epoch cursors
    advance, and they advance identically in both engines."""
    params = init_mlp_params(jax.random.PRNGKey(1))
    legacy = LegacyEngine(_clients(data))
    batched = BatchedEngine.from_clients(_clients(data))
    for ids in (np.arange(K), np.array([5, 2, 7]), np.array([2, 5]),
                np.arange(K)):
        np.testing.assert_allclose(legacy.local_train(params, ids),
                                   batched.local_train(params, ids),
                                   rtol=1e-5, atol=1e-6)


def test_make_engine_kinds(data):
    clients = _clients(data)
    assert isinstance(make_engine(clients, "batched"), BatchedEngine)
    assert isinstance(make_engine(clients, "legacy"), LegacyEngine)
    eng = BatchedEngine.from_clients(clients)
    assert make_engine(eng, "legacy") is eng   # instances pass through
    with pytest.raises(ValueError):
        make_engine(clients, "fused")


def test_empty_broadcast_returns_zero_by_d(data):
    """Regression: an empty `ids` must return shape (0, d) — not (0, 0) —
    so callers can concatenate/assign without special-casing."""
    params = init_mlp_params(jax.random.PRNGKey(0))
    legacy = LegacyEngine(_clients(data))
    batched = BatchedEngine.from_clients(_clients(data))
    d = batched.local_train(params, np.arange(K)).shape[1]
    empty = np.array([], dtype=np.int64)
    assert legacy.local_train(params, empty).shape == (0, d)
    assert batched.local_train(params, empty).shape == (0, d)
    # concatenation just works
    out = np.concatenate([legacy.local_train(params, empty),
                          legacy.local_train(params, np.array([1]))])
    assert out.shape == (1, d)


def test_counter_plan_mode_trains_and_is_stateless(data):
    """Counter-mode plans are a pure function of (key, round): the same
    round trains identically twice, and epoch cursors never advance."""
    params = init_mlp_params(jax.random.PRNGKey(0))
    eng = BatchedEngine.from_clients(_clients(data))
    eng.enable_counter_plan(jax.random.PRNGKey(7))
    ids = np.arange(K)
    out1 = eng.local_train(params, ids, round_idx=3)
    out2 = eng.local_train(params, ids, round_idx=3)
    np.testing.assert_array_equal(out1, out2)
    assert not np.array_equal(out1, eng.local_train(params, ids, round_idx=4))
    assert all(c._epoch == 0 for c in eng.fed)     # cursors untouched
    with pytest.raises(ValueError):
        eng.local_train(params, ids)               # round index required
    # plans never index past a client's true size (padding untouched)
    idx = np.asarray(eng.round_plan(11))
    assert np.all(idx >= 0)
    assert np.all(idx.max(axis=(1, 2)) < eng.n_samples)


def test_batched_engine_rejects_short_clients(data):
    """n_k < batch_size is a restriction of the HOST epoch-cursor planner
    only: construction succeeds, epoch-cursor plans refuse, and counter
    plans (which wrap short clients cyclically) train fine."""
    clients = _clients(data, batch_size=512)   # > smallest client
    eng = BatchedEngine.from_clients(clients)
    params = init_mlp_params(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="n_k >= batch_size"):
        eng.local_train(params, np.arange(K))
    eng.enable_counter_plan(jax.random.PRNGKey(0))
    out = eng.local_train(params, np.arange(K), round_idx=0)
    assert np.isfinite(np.asarray(out)).all()


def test_paota_server_equivalence_over_rounds(data):
    """Acceptance: batched and legacy engines produce allclose global
    models over >= 3 PAOTA rounds at identical seeds."""
    params = init_mlp_params(jax.random.PRNGKey(0))

    def server(engine):
        return PAOTAServer(params, _clients(data), ChannelConfig(),
                           SchedulerConfig(n_clients=K, seed=1),
                           PAOTAConfig(engine=engine))

    srv_l, srv_b = server("legacy"), server("batched")
    for _ in range(4):
        il, ib = srv_l.round(), srv_b.round()
        assert il["n_participants"] == ib["n_participants"]
        assert il["time"] == ib["time"]
        np.testing.assert_allclose(srv_l.global_vec, srv_b.global_vec,
                                   rtol=1e-4, atol=1e-5)
