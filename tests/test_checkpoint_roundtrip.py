"""Checkpoint io bit-fidelity (ISSUE-10 satellite): save -> load must be
BIT-identical for every dtype a ``RoundCarry`` plane can hold.

Pre-fix, ``np.savez`` silently degraded non-native dtypes — an ml_dtypes
bfloat16 plane came back as a void ``|V2`` array with its type identity
gone, and the old ``np.asarray(template)`` path turned ``jax.eval_shape``
ShapeDtypeStruct templates into garbage object arrays. The rewritten io
stores raw bytes + a dtype/shape index; these tests pin the contract:
exotic dtypes round-trip exactly (compared through integer views, so NaN
payloads and negative-zero bit patterns count too), templates never
materialize, and a layout/dtype mismatch is a loud error, never a cast.
"""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp_compat import given, settings, st

from repro.checkpoint.io import load_checkpoint, save_checkpoint

DTYPES = ["float32", "bfloat16", "int8", "int32", "bool", "float16",
          "uint32"]


def _sample(dtype: str, shape, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    raw = rng.standard_normal(shape).astype(np.float32) * 10.0
    if dtype == "bool":
        return raw > 0
    if dtype in ("int8", "int32", "uint32"):
        return raw.astype(np.dtype(dtype))
    a = raw.astype(jnp.dtype(dtype))       # covers bf16 via ml_dtypes
    if a.size:                             # exercise non-finite payloads
        a.flat[0] = np.float32(np.nan).astype(a.dtype)
    return a


def _bits(a: np.ndarray) -> np.ndarray:
    """Bit-pattern view: exact comparison that treats NaN == NaN and
    distinguishes -0.0 from +0.0."""
    a = np.asarray(a)
    return a.view(np.dtype(f"u{a.dtype.itemsize}"))


def _assert_bit_identical(got, want):
    got, want = np.asarray(got), np.asarray(want)
    assert got.dtype == want.dtype
    assert got.shape == want.shape
    np.testing.assert_array_equal(_bits(got), _bits(want))


@settings(max_examples=25, deadline=None)
@given(st.sampled_from(DTYPES), st.sampled_from(DTYPES),
       st.integers(0, 7), st.integers(1, 5), st.integers(0, 999))
def test_save_load_bit_identity_property(dt_a, dt_b, rows, cols, seed):
    """Any two-plane pytree with any dtype mix (including zero-row planes
    and scalar leaves) survives save -> load bit-for-bit, restored against
    a never-materialized ShapeDtypeStruct template."""
    tree = {"a": _sample(dt_a, (rows, cols), seed),
            "b": _sample(dt_b, (cols,), seed + 1),
            "s": np.int32(seed)}
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "t.npz")
        save_checkpoint(path, tree, step=seed, extra={"tag": "x"})
        template = jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(np.shape(a),
                                           np.asarray(a).dtype), tree)
        out, step, extra = load_checkpoint(path, template)
    assert step == seed and extra == {"tag": "x"}
    for k in tree:
        _assert_bit_identical(out[k], tree[k])


def test_bfloat16_plane_survives(tmp_path):
    """The regression that motivated the rewrite: plain np.savez returns
    bf16 as a void |V2 array; the raw-bytes path must not."""
    a = jnp.arange(17, dtype=jnp.bfloat16) * jnp.bfloat16(0.3)
    path = str(tmp_path / "bf16.npz")
    save_checkpoint(path, {"p": a})
    out, _, _ = load_checkpoint(
        path, {"p": jax.ShapeDtypeStruct(a.shape, a.dtype)})
    assert np.asarray(out["p"]).dtype == jnp.bfloat16
    _assert_bit_identical(out["p"], np.asarray(a))


def test_dtype_mismatch_refuses(tmp_path):
    path = str(tmp_path / "t.npz")
    save_checkpoint(path, {"p": np.zeros((3,), np.float32)})
    with pytest.raises(ValueError, match="refusing a silent cast"):
        load_checkpoint(path, {"p": jax.ShapeDtypeStruct((3,),
                                                         jnp.bfloat16)})


def test_leaf_count_mismatch_refuses(tmp_path):
    path = str(tmp_path / "t.npz")
    save_checkpoint(path, {"p": np.zeros((3,), np.float32)})
    with pytest.raises(ValueError, match="carry layout"):
        load_checkpoint(path, {"p": jax.ShapeDtypeStruct((3,), jnp.float32),
                               "q": jax.ShapeDtypeStruct((3,), jnp.float32)})


def test_atomic_save_leaves_no_temp_files(tmp_path):
    path = str(tmp_path / "t.npz")
    save_checkpoint(path, {"p": np.zeros((3,), np.float32)})
    save_checkpoint(path, {"p": np.ones((3,), np.float32)})   # overwrite
    assert os.listdir(tmp_path) == ["t.npz"]


# ---------------------------------------------------------------------------
# the real thing: a RoundCarry with every exotic-dtype plane populated
# ---------------------------------------------------------------------------

# int8 compressed slots vs bf16 dense pending planes are mutually
# exclusive carry layouts (compressed mode keeps its error-feedback
# residuals in f32), so two configs cover every dtype family together
CARRY_CFGS = {
    "topk_int8": (dict(cohort_size=4, compress="topk", compress_ratio=0.25,
                       slot_dtype="int8", divergence_factor=4.0),
                  {"int8", "int32", "bool", "float32"}),
    "dense_bf16": (dict(pending_dtype="bfloat16", divergence_factor=4.0),
                   {"bfloat16", "int32", "bool", "float32"}),
}


def _fault_carry(n_rounds: int = 2, cfg: str = "topk_int8"):
    """Fused carry with int8 compressed slots (or bf16 pending planes),
    i32 slot/scheduler planes, bool masks, AND the divergence rollback
    slot — every dtype family the checkpoint must preserve."""
    from repro.core import ChannelConfig, SchedulerConfig
    from repro.data.partition import partition_noniid
    from repro.data.pipeline import build_federation
    from repro.data.synthetic import make_mnist_like
    from repro.fl import FLClient, FusedPAOTA, PAOTAConfig
    from repro.models.mlp import init_mlp_params, mlp_loss

    K = 8
    x, y, _, _ = make_mnist_like(n_train=1200, n_test=10)
    parts = partition_noniid(y, n_clients=K, seed=0)
    clients = [FLClient(d, mlp_loss, batch_size=32, lr=0.1, local_steps=2)
               for d in build_federation(x, y, parts)]
    srv = FusedPAOTA(init_mlp_params(jax.random.PRNGKey(0)), clients,
                     ChannelConfig(), SchedulerConfig(n_clients=K, seed=1),
                     PAOTAConfig(transmit="delta"), **CARRY_CFGS[cfg][0])
    if n_rounds:
        srv.advance(n_rounds)
    return srv


@pytest.mark.parametrize("cfg", sorted(CARRY_CFGS))
def test_round_carry_round_trip_bit_identical(tmp_path, cfg):
    srv = _fault_carry(cfg=cfg)
    carry = jax.device_get(srv._carry)
    leaves = jax.tree_util.tree_leaves(carry)
    dtypes = {np.asarray(l).dtype.name for l in leaves}
    # the carry really holds the exotic planes this test claims to cover
    assert CARRY_CFGS[cfg][1] <= dtypes
    path = str(tmp_path / "carry.npz")
    save_checkpoint(path, carry, step=2)
    out, step, _ = load_checkpoint(path, carry)
    assert step == 2
    got = jax.tree_util.tree_leaves(out)
    assert len(got) == len(leaves)
    for g, w in zip(got, leaves):
        _assert_bit_identical(g, w)


def test_driver_resume_from_carry_checkpoint(tmp_path):
    """End to end through the driver API: restore_checkpoint rebinds the
    carry and the next advance continues bit-exactly (counter RNG)."""
    full = _fault_carry()          # advanced 2 rounds already
    full.advance(2)
    part = _fault_carry()
    path = str(tmp_path / "c.npz")
    part.save_checkpoint(path)
    res = _fault_carry(n_rounds=0)     # fresh driver, never advanced
    res.restore_checkpoint(path)
    res.advance(2)
    np.testing.assert_array_equal(full.global_vec, res.global_vec)
    assert len(res.history) == 4
