"""Semi-async scheduler + Theorem-1 bound tests."""
import numpy as np
import pytest

from repro.core.convergence import (BoundConstants, bound_trajectory,
                                    contraction_A, gap_G)
from repro.core.scheduler import SchedulerConfig, SemiAsyncScheduler


def _run(sched, rounds):
    history = []
    for _ in range(rounds):
        upl, stal = sched.advance_to_aggregation()
        history.append((upl, stal))
        sched.start_round(upl)
    return history


def test_scheduler_periodic_clock():
    s = SemiAsyncScheduler(SchedulerConfig(n_clients=10, delta_t=8.0, seed=0))
    _run(s, 5)
    assert s.time == pytest.approx(40.0)     # fixed-period: 5 * delta_t


def test_scheduler_semi_async_participation():
    """With latency U(5,15) and delta_t=8, some but not all clients upload
    each round, and staleness > 0 occurs (the semi-async regime)."""
    s = SemiAsyncScheduler(SchedulerConfig(n_clients=100, delta_t=8.0, seed=1))
    history = _run(s, 10)
    parts = [len(u) for u, _ in history[1:]]
    stals = np.concatenate([st[u] for u, st in history[1:]])
    assert 0 < min(parts) and max(parts) < 100
    assert stals.max() >= 1                  # stragglers exist
    assert stals.max() <= 3                  # U(5,15) -> at most ~2 periods


def test_scheduler_deterministic_given_seed():
    a = SemiAsyncScheduler(SchedulerConfig(n_clients=20, seed=7))
    b = SemiAsyncScheduler(SchedulerConfig(n_clients=20, seed=7))
    for _ in range(4):
        ua, sa = a.advance_to_aggregation()
        ub, sb = b.advance_to_aggregation()
        np.testing.assert_array_equal(ua, ub)
        np.testing.assert_array_equal(sa, sb)
        a.start_round(ua)
        b.start_round(ub)


def test_sync_round_slower_than_paota_period():
    """The paper's wall-clock claim: sync rounds wait for the max of
    participant latencies (mean ~ 14s for 50 draws of U(5,15)) while PAOTA
    rounds are fixed at delta_t = 8s."""
    s = SemiAsyncScheduler(SchedulerConfig(n_clients=100, seed=0))
    times = [s.sync_round_time(50) for _ in range(50)]
    assert np.mean(times) > 8.0


def test_contraction_A_below_one_for_paper_setting():
    c = BoundConstants(eta=0.002, local_steps=5, smooth_l=10.0, delta=0.001,
                       vartheta=0.5)
    assert contraction_A(c) < 1.0


def test_contraction_A_diverges_for_large_lr():
    c = BoundConstants(eta=0.05, local_steps=5, smooth_l=10.0)
    assert contraction_A(c) >= 1.0 or contraction_A(c) == np.inf


def test_gap_terms_positive_and_power_sensitivity():
    c = BoundConstants()
    alphas = np.full(10, 0.1)
    g_lo = gap_G(c, alphas, sum_bp=10.0, model_dim=8070, sigma_n2=1e-4)
    g_hi = gap_G(c, alphas, sum_bp=100.0, model_dim=8070, sigma_n2=1e-4)
    assert all(v > 0 for k, v in g_lo.items() if k in "abcde")
    assert g_hi["e"] < g_lo["e"]             # more power -> less noise term
    # concentrated weights worsen term (d) (staleness variance)
    conc = np.zeros(10)
    conc[0] = 1.0
    g_conc = gap_G(c, conc, 10.0, 8070, 1e-4)
    assert g_conc["d"] > g_lo["d"]


def test_bound_trajectory_converges_when_contractive():
    c = BoundConstants(eta=0.002, local_steps=5, smooth_l=10.0, delta=0.001,
                       vartheta=0.5)
    a = contraction_A(c)
    assert a < 1
    g = [0.05] * 200
    traj = bound_trajectory(c, g, f0_gap=10.0)
    # converges to the fixed point G/(1-A)
    assert traj[-1] == pytest.approx(0.05 / (1 - a), rel=0.05)
    assert traj[-1] < traj[0]
