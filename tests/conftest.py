"""Suite-wide fixtures: force a multi-device CPU backend BEFORE jax
initializes.

The mesh-sharded PAOTA tests need >= 8 devices; CI runs on 2-core CPU
boxes with exactly one XLA CPU device. ``--xla_force_host_platform_
device_count`` can only take effect if it is in ``XLA_FLAGS`` before the
first jax backend initialization, so this conftest (imported by pytest
before any test module) appends it at import time — UNLESS jax was
already imported by an earlier plugin/conftest, in which case forcing is
impossible and the multi-device tests skip gracefully via
``require_host_devices``.

Everything else in the suite is device-count-agnostic: single-device
computations place on device 0 exactly as before.
"""
import os
import sys

import pytest

FORCED_HOST_DEVICES = 8

if ("jax" not in sys.modules
        and "xla_force_host_platform_device_count"
        not in os.environ.get("XLA_FLAGS", "")):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={FORCED_HOST_DEVICES}"
    ).strip()


def require_host_devices(n: int):
    """Skip (never error) when the backend came up with < n devices —
    e.g. jax was imported before this conftest could set XLA_FLAGS, or a
    real accelerator backend ignores host-device forcing."""
    import jax
    if len(jax.devices()) < n:
        pytest.skip(f"needs {n} devices, backend has {len(jax.devices())} "
                    f"(host-device forcing unavailable)")


@pytest.fixture
def client_mesh_8():
    """(8, 1) ("data", "model") mesh over the forced host devices."""
    require_host_devices(8)
    from repro.launch.mesh import make_client_mesh
    return make_client_mesh(8)


@pytest.fixture
def pod_mesh_2x4():
    """(2, 4) ("pod", "data") mesh — the grouped-aggregation topology's
    test-sized twin (2 pods of 4 client shards)."""
    require_host_devices(8)
    from repro.launch.mesh import make_pod_mesh
    return make_pod_mesh(pods=2, data=4)
