"""Intra-client tensor parallelism under the sharded round.

The ("pod", "data", "tp") mesh completes the pods x clients x TP
topology: client shards hold (K_local, ...) stacked leaves whose model
dims are additionally TP-sharded over the "tp" axis, while the round's
tree reductions psum TP partials back together. Pinned here:

* TP extent 1 is BIT-IDENTICAL to the flat client-mesh program — any
  extent-1 tp axis must trace the exact PR-8 round, op for op;
* TP extent > 1 is allclose to the fused pytree reference (the single
  cross-client psum now also gathers the TP blocks, and the AWGN
  realization is drawn at full leaf shapes so every TP layout consumes
  the same total noise);
* the compiled HLO shows exactly ONE cross-client model-sized
  all-reduce — TP adds small tp-spanning stats psums, never a second
  model-plane collective;
* unsupported combos (raveled/cohort/grouped/compress x TP) refuse with
  messages naming both offending knobs and the nearest supported
  configuration;
* the minicpm-2b-reduced transformer client federates on the forced
  (1, 2, 4) mesh with its attention/MLP leaves genuinely TP-sharded.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ChannelConfig, SchedulerConfig
from repro.data.partition import partition_noniid
from repro.data.pipeline import ClientData, build_federation
from repro.data.synthetic import make_mnist_like
from repro.fl import FLClient, FusedPAOTA, PAOTAConfig, ShardedPAOTA
from repro.models.mlp import init_mlp_params, mlp_loss

K = 8


@pytest.fixture(scope="module")
def data():
    x, y, _, _ = make_mnist_like(n_train=2000, n_test=10)
    parts = partition_noniid(y, n_clients=K, seed=0)
    return x, y, parts


def _clients(data):
    x, y, parts = data
    return [FLClient(d, mlp_loss, batch_size=32, lr=0.1, local_steps=5)
            for d in build_federation(x, y, parts)]


def _params(hidden=128):
    # hidden=128: every hidden dim divides tp extents 2 and 4, so the
    # placement rules TP-shard the big leaves while the 10-class output
    # biases stay replicated — both reduction paths exercised
    return init_mlp_params(jax.random.PRNGKey(0), hidden=hidden)


def _cfg(k=K, **kw):
    return (ChannelConfig(), SchedulerConfig(n_clients=k, seed=1, **kw),
            PAOTAConfig())


def _tp_mesh(tp, data_shards=None):
    from tests.conftest import require_host_devices
    require_host_devices(8)
    from repro.launch.mesh import make_pod_mesh
    return make_pod_mesh(pods=1, data=data_shards or 8 // tp, tp=tp)


# ---------------------------------------------------------------------------
# extent-1 bit-identity and TP-vs-flat parity
# ---------------------------------------------------------------------------

@pytest.mark.multidevice
def test_tp_extent1_bit_identity(data, client_mesh_8):
    """A ("pod","data","tp") mesh with tp extent 1 skips every TP branch
    at trace time: the program is the historical flat round, draw for
    draw and bit for bit."""
    flat = ShardedPAOTA(_params(10), _clients(data), *_cfg(),
                        mesh=client_mesh_8, params_mode="pytree")
    tp1 = ShardedPAOTA(_params(10), _clients(data), *_cfg(),
                       mesh=_tp_mesh(1, data_shards=8),
                       params_mode="pytree")
    assert tp1._tp is None
    for rf, rt in zip(flat.advance(4), tp1.advance(4)):
        assert rf == rt
    np.testing.assert_array_equal(flat.global_vec, tp1.global_vec)


@pytest.mark.multidevice
@pytest.mark.parametrize("tp", [2, 4])
def test_tp_matches_fused_pytree(data, tp):
    """TP-sharded rounds reproduce the fused single-device pytree
    trajectory: the clients x tp psum superposes AND gathers, and the
    full-shape AWGN draw keeps the noise realization layout-invariant."""
    ref = FusedPAOTA(_params(), _clients(data), *_cfg(),
                     params_mode="pytree")
    srv = ShardedPAOTA(_params(), _clients(data), *_cfg(),
                       mesh=_tp_mesh(tp), params_mode="pytree")
    assert srv._tp is not None and srv._tp.shards == tp
    assert any(d >= 0 for d in srv._tp.leaf_dims)
    if tp == 4:
        # the 10-wide output leaves cannot divide 4 and stay replicated:
        # both reduction paths (TP-sharded + TP-replicated) exercised
        assert any(d < 0 for d in srv._tp.leaf_dims)
    for rf, rt in zip(ref.advance(4), srv.advance(4)):
        assert rf["n_participants"] == rt["n_participants"]
        assert rf["time"] == rt["time"]
        assert rf["varsigma"] == pytest.approx(rt["varsigma"], rel=1e-5)
    np.testing.assert_allclose(ref.global_vec, srv.global_vec,
                               rtol=1e-4, atol=1e-5)


@pytest.mark.multidevice
def test_tp_noise_total_is_layout_invariant(data):
    """Same seed, different TP layouts: identical trajectories. The AWGN
    split is defined on FULL leaf shapes from the replicated round key,
    so (1,4,2) and (1,2,4) consume the very same realization."""
    a = ShardedPAOTA(_params(), _clients(data), *_cfg(),
                     mesh=_tp_mesh(2), params_mode="pytree")
    b = ShardedPAOTA(_params(), _clients(data), *_cfg(),
                     mesh=_tp_mesh(4), params_mode="pytree")
    for ra, rb in zip(a.advance(3), b.advance(3)):
        assert ra["n_participants"] == rb["n_participants"]
        assert ra["varsigma"] == pytest.approx(rb["varsigma"], rel=1e-5)
    np.testing.assert_allclose(a.global_vec, b.global_vec,
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# compiled-HLO collective structure
# ---------------------------------------------------------------------------

@pytest.mark.multidevice
def test_tp_hlo_single_model_sized_psum(data):
    """The structural contract: ONE cross-client model-sized all-reduce
    per round (it spans the tp axis too — superpose + gather in the same
    op), plus small tp-spanning stats psums; never a second model-plane
    collective."""
    from repro.launch.collectives import axis_crossing_allreduce_count
    srv = ShardedPAOTA(_params(), _clients(data), *_cfg(),
                       mesh=_tp_mesh(4), params_mode="pytree")
    hlo = srv.compiled_scan_hlo(1)
    shape = tuple(srv.mesh.shape[a] for a in srv.mesh.axis_names)
    # d+1 = 118283 for the hidden-128 MLP; the floor sits above the
    # 4096-wide water-filling grid psum and every scalar metric
    floor = 4097
    assert axis_crossing_allreduce_count(hlo, shape, (0, 1),
                                         min_elements=floor) == 1
    assert axis_crossing_allreduce_count(hlo, shape, (2,),
                                         min_elements=floor) == 1
    # the TP-aware stats sweep psums its [dots|dn2|gn2] concat over tp
    assert axis_crossing_allreduce_count(hlo, shape, (2,),
                                         max_elements=4096) >= 1


# ---------------------------------------------------------------------------
# unsupported-combo refusals
# ---------------------------------------------------------------------------

@pytest.mark.multidevice
def test_tp_refusals_name_both_knobs(data):
    """Every unsupported combination refuses with a message naming BOTH
    offending knobs and pointing at the nearest supported configuration
    (the error is the only breadcrumb a launcher user gets)."""
    mesh = _tp_mesh(4)
    cases = [
        (dict(params_mode="raveled"),
         ["params_mode='raveled'", "params_mode='pytree'"]),
        (dict(params_mode="pytree", cohort_size=4),
         ["cohort_size=4", "cohort_size=None"]),
        (dict(params_mode="pytree", group_period=2),
         ["group_period=2", "group_period=0"]),
        (dict(params_mode="pytree", compress="topk", compress_ratio=0.25),
         ["compress='topk'", "compress=None"]),
    ]
    for kw, needles in cases:
        with pytest.raises(NotImplementedError) as exc:
            ShardedPAOTA(_params(), _clients(data), *_cfg(),
                         mesh=mesh, **kw)
        msg = str(exc.value)
        for needle in needles:
            assert needle in msg, (kw, needle, msg)
        assert "nearest supported" in msg, (kw, msg)
        assert "tp" in msg.lower(), (kw, msg)


@pytest.mark.multidevice
def test_tp_axes_must_be_nonclient_mesh_axes(data):
    """Explicit tp_axes naming a client axis (or a non-mesh axis) is a
    config error, not a silent fallback."""
    mesh = _tp_mesh(4)
    with pytest.raises(ValueError, match="non-client mesh axes"):
        ShardedPAOTA(_params(), _clients(data), *_cfg(), mesh=mesh,
                     params_mode="pytree", tp_axes=("data",))
    with pytest.raises(ValueError, match="non-client mesh axes"):
        ShardedPAOTA(_params(), _clients(data), *_cfg(), mesh=mesh,
                     params_mode="pytree", tp_axes=("nope",))


# ---------------------------------------------------------------------------
# transformer client under real TP placement
# ---------------------------------------------------------------------------

@pytest.mark.multidevice
@pytest.mark.slow
def test_transformer_client_tp_round():
    """Acceptance: the minicpm-2b-reduced transformer federation
    completes sharded PAOTA rounds on the forced (1, 2, 4) mesh with its
    attention/MLP leaves TP-sharded by the name-based placement rules
    (every REDUCED model dim divides 4)."""
    from repro.configs.minicpm_2b import REDUCED as cfg
    from repro.models.transformer import init_model, loss_fn

    k, n, seq = 8, 8, 16
    rng = np.random.default_rng(0)

    def tloss(p, batch):
        return loss_fn(p, {"tokens": batch["x"]}, cfg)[0]

    clients = [FLClient(ClientData(
        rng.integers(0, cfg.vocab_size, (n, seq)).astype(np.int32),
        np.zeros(n, np.int32), i), tloss, batch_size=4, lr=0.01,
        local_steps=2) for i in range(k)]
    params = init_model(jax.random.PRNGKey(0), cfg)
    srv = ShardedPAOTA(params, clients, ChannelConfig(),
                       SchedulerConfig(n_clients=k, seed=1), PAOTAConfig(),
                       mesh=_tp_mesh(4), params_mode="pytree",
                       model_cfg=cfg)
    assert srv._tp is not None and srv._tp.shards == 4
    n_sharded = sum(1 for d in srv._tp.leaf_dims if d >= 0)
    assert n_sharded >= 8          # wq/wk/wv/wo + mlp per layer at least
    rows = srv.advance(3)
    assert any(r["n_participants"] > 0 for r in rows)
    g = srv.global_params()
    assert jax.tree_util.tree_structure(g) \
        == jax.tree_util.tree_structure(params)
    assert all(bool(jnp.isfinite(l).all())
               for l in jax.tree_util.tree_leaves(g))
