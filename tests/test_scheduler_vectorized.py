"""Vectorized-scheduler invariants + draw-for-draw parity with the scalar
reference implementation."""
import numpy as np
import pytest

from repro.core.scheduler import (ScalarSemiAsyncScheduler, SchedulerConfig,
                                  SemiAsyncScheduler)


def _cfg(**kw):
    base = dict(n_clients=50, delta_t=8.0, seed=11)
    base.update(kw)
    return SchedulerConfig(**base)


def test_vector_matches_scalar_draw_for_draw():
    """Same seed -> identical uploader sets, staleness arrays, clocks and
    sync-round draws, round after round."""
    vec, ref = SemiAsyncScheduler(_cfg()), ScalarSemiAsyncScheduler(_cfg())
    vec.start_round(range(50))
    ref.start_round(range(50))
    for _ in range(12):
        uv, sv = vec.advance_to_aggregation()
        ur, sr = ref.advance_to_aggregation()
        np.testing.assert_array_equal(uv, ur)
        np.testing.assert_array_equal(sv, sr)
        assert vec.time == pytest.approx(ref.time)
        vec.start_round(uv)
        ref.start_round(ur)
    assert vec.sync_round_time(20) == pytest.approx(ref.sync_round_time(20))


def test_staleness_nonnegative_and_bounded():
    s = SemiAsyncScheduler(_cfg(n_clients=200, seed=3))
    s.start_round(range(200))
    for _ in range(20):
        upl, stal = s.advance_to_aggregation()
        assert (stal >= 0).all()
        # U(5,15) with delta_t=8 -> at most ~2 missed periods
        assert stal.max() <= 3
        s.start_round(upl)


def test_uploaders_subset_of_ready():
    s = SemiAsyncScheduler(_cfg(n_clients=100, seed=7))
    s.start_round(range(100))
    for _ in range(10):
        upl, _ = s.advance_to_aggregation()
        assert s.ready[upl].all()                  # uploaders have b_k = 1
        busy = np.setdiff1d(np.arange(100), upl)
        assert not s.ready[busy].any()             # everyone else is busy
        s.start_round(upl)


def test_time_strictly_increases_by_delta_t():
    s = SemiAsyncScheduler(_cfg(delta_t=5.5))
    s.start_round(range(50))
    prev = s.time
    for _ in range(8):
        s.start_round(s.advance_to_aggregation()[0])
        assert s.time == pytest.approx(prev + 5.5)
        prev = s.time


def test_empty_broadcast_consumes_no_draws():
    a, b = SemiAsyncScheduler(_cfg()), SemiAsyncScheduler(_cfg())
    a.start_round([])
    assert a._draw_latency() == b._draw_latency()  # streams still aligned


def test_busy_client_keeps_model_round():
    """A straggler restarted at round r keeps model_round=r until its next
    broadcast, so its staleness grows by 1 per missed period."""
    s = SemiAsyncScheduler(_cfg(n_clients=30, seed=5,
                                lat_lo=9.0, lat_hi=15.9))
    s.start_round(range(30))
    seen_growth = False
    prev_stal = None
    for _ in range(6):
        upl, stal = s.advance_to_aggregation()
        if prev_stal is not None:
            still_busy = np.setdiff1d(np.arange(30), upl)
            if len(still_busy):
                seen_growth = True
        prev_stal = stal
        s.start_round(upl)
    assert seen_growth
