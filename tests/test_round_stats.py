"""PR-5 delta-plane tests: fused round-stats + superpose-and-normalize
kernels vs the ref.py oracles (interpret mode on CPU), the chunked-jnp
twin, bf16 pending storage error bounds, and donation safety."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.aircomp_sum import superpose_normalize_pallas
from repro.kernels.round_stats import round_stats_jnp, round_stats_pallas

RNG = np.random.default_rng(7)


def _assert_stats_close(got, want, rtol=3e-5, atol=3e-4):
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=rtol, atol=atol)


# ---------------------------------------------------------------------------
# round-stats kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k,d", [(4, 64), (37, 1111), (100, 8070), (1, 513)])
@pytest.mark.parametrize("with_payload", [False, True])
def test_round_stats_kernel_sweep(k, d, with_payload):
    de = jnp.asarray(RNG.normal(size=(k, d)), jnp.float32)
    g = jnp.asarray(RNG.normal(size=d), jnp.float32)
    p = jnp.asarray(RNG.normal(size=(k, d)), jnp.float32) \
        if with_payload else None
    stats, gn2 = round_stats_pallas(de, g, p, interpret=True)
    want, wgn2 = ref.round_stats_ref(de, g, p)
    assert stats.shape == (k, 3 if with_payload else 2)
    _assert_stats_close(stats, want)
    assert float(gn2) == pytest.approx(float(wgn2), rel=3e-5)


@pytest.mark.parametrize("dtype", [jnp.bfloat16])
def test_round_stats_kernel_bf16_accumulates_f32(dtype):
    """bf16 storage in, f32 stats out — the kernel upcasts per stripe."""
    k, d = 16, 2048
    de = jnp.asarray(0.01 * RNG.normal(size=(k, d)), dtype)
    p = jnp.asarray(RNG.normal(size=(k, d)), dtype)
    g = jnp.asarray(RNG.normal(size=d), jnp.float32)
    stats, gn2 = round_stats_pallas(de, g, p, interpret=True)
    assert stats.dtype == jnp.float32
    want, _ = ref.round_stats_ref(de.astype(jnp.float32), g,
                                  p.astype(jnp.float32))
    _assert_stats_close(stats, want, rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("chunk", [None, 64, 1000])
def test_round_stats_jnp_chunked_matches_ref(chunk):
    """The chunked-jnp twin equals the oracle for chunk sizes below,
    at, and above the leaf size."""
    k, d = 13, 777
    de = jnp.asarray(RNG.normal(size=(k, d)), jnp.float32)
    p = jnp.asarray(RNG.normal(size=(k, d)), jnp.float32)
    g = jnp.asarray(RNG.normal(size=d), jnp.float32)
    dots, dn2, pn2, gn2 = round_stats_jnp(de, g, p, chunk=chunk)
    want, wgn2 = ref.round_stats_ref(de, g, p)
    _assert_stats_close(jnp.stack([dots, dn2, pn2], 1), want, rtol=1e-5)
    assert float(gn2) == pytest.approx(float(wgn2), rel=1e-5)


def test_round_stats_jnp_pytree_accumulates_leaves():
    """Tree stats == stats of the raveled concatenation (same model,
    different leaf split) up to float regrouping."""
    k = 9
    tree_d = {"a": (k, 33), "b": (k, 8, 16), "c": (k, 5)}
    de = {n: jnp.asarray(RNG.normal(size=s), jnp.float32)
          for n, s in tree_d.items()}
    g = {n: jnp.asarray(RNG.normal(size=s[1:]), jnp.float32)
         for n, s in tree_d.items()}
    dots, dn2, pn2, gn2 = round_stats_jnp(de, g, de)
    flat_de = jnp.concatenate(
        [l.reshape(k, -1) for l in jax.tree_util.tree_leaves(de)], 1)
    flat_g = jnp.concatenate(
        [l.reshape(-1) for l in jax.tree_util.tree_leaves(g)])
    want, wgn2 = ref.round_stats_ref(flat_de, flat_g, flat_de)
    _assert_stats_close(jnp.stack([dots, dn2, pn2], 1), want, rtol=1e-5)
    assert float(gn2) == pytest.approx(float(wgn2), rel=1e-5)
    np.testing.assert_allclose(np.asarray(dn2), np.asarray(pn2), rtol=1e-6)


# ---------------------------------------------------------------------------
# superpose-and-normalize kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k,d", [(4, 64), (37, 1111), (100, 8070)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_superpose_normalize_sweep(k, d, dtype):
    x = jnp.asarray(RNG.normal(size=(k, d)), dtype)
    powers = jnp.asarray(RNG.random(k), jnp.float32)
    mask = jnp.asarray(RNG.random(k) < 0.6, jnp.float32)
    n = jnp.asarray(RNG.normal(size=d), jnp.float32)
    agg, vs = superpose_normalize_pallas(x, powers, mask, n, interpret=True)
    want, wvs = ref.superpose_normalize_ref(x, powers, mask, n)
    assert agg.dtype == jnp.float32
    tol = dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(np.asarray(agg), np.asarray(want), **tol)
    assert float(vs) == pytest.approx(float(wvs), abs=1e-6)


def test_superpose_normalize_masked_phantom_rows():
    """Masked (phantom) rows never leak into the aggregate, no matter how
    large their stale payload values are."""
    k, d = 8, 512
    x = jnp.asarray(RNG.normal(size=(k, d)), jnp.float32)
    x = x.at[3].set(1e30).at[6].set(-1e30)          # phantom garbage rows
    powers = jnp.ones((k,), jnp.float32)
    mask = jnp.asarray([1, 1, 0, 0, 1, 0, 0, 1], jnp.float32)
    n = jnp.zeros((d,), jnp.float32)
    agg, vs = superpose_normalize_pallas(x, powers, mask, n, interpret=True)
    want = (x[0] + x[1] + x[4] + x[7]) / 4.0
    assert float(vs) == pytest.approx(4.0)
    np.testing.assert_allclose(np.asarray(agg), np.asarray(want), rtol=2e-6,
                               atol=2e-6)


def test_superpose_normalize_zero_uploaders():
    """A zero-uploader period returns raw varsigma 0 (the guard signal)
    and a pure clamped-noise aggregate — the caller's guard discards it."""
    k, d = 5, 256
    x = jnp.asarray(RNG.normal(size=(k, d)), jnp.float32)
    powers = jnp.asarray(RNG.random(k), jnp.float32)
    mask = jnp.zeros((k,), jnp.float32)
    n = jnp.asarray(RNG.normal(size=d), jnp.float32)
    agg, vs = superpose_normalize_pallas(x, powers, mask, n, interpret=True)
    assert float(vs) == 0.0
    np.testing.assert_allclose(np.asarray(agg), np.asarray(n) / 1e-12,
                               rtol=1e-4)


# ---------------------------------------------------------------------------
# round-level: one-sweep factors == the composed stage ops
# ---------------------------------------------------------------------------

def test_round_factors_matches_composed_ops():
    from repro.core.power_control import (client_dots, client_sq_norms,
                                          cosine_similarity,
                                          similarity_factor,
                                          staleness_factor)
    from repro.fl.runtime import round_factors
    k, d = 23, 4097
    deltas = jnp.asarray(RNG.normal(size=(k, d)), jnp.float32)
    pending = jnp.asarray(RNG.normal(size=(k, d)), jnp.float32)
    g = jnp.asarray(RNG.normal(size=d), jnp.float32)
    prev = jnp.asarray(RNG.normal(size=d), jnp.float32)
    stal = jnp.asarray(RNG.integers(0, 5, k), jnp.float32)
    rho, theta, w2 = round_factors(deltas, pending, g, prev, stal, 3.0)
    cos = cosine_similarity(deltas, g - prev)
    np.testing.assert_allclose(np.asarray(theta),
                               np.asarray(similarity_factor(cos)), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(rho),
                               np.asarray(staleness_factor(stal, 3.0)),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(w2),
                               np.asarray(client_sq_norms(pending)),
                               rtol=1e-6)
    # transmit='delta': payload norms must be the delta norms, not re-swept
    _, _, w2d = round_factors(deltas, None, g, prev, stal, 3.0)
    np.testing.assert_allclose(np.asarray(w2d),
                               np.asarray(client_sq_norms(deltas)),
                               rtol=1e-6)


def test_round_factors_zero_direction_gives_half_theta():
    """w_g == w_g^{t-1} (e.g. after a held round): cos must be exactly 0,
    theta exactly 1/2 — no NaN from the 0/0."""
    from repro.fl.runtime import round_factors
    k, d = 7, 129
    deltas = jnp.asarray(RNG.normal(size=(k, d)), jnp.float32)
    g = jnp.asarray(RNG.normal(size=d), jnp.float32)
    stal = jnp.zeros((k,), jnp.float32)
    rho, theta, _ = round_factors(deltas, None, g, g, stal, 3.0)
    np.testing.assert_array_equal(np.asarray(theta), 0.5)


# ---------------------------------------------------------------------------
# bf16 pending storage + donation safety (driver level)
# ---------------------------------------------------------------------------

def _tiny_server(pending_dtype="float32", donate=True, seed=0, k=12):
    from repro.core import ChannelConfig, SchedulerConfig
    from repro.data.partition import partition_noniid
    from repro.data.pipeline import build_federation
    from repro.data.synthetic import make_mnist_like
    from repro.fl import BatchedEngine, FusedPAOTA, PAOTAConfig
    from repro.models.mlp import init_mlp_params, mlp_loss
    x, y, _, _ = make_mnist_like(n_train=600, n_test=10, seed=1234)
    parts = partition_noniid(y, n_clients=k, sizes=(16, 24), seed=seed)
    fed = build_federation(x, y, parts, seed=seed)
    eng = BatchedEngine(fed, mlp_loss, batch_size=8, lr=0.1, local_steps=2)
    params = init_mlp_params(jax.random.PRNGKey(seed))
    return FusedPAOTA(params, eng, ChannelConfig(),
                      SchedulerConfig(n_clients=k, seed=seed),
                      PAOTAConfig(seed=seed), pending_dtype=pending_dtype,
                      donate=donate)


def test_bf16_pending_tracks_f32_trajectory():
    """Property: the bf16 storage cast is a RELATIVE rounding (~2^-8) of
    the stored planes, not a cancellation. After one aggregation the
    global must sit within a rounding-scaled envelope of the f32 result;
    over more rounds the trajectories drift (SGD amplifies the rounding)
    but must stay finite with identical participation patterns (the
    scheduler never sees the planes)."""
    f32 = _tiny_server("float32")
    b16 = _tiny_server("bfloat16")
    # first aggregation with >=1 uploader: one storage-rounding step
    rows_f, rows_b = f32.advance(2), b16.advance(2)
    gf, gb = f32.global_vec, b16.global_vec
    assert any(r["n_participants"] > 0 for r in rows_f)
    scale = float(np.max(np.abs(gf)))
    assert float(np.max(np.abs(gf - gb))) < 0.02 * scale
    rows_f, rows_b = f32.advance(4), b16.advance(4)
    for rf, rb in zip(rows_f, rows_b):
        assert rf["n_participants"] == rb["n_participants"]
        assert rf["time"] == rb["time"]
    assert np.isfinite(b16.global_vec).all()
    # the carry planes really are stored in bf16, the globals in f32
    assert b16._carry.pending.dtype == jnp.bfloat16
    assert b16._carry.deltas.dtype == jnp.bfloat16
    assert b16._carry.global_vec.dtype == jnp.float32


@pytest.mark.multidevice
def test_bf16_sharded_global_stays_f32(client_mesh_8):
    """The sharded psum aggregation must return f32 aggregates for a bf16
    carry — only the stored planes are rounded, never the global update
    (regression: the psum entries used to cast the aggregate back to the
    payload dtype, quantizing w_g to bf16 every round)."""
    from repro.core import ChannelConfig, SchedulerConfig
    from repro.data.partition import partition_noniid
    from repro.data.pipeline import build_federation
    from repro.data.synthetic import make_mnist_like
    from repro.fl import BatchedEngine, FusedPAOTA, PAOTAConfig, ShardedPAOTA
    from repro.models.mlp import init_mlp_params, mlp_loss

    def build(cls, **kw):
        x, y, _, _ = make_mnist_like(n_train=800, n_test=10, seed=1234)
        parts = partition_noniid(y, n_clients=16, sizes=(16, 24), seed=0)
        eng = BatchedEngine(build_federation(x, y, parts, seed=0), mlp_loss,
                            batch_size=8, lr=0.1, local_steps=2)
        return cls(init_mlp_params(jax.random.PRNGKey(0)), eng,
                   ChannelConfig(), SchedulerConfig(n_clients=16, seed=0),
                   PAOTAConfig(seed=0), pending_dtype="bfloat16", **kw)

    fused = build(FusedPAOTA)
    shard = build(ShardedPAOTA, mesh=client_mesh_8)
    rows_f, rows_s = fused.advance(4), shard.advance(4)
    assert any(r["n_participants"] > 0 for r in rows_f)
    for rf, rs in zip(rows_f, rows_s):
        assert rf["n_participants"] == rs["n_participants"]
    assert shard._carry.global_vec.dtype == jnp.float32
    assert shard._carry.pending.dtype == jnp.bfloat16
    gf, gs = fused.global_vec, shard.global_vec
    # full precision: NOT bf16-quantized (a bf16 roundtrip would be exact)
    assert not np.array_equal(
        gs, np.asarray(jnp.asarray(gs).astype(jnp.bfloat16).astype(
            jnp.float32)))
    np.testing.assert_allclose(gf, gs, rtol=2e-3, atol=2e-3)


def test_donation_safe():
    """Donating the round carry into the scan must not change a single
    bit of the trajectory (the donated buffers are never re-read)."""
    don = _tiny_server(donate=True)
    ref_srv = _tiny_server(donate=False)
    for _ in range(3):
        rd, rr = don.advance(2), ref_srv.advance(2)
        for a, b in zip(rd, rr):
            assert a == b, (a, b)
    np.testing.assert_array_equal(don.global_vec, ref_srv.global_vec)


def test_donation_buffers_actually_donated():
    """The scan jit really declares the carry donated (guards against the
    flag silently regressing to a copy)."""
    srv = _tiny_server(donate=True)
    srv.advance(1)
    carry = srv._carry
    srv.advance(1)
    # the old carry's buffers were handed to XLA; their jax view must be
    # marked deleted (donated), not silently copied
    assert carry.pending.is_deleted()
    assert carry.deltas.is_deleted()
