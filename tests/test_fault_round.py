"""Fault-tolerant round core (ISSUE-10 tentpole): injection, containment,
rollback, resume.

The contracts pinned here:

* identity — a default ``FaultConfig()`` (and screen/divergence off) is
  the IDENTITY on both the fused and sharded drivers: every fault stage
  is skipped at trace time, so the trajectory is bit-identical to a
  driver built with no fault arguments at all;
* containment — a screened faulty round equals the same round in which
  the faulty clients' uploads were dropped in transit (the scenario
  drop mask): screening masks corrupt rows out of the superposition
  exactly like phantoms, bit-for-bit;
* NaN storms stall, screening rides through — unscreened non-finite
  uploads are stopped by the aggregate finite guard (w_g freezes,
  finite), while the screened run keeps converging on the clean cohort;
* Byzantine uploads corrupt, the norm fence contains — finite divergent
  deltas sail past the finite guard and blow up ||w_g|| unscreened; the
  ``screen_max_norm`` fence (or the divergence rollback) bounds them;
* kill-at-round-r + restore == the uninterrupted run bit-for-bit, on
  every carry layout (fused dense, fused compressed cohort, sharded
  dense, sharded grouped with a pod blackout);
* the compiled sharded program keeps exactly ONE cross-client
  model-sized all-reduce per round with screening enabled.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ChannelConfig, SchedulerConfig
from repro.core.scheduler import FaultConfig
from repro.data.partition import partition_noniid
from repro.data.pipeline import build_federation
from repro.data.synthetic import make_mnist_like
from repro.fl import FLClient, FusedPAOTA, PAOTAConfig
from repro.models.mlp import init_mlp_params, mlp_loss

K = 8
# fast latencies: every client uploads every period, so faults reach the
# superposition from their start round on
FAST_SCHED = dict(n_clients=K, delta_t=8.0, lat_lo=0.5, lat_hi=3.0)


@pytest.fixture(scope="module")
def data():
    x, y, _, _ = make_mnist_like(n_train=2000, n_test=10)
    parts = partition_noniid(y, n_clients=K, seed=0)
    return x, y, parts


def _clients(data):
    x, y, parts = data
    return [FLClient(d, mlp_loss, batch_size=32, lr=0.1, local_steps=2)
            for d in build_federation(x, y, parts)]


def _params():
    return init_mlp_params(jax.random.PRNGKey(0))


def _fused(data, transmit="delta", **kw):
    return FusedPAOTA(_params(), _clients(data), ChannelConfig(),
                      SchedulerConfig(seed=1, **FAST_SCHED),
                      PAOTAConfig(transmit=transmit), **kw)


def _sharded(data, mesh, transmit="delta", **kw):
    from repro.fl import ShardedPAOTA
    return ShardedPAOTA(_params(), _clients(data), ChannelConfig(),
                        SchedulerConfig(seed=1, **FAST_SCHED),
                        PAOTAConfig(transmit=transmit), mesh=mesh, **kw)


# ---------------------------------------------------------------------------
# identity: FaultConfig() + screen off + divergence off == no fault args
# ---------------------------------------------------------------------------

def test_identity_faultconfig_is_noop_fused(data):
    plain = _fused(data)
    armed = _fused(data, faults=FaultConfig(), screen=False,
                   divergence_factor=0.0)
    for rp, ra in zip(plain.advance(3), armed.advance(3)):
        assert rp == ra
    np.testing.assert_array_equal(plain.global_vec, armed.global_vec)


@pytest.mark.multidevice
def test_identity_faultconfig_is_noop_sharded(data, client_mesh_8):
    plain = _sharded(data, client_mesh_8)
    armed = _sharded(data, client_mesh_8, faults=FaultConfig(),
                     screen=False, divergence_factor=0.0)
    for rp, ra in zip(plain.advance(3), armed.advance(3)):
        assert rp == ra
    np.testing.assert_array_equal(plain.global_vec, armed.global_vec)


# ---------------------------------------------------------------------------
# containment: screened corrupt rows == uploads dropped in transit
# ---------------------------------------------------------------------------

def test_screened_faulty_round_equals_dropped_uploads(data):
    """Acceptance: a round with faulty clients under screening produces a
    global BIT-identical to the same round in which those clients' uploads
    were lost in transit (scenario drop mask) — screening masks the rows
    out of the superposition exactly like phantoms: b zeroed, payload row
    exact +0.0, scalars sanitized, and the restart/broadcast state plane
    untouched."""
    F = jnp.array([1, 4])                        # the always-faulty clients
    screened = _fused(data, screen=True)
    base = screened._streams()

    def poisoned_train(g, x, y, r):
        tr = base.local_train(g, x, y, r)
        return jax.tree_util.tree_map(
            lambda l: l.at[F].set(jnp.nan), tr)

    screened._streams = lambda: base._replace(local_train=poisoned_train)

    dropped = _fused(data)                       # clean train, no screening
    base_d = dropped._streams()
    drop = jnp.zeros((K,), bool).at[F].set(True)
    dropped._streams = lambda: base_d._replace(
        scenario=lambda t: (jnp.ones((K,), bool), drop))

    for rs, rd in zip(screened.advance(4), dropped.advance(4)):
        np.testing.assert_array_equal(screened.global_vec,
                                      dropped.global_vec)
        assert rs["time"] == rd["time"]
    assert sum(r["n_screened"] for r in screened.history) > 0
    assert all(r["n_screened"] == 0 for r in dropped.history)


# ---------------------------------------------------------------------------
# NaN storm: unscreened stalls (finite guard), screened converges
# ---------------------------------------------------------------------------

def test_nan_storm_unscreened_stalls_screened_progresses(data):
    storm = FaultConfig(nan_frac=0.9, start=1)
    unscr = _fused(data, faults=storm)
    unscr.advance(1)                      # round 0: faults not yet active
    g1 = np.array(unscr.global_vec, copy=True)
    unscr.advance(4)
    # every active round has >= 1 NaN uploader, the aggregate finite
    # guard holds w_g bit-identically — frozen, never corrupted
    np.testing.assert_array_equal(unscr.global_vec, g1)
    assert np.isfinite(unscr.global_vec).all()

    scr = _fused(data, faults=storm, screen=True)
    scr.advance(1)
    s1 = np.array(scr.global_vec, copy=True)
    scr.advance(4)
    assert not np.array_equal(scr.global_vec, s1)     # kept converging
    assert np.isfinite(scr.global_vec).all()
    assert sum(r["n_screened"] for r in scr.history) > 0


# ---------------------------------------------------------------------------
# Byzantine: unscreened corrupts ||w_g||, the norm fence contains it
# ---------------------------------------------------------------------------

def test_byzantine_unscreened_corrupts_fence_contains(data):
    """Finite-but-divergent deltas sail past the finite guard: the
    unscreened run is demonstrably corrupted (its trajectory deviates from
    the clean run by an order of magnitude more than the norm-fenced run,
    and ||w_g|| inflates past the clean norm). The instantaneous power cap
    (7) bounds any ONE round's shift — p_k ||x_k||^2 <= P_max attenuates
    huge-norm rows — so corruption shows up as steady trajectory drift,
    not a norm explosion; the screen_max_norm fence removes it at the
    source. Model transmit: the Byzantine rows carry
    w_g + scale (w - w_g), norm ~|scale| ||delta|| >> a clean row's."""
    byz = FaultConfig(byzantine_frac=0.5, byzantine_scale=-50.0, start=1)
    clean = _fused(data, transmit="model")
    clean.advance(6)
    g_clean = np.array(clean.global_vec, copy=True)
    ref = float(np.linalg.norm(g_clean))

    unscr = _fused(data, transmit="model", faults=byz)
    unscr.advance(6)
    dev_unscr = float(np.linalg.norm(unscr.global_vec - g_clean))
    assert np.isfinite(unscr.global_vec).all()
    assert float(np.linalg.norm(unscr.global_vec)) > 1.2 * ref   # inflated
    assert dev_unscr > 0.5 * ref                                 # corrupted

    # clean model-mode payload norms sit at ~||w_g|| (~8 here); the
    # scale=-50 Byzantine rows land at 20-40 — the fence separates them
    fence = _fused(data, transmit="model", faults=byz, screen=True,
                   screen_max_norm=10.0)
    fence.advance(6)
    dev_fence = float(np.linalg.norm(fence.global_vec - g_clean))
    assert dev_fence < 0.15 * dev_unscr
    assert sum(r["n_screened"] for r in fence.history) > 0


def test_rollback_restores_last_good_on_divergence(data):
    """The second line of defense: with screening off, a one-round payload
    blowup (every round-3 local model scaled 100x — past what the power
    cap can attenuate, since EVERY uploader carries it) jumps ||w_g|| by
    orders of magnitude. Unguarded, w_g stays corrupted; with
    divergence_factor the detector fires exactly once, restores the
    last-good global, and the trajectory recovers."""
    def make(**kw):
        srv = _fused(data, transmit="model", **kw)
        base = srv._streams()

        def blowup_train(g, x, y, r):
            tr = base.local_train(g, x, y, r)
            s = jnp.where(jnp.asarray(r) == 3, jnp.float32(100.0),
                          jnp.float32(1.0))
            return jax.tree_util.tree_map(lambda l: l * s, tr)

        srv._streams = lambda: base._replace(local_train=blowup_train)
        return srv

    clean = _fused(data, transmit="model")
    clean.advance(6)
    ref = float(np.linalg.norm(clean.global_vec))

    bare = make()
    bare.advance(6)
    n_bare = float(np.linalg.norm(bare.global_vec))
    assert np.isfinite(n_bare) and n_bare > 5.0 * ref    # stays corrupted

    guard = make(divergence_factor=4.0)
    guard.advance(6)
    rolled = [r["rolled_back"] for r in guard.history]
    assert sum(rolled) == 1.0 and rolled[3] == 1.0
    n_guard = float(np.linalg.norm(guard.global_vec))
    assert np.isfinite(n_guard) and n_guard < 2.0 * ref  # recovered


# ---------------------------------------------------------------------------
# kill-at-round-r + restore == uninterrupted, on every carry layout
# ---------------------------------------------------------------------------

_FAULTS = FaultConfig(nan_frac=0.25, byzantine_frac=0.25, deep_fade_frac=0.2)


def _resume_roundtrip(make, tmp_path, n=4, r=2):
    """full-run vs save-at-r + fresh-driver restore + finish: bit-exact."""
    full = make()
    full.advance(n)
    part = make()
    part.advance(r)
    path = str(tmp_path / "kill.npz")
    part.save_checkpoint(path)
    res = make()                      # fresh driver, never advanced
    assert res.restore_checkpoint(path) == r
    res.advance(n - r)
    np.testing.assert_array_equal(full.global_vec, res.global_vec)
    assert len(res.history) == n
    for rf, rr in zip(full.history, res.history):
        assert rf == rr


def test_resume_bit_exact_fused_dense(data, tmp_path):
    _resume_roundtrip(
        lambda: _fused(data, faults=_FAULTS, screen=True,
                       divergence_factor=4.0), tmp_path)


def test_resume_bit_exact_fused_compressed_cohort(data, tmp_path):
    _resume_roundtrip(
        lambda: _fused(data, faults=_FAULTS, screen=True, cohort_size=4,
                       compress="topk", compress_ratio=0.25,
                       slot_dtype="int8"), tmp_path)


@pytest.mark.multidevice
def test_resume_bit_exact_sharded_dense(data, client_mesh_8, tmp_path):
    _resume_roundtrip(
        lambda: _sharded(data, client_mesh_8, faults=_FAULTS, screen=True),
        tmp_path)


@pytest.mark.multidevice
def test_resume_bit_exact_sharded_grouped_blackout(data, pod_mesh_2x4,
                                                   tmp_path):
    """Grouped carry (held partials) + a pod blackout across the kill
    point: the restored run must replay the blackout window identically."""
    blk = FaultConfig(nan_frac=0.2, pod_blackout=(0,), blackout_start=1,
                      blackout_stop=3)
    _resume_roundtrip(
        lambda: _sharded(data, pod_mesh_2x4, faults=blk, screen=True,
                         group_period=2), tmp_path)


# ---------------------------------------------------------------------------
# compiled structure: screening keeps ONE cross-client all-reduce
# ---------------------------------------------------------------------------

@pytest.mark.multidevice
def test_screened_hlo_single_model_sized_allreduce(data, client_mesh_8):
    """Structural acceptance: with faults + screening enabled the sharded
    round body still compiles to exactly ONE cross-client model-sized
    all-reduce — containment is shard-local masking BEFORE the psum, never
    a second collective. (d = 8070 for the test MLP; the 4097 floor sits
    above the 4096-wide water-filling grid psum and every metric.)"""
    from repro.launch.collectives import axis_crossing_allreduce_count
    srv = _sharded(data, client_mesh_8, faults=_FAULTS, screen=True)
    hlo = srv.compiled_scan_hlo(1)
    shape = tuple(srv.mesh.shape[a] for a in srv.mesh.axis_names)
    assert axis_crossing_allreduce_count(hlo, shape, (0,),
                                         min_elements=4097) == 1
