"""Semantics of the SPMD PAOTA round step (launch.steps) on a 1x1 CPU mesh:
the aggregation must equal eq. (8) exactly, stragglers must keep their
local training state (eq. 4), and grad accumulation must not change the
SGD result."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.launch.shapes import InputShape
from repro.launch.steps import make_paota_train_step
from repro.models import init_model
from repro.models.transformer import loss_fn

pytestmark = pytest.mark.slow  # arch-zoo/serving/integration tier (scripts/ci.sh)


def _mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


def _setup(arch="smollm-135m", k=3, m=2, mb=2, seq=32, sigma=0.0):
    cfg = get_reduced(arch)
    shape = InputShape("t", seq_len=seq, global_batch=k * mb, kind="train")
    mesh = _mesh11()
    with mesh:
        step, structs, _ = make_paota_train_step(
            cfg, mesh, shape, lr=0.05, local_steps=m,
            sigma_over_varsigma=sigma, client_axes=("data",), donate=False)
    params = init_model(jax.random.PRNGKey(0), cfg)
    stacked = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (1,) + x.shape), params)
    # K=1 on the 1x1 mesh; emulate K clients by running the pure function
    return cfg, shape, mesh, step, params


def test_round_step_aggregation_matches_eq8():
    """Run the un-jitted round math with K=3 clients and compare the masked
    power-weighted aggregate against a hand computation."""
    cfg = get_reduced("smollm-135m")
    k, m, mb, seq = 3, 2, 2, 32
    shape = InputShape("t", seq_len=seq, global_batch=k * mb, kind="train")
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (k, m, mb, seq)),
                       jnp.int32)
    powers = jnp.asarray([2.0, 3.0, 5.0], jnp.float32)
    mask = jnp.asarray([1.0, 0.0, 1.0], jnp.float32)   # client 1 straggles
    seed = jax.random.key_data(jax.random.PRNGKey(0)).astype(jnp.uint32)

    params = init_model(jax.random.PRNGKey(1), cfg)
    stacked = jax.tree_util.tree_map(
        lambda x: jnp.stack([x, x * 1.01, x * 0.99]), params)

    # reference semantics: manual per-client local SGD
    def local_sgd(p, mbs):
        for i in range(m):
            sub = {"tokens": mbs[i]}
            g = jax.grad(lambda q: loss_fn(q, sub, cfg)[0])(p)
            p = jax.tree_util.tree_map(lambda a, b: a - 0.05 * b, p, g)
        return p

    trained = [local_sgd(jax.tree_util.tree_map(lambda x: x[i], stacked),
                         toks[i]) for i in range(k)]
    bp = np.asarray(powers * mask)
    varsigma = bp.sum()

    def agg(*leaves):
        return sum(b * l for b, l in zip(bp, leaves)) / varsigma

    expected_agg = jax.tree_util.tree_map(agg, *trained)
    # validate the aggregation rule (eq. 8) against the stacked form used
    # by the jitted step:
    from repro.core.aggregation import paota_aggregate_stacked
    flat_trained = [jax.flatten_util.ravel_pytree(t)[0] for t in trained]
    stacked_vec = jnp.stack(flat_trained)
    got, vs = paota_aggregate_stacked(stacked_vec, powers, mask,
                                      jax.random.PRNGKey(0), 0.0)
    want_vec = jax.flatten_util.ravel_pytree(expected_agg)[0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want_vec),
                               rtol=2e-5, atol=2e-5)
    assert float(vs) == pytest.approx(float(varsigma))


def test_jitted_round_step_runs_and_improves_loss():
    cfg, shape, mesh, step, params = _setup()
    k, m, mb, seq = 1, 2, 6, 32
    rng = np.random.default_rng(0)
    stacked = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (1,) + x.shape), params)
    shape1 = InputShape("t", seq_len=seq, global_batch=mb, kind="train")
    with mesh:
        step1, structs, _ = make_paota_train_step(
            cfg, mesh, shape1, lr=0.05, local_steps=m,
            sigma_over_varsigma=0.0, client_axes=("data",), donate=False)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, m, mb, seq)),
                       jnp.int32)
    powers = jnp.ones((1,), jnp.float32)
    mask = jnp.ones((1,), jnp.float32)
    seed = jax.random.key_data(jax.random.PRNGKey(0)).astype(jnp.uint32)
    losses = []
    with mesh:
        for r in range(4):
            stacked, metrics = step1(stacked, {"tokens": toks}, powers, mask,
                                     seed)
            losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0]
    assert metrics["participants"] == 1


def test_grad_accum_equivalent_to_full_batch():
    """accum chunks of the same batch must produce (nearly) the same SGD
    update as the unchunked step (bf16 accumulation tolerance)."""
    cfg = get_reduced("olmo-1b")
    rng = np.random.default_rng(1)
    mb, seq = 8, 64
    toks = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (mb, seq)),
                                  jnp.int32)}
    params = init_model(jax.random.PRNGKey(0), cfg)
    g_full = jax.grad(lambda p: loss_fn(p, toks, cfg)[0])(params)

    accum = 4
    sub = jax.tree_util.tree_map(
        lambda x: x.reshape((accum, mb // accum) + x.shape[1:]), toks)
    g_acc = jax.tree_util.tree_map(jnp.zeros_like, params)
    for i in range(accum):
        chunk = jax.tree_util.tree_map(lambda x: x[i], sub)
        g = jax.grad(lambda p: loss_fn(p, chunk, cfg)[0])(params)
        g_acc = jax.tree_util.tree_map(lambda a, b: a + b, g_acc, g)
    g_acc = jax.tree_util.tree_map(lambda x: x / accum, g_acc)
    err = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(jax.tree_util.tree_leaves(g_full),
                              jax.tree_util.tree_leaves(g_acc)))
    assert err < 5e-3
