"""Compressed cohort payloads: sparsification + error feedback + int8
slot storage + the gather-superpose-decompress kernel.

Claims under test:

* error feedback is EXACT bookkeeping: ``residual + scatter(transmitted)
  == original`` bit-for-bit in f32, for both top-k and random-mask
  supports (hypothesis property over shapes/seeds);
* int8 stochastic rounding is unbiased: the dequantized mean over many
  counter keys converges to the input;
* the Pallas gather-superpose kernel (interpret mode), the jnp twin in
  ``ops.gather_superpose``, and the dense reference oracle agree on
  non-divisible shapes, with and without the int8 scale fold, and the
  varsigma they emit is the RAW sum of b*p;
* ``compressed_round_stats`` equals the dense stats computed on the
  scattered reconstructions;
* the driver-level EF hand-off is invariant under slot permutation: the
  (K,) state plane and the parked (K, s) residual planes advance
  bit-identically, the global model allclose.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp_compat import given, settings, st

from repro.core import ChannelConfig, SchedulerConfig
from repro.core.compress import (dequantize_int8, ef_residual, gather_rows,
                                 quantize_int8_stochastic, randmask_indices,
                                 scatter_rows, sparsify, topk_support)
from repro.data.partition import partition_noniid
from repro.data.pipeline import build_federation
from repro.data.synthetic import make_mnist_like
from repro.fl import FLClient, FusedPAOTA, PAOTAConfig
from repro.kernels.aircomp_sum import gather_superpose_pallas
from repro.kernels.ref import gather_superpose_ref
from repro.kernels.round_stats import compressed_round_stats
from repro.models.mlp import init_mlp_params, mlp_loss


def _plane(seed, m, d):
    return jax.random.normal(jax.random.PRNGKey(seed), (m, d), jnp.float32)


# ---------------------------------------------------------------------------
# error feedback is exact bookkeeping (hypothesis property)
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 6), st.integers(2, 64),
       st.sampled_from(["topk", "randmask"]))
def test_residual_plus_transmitted_is_original(seed, m, d, scheme):
    s = max(1, d // 3)
    comp = _plane(seed, m, d)
    if scheme == "topk":
        idx = topk_support(comp, s)
    else:
        idx = jnp.broadcast_to(
            randmask_indices(jax.random.PRNGKey(seed + 1), d, s), (m, s))
    v = gather_rows(comp, idx)
    e = ef_residual(comp, idx, v)
    recon = np.asarray(e + scatter_rows(v, idx, d))
    # EXACT: the residual is the in-place f32 complement, not a subtraction
    # of a rebuilt plane — bit-for-bit equality is the contract
    np.testing.assert_array_equal(recon, np.asarray(comp))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_residual_exact_through_int8(seed):
    """The residual absorbs the quantization error too: with int8 slot
    storage the complement is taken against the DEQUANTIZED values, so
    residual + scatter(dequant(q)) still reconstructs exactly."""
    m, d, s = 3, 48, 12
    comp = _plane(seed, m, d)
    idx = topk_support(comp, s)
    v = gather_rows(comp, idx)
    q, scale = quantize_int8_stochastic(v, jax.random.PRNGKey(seed + 7))
    v_hat = dequantize_int8(q, scale)
    e = ef_residual(comp, idx, v_hat)
    recon = np.asarray(e + scatter_rows(v_hat, idx, d))
    np.testing.assert_array_equal(recon, np.asarray(comp))


def test_sparsify_keeps_largest():
    e = jnp.asarray([[0.0, -5.0, 1.0, 3.0, -0.5]])
    vals, idx = sparsify(e, 2)
    assert set(np.asarray(idx)[0].tolist()) == {1, 3}
    np.testing.assert_array_equal(
        np.asarray(scatter_rows(vals, idx, 5))[0],
        np.asarray([0.0, -5.0, 0.0, 3.0, 0.0]))


# ---------------------------------------------------------------------------
# int8 stochastic rounding: unbiased, bounded error
# ---------------------------------------------------------------------------

def test_int8_stochastic_rounding_unbiased():
    m, s, n_keys = 4, 64, 400
    v = _plane(3, m, s)
    base = jax.random.PRNGKey(42)

    def dequant(i):
        q, scale = quantize_int8_stochastic(v, jax.random.fold_in(base, i))
        return dequantize_int8(q, scale)

    mean = np.mean(jax.vmap(dequant)(jnp.arange(n_keys)), axis=0)
    scale = np.abs(np.asarray(v)).max(axis=1, keepdims=True) / 127.0
    # one draw errs < scale; the mean of n_keys unbiased draws concentrates
    np.testing.assert_allclose(mean, np.asarray(v), atol=float(scale.max()) * 0.2)


def test_int8_rounding_error_bounded_by_one_step():
    v = _plane(5, 2, 128)
    q, scale = quantize_int8_stochastic(v, jax.random.PRNGKey(0))
    err = np.abs(np.asarray(dequantize_int8(q, scale)) - np.asarray(v))
    assert (err <= np.asarray(scale)[:, None] * (1 + 1e-6)).all()


# ---------------------------------------------------------------------------
# gather-superpose kernel vs twin vs dense oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype,with_scale", [
    ("float32", False), ("bfloat16", False), ("int8", True)])
def test_gather_superpose_matches_reference(dtype, with_scale):
    m, d, s = 5, 1000, 37          # d not a multiple of block_d, m*s odd
    key = jax.random.PRNGKey(9)
    comp = _plane(11, m, d)
    idx = topk_support(comp, s)
    vals = gather_rows(comp, idx)
    scale = None
    if with_scale:
        vals, scale = quantize_int8_stochastic(vals, key)
    else:
        vals = vals.astype(jnp.dtype(dtype))
    bp = jax.random.uniform(jax.random.fold_in(key, 1), (m,), jnp.float32)
    noise = jax.random.normal(jax.random.fold_in(key, 2), (d,), jnp.float32)
    agg_ref, vs_ref = gather_superpose_ref(vals, idx, bp, noise, d,
                                           scale=scale)
    agg_k, vs_k = gather_superpose_pallas(vals, idx, bp, noise, d=d,
                                          scale=scale, block_d=256,
                                          block_n=64, interpret=True)
    from repro.kernels import ops
    agg_t, vs_t = ops.gather_superpose(vals, idx, bp, noise, d=d,
                                       scale=scale)
    # varsigma is the RAW sum of b*p — the int8 scale must NOT leak in
    np.testing.assert_allclose(float(vs_k), float(np.sum(np.asarray(bp))),
                               rtol=1e-6)
    np.testing.assert_allclose(float(vs_t), float(vs_ref), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(agg_k), np.asarray(agg_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(agg_t), np.asarray(agg_ref),
                               rtol=1e-5, atol=1e-5)


def test_gather_superpose_masked_rows_do_not_contribute():
    m, d, s = 4, 300, 16
    comp = _plane(13, m, d)
    idx = topk_support(comp, s)
    vals = gather_rows(comp, idx)
    bp = jnp.asarray([0.7, 0.0, 1.3, 0.0])      # rows 1 and 3 masked out
    noise = jnp.zeros((d,), jnp.float32)
    agg, vs = gather_superpose_ref(vals, idx, bp, noise, d)
    dense = np.asarray(scatter_rows(vals, idx, d))
    want = (0.7 * dense[0] + 1.3 * dense[2]) / 2.0
    np.testing.assert_allclose(np.asarray(agg), want, rtol=1e-6, atol=1e-7)
    assert float(vs) == pytest.approx(2.0)


# ---------------------------------------------------------------------------
# compressed round stats vs dense stats on the reconstructions
# ---------------------------------------------------------------------------

def test_compressed_round_stats_match_dense():
    m, d, s = 6, 500, 50
    comp = _plane(17, m, d)
    idx = topk_support(comp, s)
    vals = gather_rows(comp, idx)
    resid = ef_residual(comp, idx, vals)
    r_vals, r_idx = sparsify(resid, s)
    g = jax.random.normal(jax.random.PRNGKey(23), (d,), jnp.float32)
    dots, dn2, pn2, gn2 = compressed_round_stats(vals, idx, r_vals, r_idx,
                                                 g)
    dense_v = np.asarray(scatter_rows(vals, idx, d))
    dense_r = np.asarray(scatter_rows(r_vals, r_idx, d))
    g_np = np.asarray(g)
    np.testing.assert_allclose(np.asarray(dots),
                               (dense_v + dense_r) @ g_np, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(dn2),
                               (dense_v ** 2).sum(1) + (dense_r ** 2).sum(1),
                               rtol=1e-5)
    # pn2 is the TRANSMITTED energy only — what constraint (7) caps
    np.testing.assert_allclose(np.asarray(pn2), (dense_v ** 2).sum(1),
                               rtol=1e-5)
    np.testing.assert_allclose(float(gn2), float(g_np @ g_np), rtol=1e-6)


# ---------------------------------------------------------------------------
# driver-level EF hand-off: slot-permutation invariance
# ---------------------------------------------------------------------------

K = 8


@functools.lru_cache(maxsize=1)
def _ef_fixture():
    """A mid-flight compressed cohort carry (f32 slots — int8 dither is
    position-keyed, so only the exactly-stored dtypes are permutation
    invariant) + a non-donating one-step runner."""
    x, y, _, _ = make_mnist_like(n_train=1500, n_test=10)
    parts = partition_noniid(y, n_clients=K, seed=0)
    clients = [FLClient(d, mlp_loss, batch_size=32, lr=0.1, local_steps=5)
               for d in build_federation(x, y, parts)]
    srv = FusedPAOTA(init_mlp_params(jax.random.PRNGKey(0)), clients,
                     ChannelConfig(), SchedulerConfig(n_clients=K, seed=1),
                     PAOTAConfig(transmit="delta"), cohort_size=4,
                     compress="topk", compress_ratio=0.25, donate=False)
    srv.advance(3)
    step = lambda c: srv._jit_scan(c, srv.engine._x, srv.engine._y,
                                   n_rounds=1)
    return srv, step


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 100_000))
def test_ef_handoff_invariant_under_slot_permutation(seed):
    srv, step = _ef_fixture()
    carry = srv._carry
    perm = jnp.asarray(np.random.default_rng(seed).permutation(4))
    permuted = carry._replace(
        slot_client=carry.slot_client[perm],
        slot_live=carry.slot_live[perm],
        deltas=carry.deltas[perm],
        slot_idx=carry.slot_idx[perm],
        slot_resid=carry.slot_resid[perm],
        slot_resid_idx=carry.slot_resid_idx[perm])
    c1, o1 = step(carry)
    c2, o2 = step(permuted)
    # (K,) state plane: bit-identical
    for f in ("ready", "busy_lat", "model_round"):
        np.testing.assert_array_equal(np.asarray(getattr(c1, f)),
                                      np.asarray(getattr(c2, f)))
    # parked residuals index by CLIENT id, not slot: the hand-off scatter
    # lands each departing slot's residual on the same (K, s) row whatever
    # order the slots sit in — bit-identical
    np.testing.assert_array_equal(np.asarray(c1.resid_val),
                                  np.asarray(c2.resid_val))
    s1 = set(np.asarray(c1.slot_client)[np.asarray(c1.slot_live)].tolist())
    s2 = set(np.asarray(c2.slot_client)[np.asarray(c2.slot_live)].tolist())
    assert s1 == s2
    np.testing.assert_allclose(np.asarray(c1.global_vec),
                               np.asarray(c2.global_vec),
                               rtol=1e-4, atol=1e-5)
    assert float(o1["n_participants"][0]) == \
        pytest.approx(float(o2["n_participants"][0]))
