"""Bench differ: >2x wall-clock regressions vs the previous artifacts
fail; noise-floor micro rows, cross-environment baselines, and
improvements pass."""
import json
import os

from benchmarks.diff import diff_artifacts, load_artifacts, main


def _write(d, name, rows, **extra):
    with open(os.path.join(d, f"BENCH_{name}.json"), "w") as f:
        json.dump({"name": name, "rows": rows, **extra}, f)


def test_diff_flags_only_real_regressions(tmp_path):
    base, cur = tmp_path / "base", tmp_path / "cur"
    base.mkdir(), cur.mkdir()
    _write(base, "roundbench", [
        {"name": "big", "us_per_call": 50_000.0},
        {"name": "ok", "us_per_call": 20_000.0},
        {"name": "tiny", "us_per_call": 50.0},
    ])
    _write(cur, "roundbench", [
        {"name": "big", "us_per_call": 150_000.0},   # 3x -> regression
        {"name": "ok", "us_per_call": 25_000.0},     # 1.25x -> fine
        {"name": "tiny", "us_per_call": 900.0},      # 18x but < noise floor
        {"name": "new_row", "us_per_call": 1.0},     # no baseline -> skip
    ])
    report, regressions = diff_artifacts(
        load_artifacts(str(base)), load_artifacts(str(cur)),
        ratio=2.0, min_us=1000.0)
    assert len(report) == 4
    assert [(a, n) for a, n, *_ in regressions] == [("roundbench", "big")]
    new = [r for r in report if r[1] == "new_row"]
    assert len(new) == 1 and new[0][-1] == "new (no baseline)"


def test_diff_tolerates_newly_added_series(tmp_path):
    """A brand-new artifact (or row) with no baseline must be reported as
    new, never failed — first introduction of a tracked series."""
    base, cur = tmp_path / "base", tmp_path / "cur"
    base.mkdir(), cur.mkdir()
    # "ratio" is a pre-existing 0-value sentinel row (speedup ratios are
    # encoded in `derived`, us_per_call=0): never comparable, never "new"
    _write(base, "old", [{"name": "r", "us_per_call": 10_000.0},
                         {"name": "ratio", "us_per_call": 0.0}])
    _write(cur, "old", [{"name": "r", "us_per_call": 11_000.0},
                        {"name": "ratio", "us_per_call": 0.0}])
    _write(cur, "brand_new", [{"name": "a", "us_per_call": 99_000.0},
                              {"name": "b", "us_per_call": 1.0}])
    report, regressions = diff_artifacts(
        load_artifacts(str(base)), load_artifacts(str(cur)),
        ratio=2.0, min_us=1000.0)
    assert not regressions
    flags = {(a, n): f for a, n, _, _, _, f in report}
    assert flags[("brand_new", "a")] == "new (no baseline)"
    assert flags[("brand_new", "b")] == "new (no baseline)"
    assert ("old", "ratio") not in flags
    assert main(["--baseline", str(base), "--current", str(cur)]) == 0


def test_diff_skips_cross_environment_baselines(tmp_path):
    """A baseline recorded on a different backend/device count reports
    but never fails — absolute wall clocks aren't comparable."""
    base, cur = tmp_path / "base", tmp_path / "cur"
    base.mkdir(), cur.mkdir()
    _write(base, "a", [{"name": "r", "us_per_call": 10_000.0}],
           backend="cpu", device_count=8)
    _write(cur, "a", [{"name": "r", "us_per_call": 90_000.0}],
           backend="cpu", device_count=1)
    report, regressions = diff_artifacts(
        load_artifacts(str(base)), load_artifacts(str(cur)),
        ratio=2.0, min_us=1000.0)
    assert len(report) == 1 and not regressions
    assert "env mismatch" in report[0][-1]
    # same env -> the same 9x row fails
    _write(cur, "a", [{"name": "r", "us_per_call": 90_000.0}],
           backend="cpu", device_count=8)
    _, regressions = diff_artifacts(
        load_artifacts(str(base)), load_artifacts(str(cur)),
        ratio=2.0, min_us=1000.0)
    assert len(regressions) == 1


def test_diff_cli_exit_codes(tmp_path):
    base, cur = tmp_path / "base", tmp_path / "cur"
    base.mkdir(), cur.mkdir()
    _write(base, "a", [{"name": "r", "us_per_call": 10_000.0}])
    _write(cur, "a", [{"name": "r", "us_per_call": 12_000.0}])
    assert main(["--baseline", str(base), "--current", str(cur)]) == 0
    _write(cur, "a", [{"name": "r", "us_per_call": 30_000.0}])
    assert main(["--baseline", str(base), "--current", str(cur)]) == 1
    # an artifact only in the baseline (e.g. a renamed bench) is not an error
    _write(base, "gone", [{"name": "r", "us_per_call": 5_000.0}])
    assert main(["--baseline", str(base), "--current", str(cur)]) == 1
    assert main(["--baseline", str(tmp_path / "missing"),
                 "--current", str(cur)]) == 2
