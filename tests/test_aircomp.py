"""AirComp channel/aggregation tests: eq. 5-8 semantics, weight simplex,
noise scaling, masked stragglers, Pallas-kernel path equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp_compat import given, settings, st

from repro.core.aircomp import (ChannelConfig, aggregation_weights,
                                aircomp_aggregate, dbm_per_hz_to_watts,
                                effective_power_cap, sample_channel_gains)

RNG = np.random.default_rng(0)


def test_noise_psd_conversion():
    # -174 dBm/Hz * 20 MHz -> ~8e-14 W (thermal noise floor)
    chan = ChannelConfig()
    assert chan.sigma_n2 == pytest.approx(20e6 * dbm_per_hz_to_watts(-174.0))
    assert chan.sigma_n2 == pytest.approx(7.96e-14, rel=0.01)


def test_aggregation_weights_simplex():
    p = jnp.asarray(RNG.random(10).astype(np.float32)) * 15
    b = jnp.asarray((RNG.random(10) < 0.6).astype(np.float32))
    if float(b.sum()) == 0:
        b = b.at[0].set(1.0)
    a = aggregation_weights(p, b)
    assert float(jnp.sum(a)) == pytest.approx(1.0, abs=1e-5)
    assert np.all(np.asarray(a)[np.asarray(b) == 0] == 0)


def test_noiseless_aggregate_is_weighted_mean():
    x = jnp.asarray(RNG.normal(size=(5, 64)).astype(np.float32))
    p = jnp.asarray([1.0, 2, 3, 4, 5], jnp.float32)
    b = jnp.asarray([1.0, 1, 0, 1, 1], jnp.float32)
    agg, varsigma = aircomp_aggregate(x, p, b, jax.random.PRNGKey(0), 0.0)
    want = (1 * x[0] + 2 * x[1] + 4 * x[3] + 5 * x[4]) / 12.0
    np.testing.assert_allclose(np.asarray(agg), np.asarray(want), rtol=1e-5)
    assert float(varsigma) == pytest.approx(12.0)


def test_kernel_path_matches_jnp_path():
    x = jnp.asarray(RNG.normal(size=(7, 300)).astype(np.float32))
    p = jnp.asarray(RNG.random(7).astype(np.float32))
    b = jnp.ones(7, jnp.float32)
    key = jax.random.PRNGKey(3)
    a1, _ = aircomp_aggregate(x, p, b, key, 0.01, use_kernel=False)
    a2, _ = aircomp_aggregate(x, p, b, key, 0.01, use_kernel=True)
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2), rtol=2e-4,
                               atol=2e-4)


def test_equivalent_noise_shrinks_with_total_power():
    """Term (e): more total transmit power -> less equivalent noise. This is
    WHY the optimizer pushes powers up against the numerator penalty."""
    x = jnp.asarray(RNG.normal(size=(4, 2048)).astype(np.float32))
    b = jnp.ones(4, jnp.float32)
    key = jax.random.PRNGKey(0)
    lo, _ = aircomp_aggregate(x, jnp.full(4, 1.0), b, key, 1.0)
    hi, _ = aircomp_aggregate(x, jnp.full(4, 100.0), b, key, 1.0)
    mean = jnp.mean(x, axis=0)
    assert float(jnp.linalg.norm(hi - mean)) < float(jnp.linalg.norm(lo - mean))


def test_power_cap_eq7():
    w2 = jnp.asarray([4.0, 100.0])
    h = jnp.asarray([1.0, 0.5])
    cap = np.asarray(effective_power_cap(w2, h, p_max=16.0))
    # p <= |h| sqrt(P/||w||^2)
    np.testing.assert_allclose(cap, [1.0 * 2.0, 0.5 * 0.4], rtol=1e-6)


def test_rayleigh_channel_stats():
    h = np.asarray(sample_channel_gains(jax.random.PRNGKey(0), 20000,
                                        ChannelConfig()))
    # Rayleigh(1): mean = sqrt(pi/2)
    assert h.mean() == pytest.approx(np.sqrt(np.pi / 2), rel=0.03)
    assert h.min() > 0


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 16), st.integers(4, 128), st.integers(0, 1000))
def test_aggregate_convexity_property(k, d, seed):
    """Noiseless aggregate lies in the convex hull of the inputs: for every
    coordinate, min_k x <= agg <= max_k x."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(k, d)).astype(np.float32))
    p = jnp.asarray(rng.random(k).astype(np.float32) + 0.01)
    b = jnp.ones(k, jnp.float32)
    agg, _ = aircomp_aggregate(x, p, b, jax.random.PRNGKey(0), 0.0)
    xn = np.asarray(x)
    assert np.all(np.asarray(agg) <= xn.max(0) + 1e-4)
    assert np.all(np.asarray(agg) >= xn.min(0) - 1e-4)
