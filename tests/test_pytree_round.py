"""Pytree-native PAOTA round core.

The federated model is an arbitrary params pytree; the raveled federation
is its single-leaf instance. Pinned here:

* pytree-vs-raveled equivalence — the MLP federated as its natural 4-leaf
  (3 layers x {w, b}) params tree is allclose to the raveled fused
  reference round for round (identical RNG draws — latency, channel,
  minibatch plans, and ONE flat AWGN realization split across leaves —
  float reduction regrouping across leaves the only difference), fused
  AND sharded;
* phantom-pad invariance — a K the client-axis extent does not divide
  pads with masked phantom clients and reproduces the unsharded
  trajectory draw for draw;
* a transformer-config client federation (minicpm-2b reduced) completes
  sharded PAOTA rounds on the forced 8-device mesh with its params
  carried natively (leaves placed by stack_client_specs).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ChannelConfig, SchedulerConfig
from repro.data.partition import partition_noniid
from repro.data.pipeline import ClientData, build_federation
from repro.data.synthetic import make_mnist_like
from repro.fl import FLClient, FusedPAOTA, PAOTAConfig, ShardedPAOTA
from repro.models.mlp import init_mlp_params, mlp_loss

K = 8


@pytest.fixture(scope="module")
def data():
    x, y, _, _ = make_mnist_like(n_train=2000, n_test=10)
    parts = partition_noniid(y, n_clients=K, seed=0)
    return x, y, parts


def _clients(data, k=None):
    x, y, parts = data
    if k is not None:
        parts = partition_noniid(y, n_clients=k, seed=0)
    return [FLClient(d, mlp_loss, batch_size=32, lr=0.1, local_steps=5)
            for d in build_federation(x, y, parts)]


def _params():
    return init_mlp_params(jax.random.PRNGKey(0))


def _cfg(k, **kw):
    return (ChannelConfig(), SchedulerConfig(n_clients=k, seed=1, **kw),
            PAOTAConfig())


# ---------------------------------------------------------------------------
# tree helper units
# ---------------------------------------------------------------------------

def test_tree_scalars_match_raveled():
    """client norms / dots / cosines over a multi-leaf stacked tree equal
    the raveled single-leaf computation (same model, different leaf
    split)."""
    from jax.flatten_util import ravel_pytree

    from repro.core.power_control import (client_dots, client_sq_norms,
                                          cosine_similarity)
    key = jax.random.PRNGKey(0)
    tree = {"a": jax.random.normal(key, (6, 3, 4)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (6, 5))}
    vec = {"a": jax.random.normal(jax.random.fold_in(key, 2), (3, 4)),
           "b": jax.random.normal(jax.random.fold_in(key, 3), (5,))}
    flat = jnp.stack([ravel_pytree(
        jax.tree_util.tree_map(lambda l: l[i], tree))[0] for i in range(6)])
    gvec = ravel_pytree(vec)[0]
    np.testing.assert_allclose(np.asarray(client_sq_norms(tree)),
                               np.asarray(client_sq_norms(flat)), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(client_dots(tree, vec)),
                               np.asarray(client_dots(flat, gvec)),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(cosine_similarity(tree, vec)),
                               np.asarray(cosine_similarity(flat, gvec)),
                               rtol=1e-5, atol=1e-6)


def test_tree_aggregate_noise_is_leaf_split_invariant():
    """paota_aggregate_stacked draws ONE flat AWGN realization: the
    multi-leaf aggregate equals the raveled aggregate bit-for-bit modulo
    the per-leaf reduction split (same noise, same normalizer)."""
    from jax.flatten_util import ravel_pytree

    from repro.core.aggregation import paota_aggregate_stacked
    key = jax.random.PRNGKey(7)
    tree = {"a": jax.random.normal(key, (5, 4)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (5, 2, 3))}
    flat = jnp.stack([ravel_pytree(
        jax.tree_util.tree_map(lambda l: l[i], tree))[0] for i in range(5)])
    powers = jnp.asarray([1.0, 0.5, 2.0, 0.0, 3.0])
    mask = jnp.asarray([1.0, 1.0, 0.0, 1.0, 1.0])
    nkey = jax.random.PRNGKey(11)
    agg_t, vs_t = paota_aggregate_stacked(tree, powers, mask, nkey, 0.3)
    agg_f, vs_f = paota_aggregate_stacked(flat, powers, mask, nkey, 0.3)
    assert float(vs_t) == pytest.approx(float(vs_f), rel=1e-6)
    np.testing.assert_allclose(np.asarray(ravel_pytree(agg_t)[0]),
                               np.asarray(agg_f), rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# fused pytree mode (single device)
# ---------------------------------------------------------------------------

def test_pytree_fused_matches_raveled_over_rounds(data):
    """Acceptance: the MLP federated as its params pytree is allclose to
    the raveled fused reference round for round over 4 rounds."""
    rav = FusedPAOTA(_params(), _clients(data), *_cfg(K))
    tre = FusedPAOTA(_params(), _clients(data), *_cfg(K),
                     params_mode="pytree")
    assert len(jax.tree_util.tree_leaves(tre.global_params())) >= 4
    for rf, rt in zip(rav.advance(4), tre.advance(4)):
        assert rf["n_participants"] == rt["n_participants"]
        assert rf["time"] == rt["time"]
        assert rf["varsigma"] == pytest.approx(rt["varsigma"], rel=1e-5)
        np.testing.assert_allclose(rav.global_vec, tre.global_vec,
                                   rtol=1e-4, atol=1e-5)


def test_pytree_fused_zero_uploader_holds_global(data):
    """The zero-uploader guard holds every leaf bit-identical."""
    tre = FusedPAOTA(_params(), _clients(data), ChannelConfig(),
                     SchedulerConfig(n_clients=K, seed=1, delta_t=8.0,
                                     lat_lo=30.0, lat_hi=40.0),
                     PAOTAConfig(), params_mode="pytree")
    g0 = tre.global_vec.copy()
    rows = tre.advance(3)
    assert all(r["n_participants"] == 0 for r in rows)
    np.testing.assert_array_equal(tre.global_vec, g0)


def test_fused_rejects_unknown_params_mode(data):
    with pytest.raises(ValueError, match="params_mode"):
        FusedPAOTA(_params(), _clients(data), *_cfg(K), params_mode="tree")


# ---------------------------------------------------------------------------
# sharded pytree mode + phantom padding (forced 8-device mesh)
# ---------------------------------------------------------------------------

@pytest.mark.multidevice
def test_pytree_sharded_matches_raveled_fused(data, client_mesh_8):
    """Acceptance: the pytree MLP federation, sharded over the 8-device
    client mesh (stack_client_specs placement with cfg=None), is allclose
    to the raveled single-device fused reference over 4 rounds."""
    rav = FusedPAOTA(_params(), _clients(data), *_cfg(K))
    tre = ShardedPAOTA(_params(), _clients(data), *_cfg(K),
                       mesh=client_mesh_8, params_mode="pytree")
    for rf, rt in zip(rav.advance(4), tre.advance(4)):
        assert rf["n_participants"] == rt["n_participants"]
        assert rf["varsigma"] == pytest.approx(rt["varsigma"], rel=1e-5)
    np.testing.assert_allclose(rav.global_vec, tre.global_vec,
                               rtol=1e-4, atol=1e-5)


@pytest.mark.multidevice
@pytest.mark.parametrize("params_mode", ["raveled", "pytree"])
def test_phantom_pad_invariance(data, client_mesh_8, params_mode):
    """K=10 on 8 shards (pad to 16 with 6 phantoms) reproduces the K=10
    unsharded fused trajectory draw for draw: phantoms are never ready,
    never power, and never enter a psum or metric."""
    k = 10
    fused = FusedPAOTA(_params(), _clients(data, k), *_cfg(k))
    shard = ShardedPAOTA(_params(), _clients(data, k), *_cfg(k),
                         mesh=client_mesh_8, params_mode=params_mode)
    assert (shard.k, shard.k_pad, shard.n_phantom, shard.k_local) \
        == (10, 16, 6, 2)
    for rf, rs in zip(fused.advance(5), shard.advance(5)):
        assert rf["n_participants"] == rs["n_participants"]
        assert rf["time"] == rs["time"]
        assert rf["mean_staleness"] == pytest.approx(rs["mean_staleness"],
                                                     rel=1e-5)
        assert rf["varsigma"] == pytest.approx(rs["varsigma"], rel=1e-5)
    np.testing.assert_allclose(fused.global_vec, shard.global_vec,
                               rtol=1e-4, atol=1e-5)


@pytest.mark.multidevice
def test_pytree_sharded_rejects_nontrivial_model_axis(data):
    """A mesh whose non-client, non-TP axes have extent > 1 must refuse
    pytree mode, and the refusal must NAME the offending axis with its
    extent and point at every workaround — tp_axes (intra-client TP),
    raveled mode, or widening client_axes (the error is the only
    breadcrumb a launcher user gets)."""
    from tests.conftest import require_host_devices
    require_host_devices(8)
    from repro.launch.mesh import make_cpu_mesh
    mesh = make_cpu_mesh(data=4, model=2)
    with pytest.raises(NotImplementedError, match="non-client") as exc:
        ShardedPAOTA(_params(), _clients(data), *_cfg(K), mesh=mesh,
                     params_mode="pytree")
    msg = str(exc.value)
    assert "'model' (extent 2)" in msg
    assert "tp_axes" in msg
    assert "params_mode='raveled'" in msg
    assert "client_axes" in msg


@pytest.mark.multidevice
@pytest.mark.slow
def test_transformer_client_sharded_round(client_mesh_8):
    """Acceptance: a transformer-config client federation (minicpm-2b
    reduced) completes sharded PAOTA rounds on the forced 8-device CPU
    mesh with its params pytree placed by stack_client_specs."""
    from repro.configs.minicpm_2b import REDUCED as cfg
    from repro.launch.mesh import client_axes_for
    from repro.models.transformer import init_model, loss_fn

    k, n, seq = 8, 8, 16
    rng = np.random.default_rng(0)

    def tloss(p, batch):
        return loss_fn(p, {"tokens": batch["x"]}, cfg)[0]

    clients = [FLClient(ClientData(
        rng.integers(0, cfg.vocab_size, (n, seq)).astype(np.int32),
        np.zeros(n, np.int32), i), tloss, batch_size=4, lr=0.01,
        local_steps=2) for i in range(k)]
    params = init_model(jax.random.PRNGKey(0), cfg)
    srv = ShardedPAOTA(params, clients, ChannelConfig(),
                       SchedulerConfig(n_clients=k, seed=1), PAOTAConfig(),
                       mesh=client_mesh_8, params_mode="pytree",
                       model_cfg=cfg)
    assert srv.client_axes == client_axes_for(cfg, srv.mesh)
    rows = srv.advance(3)
    assert any(r["n_participants"] > 0 for r in rows)
    g = srv.global_params()
    assert jax.tree_util.tree_structure(g) \
        == jax.tree_util.tree_structure(params)
    assert all(bool(jnp.isfinite(l).all())
               for l in jax.tree_util.tree_leaves(g))
    # tokens stacked with their integer dtype (stack_federation keeps it)
    assert srv.engine._x.dtype == jnp.int32
