"""Hypothesis compatibility shim for bare environments.

The property tests use ``hypothesis`` when it is installed. On containers
without it (the default CI image bakes only the jax toolchain) we fall back
to a tiny deterministic sampler: ``@given(st.integers(lo, hi), ...)`` runs
the test body on a fixed number of seeded draws from the same ranges. This
keeps every property test collected and exercising real (if fewer) examples
instead of import-erroring the whole module.

Usage in test files::

    from _hyp_compat import given, settings, st
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import numpy as _np

    HAVE_HYPOTHESIS = False
    _FALLBACK_EXAMPLES = 10
    _FALLBACK_SEED = 0xA07A  # "AOTA"

    class _IntSpec:
        def __init__(self, lo: int, hi: int):
            self.lo, self.hi = int(lo), int(hi)

        def draw(self, rng) -> int:
            return int(rng.integers(self.lo, self.hi + 1))

    class _SampledSpec:
        def __init__(self, options):
            self.options = list(options)

        def draw(self, rng):
            return self.options[int(rng.integers(0, len(self.options)))]

    class st:  # noqa: N801 - mimics `strategies as st`
        @staticmethod
        def integers(min_value: int, max_value: int) -> _IntSpec:
            return _IntSpec(min_value, max_value)

        @staticmethod
        def sampled_from(options) -> "_SampledSpec":
            return _SampledSpec(options)

    def settings(**_kwargs):
        """No-op: the fallback ignores max_examples/deadline tuning."""
        def deco(fn):
            return fn
        return deco

    def given(*specs):
        def deco(fn):
            def wrapper():
                rng = _np.random.default_rng(_FALLBACK_SEED)
                for _ in range(_FALLBACK_EXAMPLES):
                    fn(*(s.draw(rng) for s in specs))

            # plain zero-arg callable: pytest must NOT see the wrapped
            # signature, or it would treat the property args as fixtures
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco
