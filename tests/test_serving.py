"""Serving-path integration: prefill -> cache hand-off -> decode must
continue the sequence with logits matching the teacher-forced full
forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import decode_step, forward, init_model
from repro.models.transformer import cache_from_prefill

pytestmark = pytest.mark.slow  # arch-zoo/serving/integration tier (scripts/ci.sh)


@pytest.mark.parametrize("arch", ["smollm-135m", "olmo-1b", "mamba2-370m",
                                  "zamba2-7b", "mixtral-8x22b"])
def test_prefill_then_decode_continuity(arch):
    cfg = get_reduced(arch)
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    rng = np.random.default_rng(0)
    b, t_pre, t_dec, ring = 2, 11, 5, 64
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, t_pre + t_dec)),
                       jnp.int32)

    # reference: full forward over the whole sequence
    full, _, _ = forward(params := init_model(jax.random.PRNGKey(0), cfg),
                         {"tokens": toks}, cfg)

    # prefill the first t_pre tokens, convert, then decode the rest
    logits_pre, _, caches = forward(params, {"tokens": toks[:, :t_pre]}, cfg,
                                    return_cache=True)
    state = cache_from_prefill(caches, cfg, b, ring, t_pre)
    outs = []
    for i in range(t_dec):
        lg, state = decode_step(params, toks[:, t_pre + i:t_pre + i + 1],
                                state, jnp.int32(t_pre + i), cfg)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec),
                               np.asarray(full[:, t_pre:t_pre + t_dec]),
                               rtol=3e-3, atol=3e-3)
    np.testing.assert_allclose(np.asarray(logits_pre[:, -1]),
                               np.asarray(full[:, t_pre - 1]),
                               rtol=3e-3, atol=3e-3)


def test_prefill_longer_than_ring_window():
    """SWA arch: prefill longer than the ring buffer must keep only the
    last `window` keys and still match the windowed full forward."""
    cfg = dataclasses.replace(get_reduced("smollm-135m"), sliding_window=8)
    rng = np.random.default_rng(1)
    b, t_pre, t_dec = 1, 21, 4
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, t_pre + t_dec)),
                       jnp.int32)
    params = init_model(jax.random.PRNGKey(0), cfg)
    full, _, _ = forward(params, {"tokens": toks}, cfg)
    _, _, caches = forward(params, {"tokens": toks[:, :t_pre]}, cfg,
                           return_cache=True)
    state = cache_from_prefill(caches, cfg, b, 64, t_pre)
    assert state["k"].shape[2] == 8
    outs = []
    for i in range(t_dec):
        lg, state = decode_step(params, toks[:, t_pre + i:t_pre + i + 1],
                                state, jnp.int32(t_pre + i), cfg)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec),
                               np.asarray(full[:, t_pre:t_pre + t_dec]),
                               rtol=3e-3, atol=3e-3)


def test_hubert_masked_prediction_learns():
    """Encoder path: a few SGD steps on fixed batch reduce the masked-
    prediction loss (the audio family's train objective)."""
    from repro.models.transformer import loss_fn
    cfg = get_reduced("hubert-xlarge")
    rng = np.random.default_rng(0)
    b, t = 2, 48
    batch = {
        "frame_feats": jnp.asarray(rng.normal(size=(b, t, cfg.frontend_dim)),
                                   jnp.float32),
        "mask_indicator": jnp.asarray(rng.random((b, t)) < 0.3, jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, t)),
                               jnp.int32),
    }
    params = init_model(jax.random.PRNGKey(0), cfg)
    step = jax.jit(lambda p: jax.value_and_grad(
        lambda q: loss_fn(q, batch, cfg)[0])(p))
    l0 = None
    for _ in range(8):
        l, g = step(params)
        if l0 is None:
            l0 = float(l)
        params = jax.tree_util.tree_map(lambda a, b: a - 0.1 * b, params, g)
    assert float(l) < l0 - 0.2


@pytest.mark.parametrize("arch", ["smollm-135m", "granite-3-8b"])
def test_int8_kv_cache_decode_accuracy(arch):
    """kv_quant=True: int8 cache + scale-folded attention must track the
    fp full forward within quantization tolerance (§Perf iter E)."""
    cfg = dataclasses.replace(get_reduced(arch), kv_quant=True)
    rng = np.random.default_rng(0)
    params = init_model(jax.random.PRNGKey(0), cfg)
    t = 15
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, t)), jnp.int32)
    full, _, _ = forward(params, {"tokens": toks}, cfg)
    from repro.models import init_decode_state
    state = init_decode_state(cfg, 2, 64)
    assert state["k"].dtype == jnp.int8
    assert state["k_scale"].dtype == jnp.float16
    outs = []
    for i in range(t):
        lg, state = decode_step(params, toks[:, i:i + 1], state,
                                jnp.int32(i), cfg)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    rel = float(jnp.max(jnp.abs(dec - full))) / float(jnp.max(jnp.abs(full)))
    assert rel < 0.02


def test_int8_kv_prefill_handoff():
    cfg = dataclasses.replace(get_reduced("smollm-135m"), kv_quant=True)
    rng = np.random.default_rng(2)
    b, t_pre, t_dec = 2, 9, 4
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, t_pre + t_dec)),
                       jnp.int32)
    params = init_model(jax.random.PRNGKey(0), cfg)
    full, _, _ = forward(params, {"tokens": toks}, cfg)
    _, _, caches = forward(params, {"tokens": toks[:, :t_pre]}, cfg,
                           return_cache=True)
    state = cache_from_prefill(caches, cfg, b, 64, t_pre)
    assert state["k"].dtype == jnp.int8
    outs = []
    for i in range(t_dec):
        lg, state = decode_step(params, toks[:, t_pre + i:t_pre + i + 1],
                                state, jnp.int32(t_pre + i), cfg)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    rel = (float(jnp.max(jnp.abs(dec - full[:, t_pre:t_pre + t_dec])))
           / float(jnp.max(jnp.abs(full))))
    assert rel < 0.02
