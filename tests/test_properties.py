"""Model-level property tests (hypothesis + targeted invariants):
causality, RoPE shift behaviour, MoE conservation, aggregation algebra."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp_compat import given, settings, st

from repro.configs import get_reduced
from repro.models import forward, init_model

pytestmark = pytest.mark.slow  # arch-zoo/serving/integration tier (scripts/ci.sh)


@pytest.mark.parametrize("arch", ["smollm-135m", "mamba2-370m", "zamba2-7b",
                                  "mixtral-8x22b", "granite-3-8b"])
def test_causality_future_tokens_do_not_affect_past(arch):
    """Changing tokens after position t0 must leave logits[:, :t0] unchanged
    — holds for causal attention, SSD scans, SWA and MoE routing alike."""
    cfg = get_reduced(arch)
    rng = np.random.default_rng(0)
    b, t, t0 = 2, 24, 10
    params = init_model(jax.random.PRNGKey(0), cfg)
    toks = rng.integers(0, cfg.vocab_size, (b, t)).astype(np.int32)
    toks2 = toks.copy()
    toks2[:, t0:] = rng.integers(0, cfg.vocab_size, (b, t - t0))
    l1, _, _ = forward(params, {"tokens": jnp.asarray(toks)}, cfg)
    l2, _, _ = forward(params, {"tokens": jnp.asarray(toks2)}, cfg)
    np.testing.assert_allclose(np.asarray(l1[:, :t0]), np.asarray(l2[:, :t0]),
                               rtol=2e-4, atol=2e-4)


def test_encoder_is_not_causal():
    """hubert (bidirectional): changing late frames MUST change early
    outputs — the inverse of the causality property."""
    cfg = get_reduced("hubert-xlarge")
    rng = np.random.default_rng(0)
    b, t = 1, 24
    params = init_model(jax.random.PRNGKey(0), cfg)
    feats = rng.normal(size=(b, t, cfg.frontend_dim)).astype(np.float32)
    feats2 = feats.copy()
    feats2[:, 20:] += 3.0
    mk = {"mask_indicator": jnp.zeros((b, t), jnp.int32),
          "targets": jnp.zeros((b, t), jnp.int32)}
    l1, _, _ = forward(params, {"frame_feats": jnp.asarray(feats), **mk}, cfg)
    l2, _, _ = forward(params, {"frame_feats": jnp.asarray(feats2), **mk}, cfg)
    assert float(jnp.max(jnp.abs(l1[:, :10] - l2[:, :10]))) > 1e-4


def test_rope_relative_shift_invariance():
    """RoPE attention scores depend only on relative positions: shifting
    all positions by a constant leaves the attention output unchanged."""
    from repro.models import layers as L
    cfg = get_reduced("smollm-135m")
    rng = np.random.default_rng(0)
    b, t = 1, 16
    params = L.init_attention(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.normal(size=(b, t, cfg.d_model)), jnp.float32)
    pos0 = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    out0, _ = L.apply_attention(params, x, cfg, pos0)
    out7, _ = L.apply_attention(params, x, cfg, pos0 + 700)
    np.testing.assert_allclose(np.asarray(out0), np.asarray(out7),
                               rtol=2e-3, atol=2e-3)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_moe_dropless_output_is_convex_combination(seed):
    """With dropless capacity, each token's MoE output equals the
    router-weighted sum of per-expert FFN outputs computed densely."""
    from repro.models.moe import apply_moe, router_topk
    import repro.models.moe as MOE
    cfg = dataclasses.replace(get_reduced("mixtral-8x22b"),
                              capacity_factor=8.0)
    rng = np.random.default_rng(seed)
    params = MOE.init_moe(jax.random.PRNGKey(seed % 97), cfg, jnp.float32)
    x = jnp.asarray(rng.normal(size=(1, 8, cfg.d_model)), jnp.float32)
    got, _ = apply_moe(params, x, cfg)

    logits = x.reshape(8, -1) @ params["router"]["w"]
    w, _ = router_topk(logits, cfg)                      # (8, E)
    xs = x.reshape(8, -1)
    dense = []
    for e in range(cfg.num_experts):
        h = jax.nn.silu(xs @ params["gate"][e]) * (xs @ params["up"][e])
        dense.append(h @ params["down"][e])
    dense = jnp.stack(dense, axis=1)                     # (8, E, d)
    want = jnp.einsum("te,ted->td", w.astype(jnp.float32), dense)
    np.testing.assert_allclose(np.asarray(got.reshape(8, -1)),
                               np.asarray(want), rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 10), st.integers(0, 10_000))
def test_aggregation_scale_invariance(k, seed):
    """alpha weights (eq. 8) are invariant to uniformly scaling all powers;
    the noiseless aggregate therefore is too."""
    from repro.core.aircomp import aircomp_aggregate
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(k, 32)), jnp.float32)
    p = jnp.asarray(rng.random(k) + 0.1, jnp.float32)
    b = jnp.ones(k, jnp.float32)
    key = jax.random.PRNGKey(0)
    a1, _ = aircomp_aggregate(x, p, b, key, 0.0)
    a2, _ = aircomp_aggregate(x, 7.5 * p, b, key, 0.0)
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2),
                               rtol=2e-5, atol=2e-5)


def test_paota_delta_mode_noiseless_equals_model_mode():
    """With zero channel noise and s_k = 0 for all clients, delta- and
    model-transmission produce the same global model."""
    from repro.core.aggregation import paota_aggregate_stacked
    rng = np.random.default_rng(0)
    k, d = 4, 64
    start = rng.normal(size=d).astype(np.float32)
    deltas = rng.normal(size=(k, d)).astype(np.float32)
    models = start[None] + deltas
    p = jnp.asarray(rng.random(k) + 0.1, jnp.float32)
    b = jnp.ones(k, jnp.float32)
    key = jax.random.PRNGKey(0)
    agg_m, _ = paota_aggregate_stacked(jnp.asarray(models), p, b, key, 0.0)
    agg_d, _ = paota_aggregate_stacked(jnp.asarray(deltas), p, b, key, 0.0)
    np.testing.assert_allclose(np.asarray(agg_m),
                               start + np.asarray(agg_d), rtol=2e-5,
                               atol=2e-5)
