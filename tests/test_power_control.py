"""Power-control optimization tests: solver cross-validation (the paper's
Dinkelbach+MILP vs our exact water-filling vs PGD vs exhaustive), eq. 25
properties, and hypothesis property tests on random P2 instances."""
import numpy as np
import pytest
from _hyp_compat import given, settings, st

from repro.core.boxqp import solve_waterfill
from repro.core.dinkelbach import dinkelbach, solve_p2
from repro.core.power_control import (build_p2, power_from_beta,
                                      similarity_factor, staleness_factor)


def _rand_problem(rng, k, p_max=15.0):
    rho = rng.uniform(0.2, 1.0, k)
    theta = rng.uniform(0.0, 1.0, k)
    b = (rng.random(k) < 0.8).astype(float)
    if b.sum() == 0:
        b[0] = 1.0
    return build_p2(rho, theta, np.full(k, p_max), b, smooth_l=10.0,
                    eps_bound=0.05, model_dim=8070, sigma_n2=8e-5)


@pytest.mark.parametrize("seed", range(5))
def test_solvers_agree_small_k(seed):
    rng = np.random.default_rng(seed)
    prob = _rand_problem(rng, 4)
    ex = solve_p2(prob, "exhaustive")
    for method in ("pgd", "waterfill", "waterfill_jnp", "milp"):
        res = solve_p2(prob, method)
        assert res.objective <= ex.objective * 1.02 + 1e-9, method
        assert np.all(res.beta >= -1e-9) and np.all(res.beta <= 1 + 1e-9)


def test_waterfill_scales_to_k100():
    rng = np.random.default_rng(0)
    prob = _rand_problem(rng, 100)
    wf = solve_waterfill(prob)
    pgd = dinkelbach(prob, inner="pgd")
    assert wf.objective <= pgd.objective * 1.001 + 1e-12


@pytest.mark.parametrize("seed,k", [(0, 100), (1, 1000), (2, 5000)])
def test_waterfill_prefix_matches_dense(seed, k):
    """The O((K+G) log K) sorted-prefix-sum grid evaluator (the numpy host
    path past K ~ 4096, where the dense (grid, K) matrix cost hundreds of
    MB per solve) lands on the dense path's optimum: objectives match to
    float summation order; beta only to ~sqrt(eps) because the P2 ratio is
    flat in tau near the optimum."""
    rng = np.random.default_rng(seed)
    prob = _rand_problem(rng, k)
    dense = solve_waterfill(prob, method="dense")
    prefix = solve_waterfill(prob, method="prefix")
    assert prefix.objective == pytest.approx(dense.objective, rel=1e-8)
    np.testing.assert_allclose(prefix.beta, dense.beta, atol=1e-4)
    # auto dispatch: dense below the threshold, prefix above
    from repro.core.boxqp import PREFIX_K_THRESHOLD
    auto = solve_waterfill(prob)
    expect = dense if k < PREFIX_K_THRESHOLD else prefix
    assert auto.objective == pytest.approx(expect.objective, rel=1e-12)


@pytest.mark.parametrize("k", [4, 37, 100])
def test_waterfill_jnp_matches_numpy_reference(k):
    """The jit-traceable float32 solver (the fused round's P2 step) lands
    on the numpy/float64 water-filling optimum."""
    rng = np.random.default_rng(k)
    prob = _rand_problem(rng, k)
    wf = solve_waterfill(prob)
    wj = solve_p2(prob, "waterfill_jnp")
    assert wj.objective == pytest.approx(wf.objective, rel=1e-3)
    # and it is a valid point of the box
    assert np.all(wj.beta >= -1e-6) and np.all(wj.beta <= 1 + 1e-6)


def test_dinkelbach_monotone_lambda():
    """Dinkelbach lambda sequence is nondecreasing (ratio improves)."""
    rng = np.random.default_rng(3)
    prob = _rand_problem(rng, 6)
    lams = []
    beta = np.full(prob.K, 0.5)
    lam = prob.h2(beta) / prob.h1(beta)
    from repro.core.dinkelbach import inner_pgd, _eval_F
    for _ in range(8):
        beta = inner_pgd(prob, lam)
        new_lam = prob.h2(beta) / prob.h1(beta)
        lams.append(new_lam)
        if abs(new_lam - lam) < 1e-15:
            break
        lam = new_lam
    assert all(b >= a - 1e-9 for a, b in zip(lams, lams[1:]))


def test_power_law_eq25_properties():
    rho = np.array([1.0, 0.5, 0.3])
    theta = np.array([0.2, 0.9, 0.5])
    p_max = np.array([15.0, 15.0, 10.0])
    for beta in (0.0, 0.3, 1.0):
        p = np.asarray(power_from_beta(np.full(3, beta), rho, theta, p_max))
        assert np.all(p >= 0) and np.all(p <= p_max + 1e-9)
    # beta=1: pure staleness weighting; beta=0: pure similarity weighting
    p1 = np.asarray(power_from_beta(np.ones(3), rho, theta, p_max))
    np.testing.assert_allclose(p1, p_max * rho)
    p0 = np.asarray(power_from_beta(np.zeros(3), rho, theta, p_max))
    np.testing.assert_allclose(p0, p_max * theta)


def test_staleness_factor_monotone():
    s = np.arange(10).astype(float)
    rho = np.asarray(staleness_factor(s, omega=3.0))
    assert np.all(np.diff(rho) < 0)          # fresher -> more power
    assert rho[0] == 1.0                     # s=0 -> full weight


def test_similarity_factor_range():
    cos = np.linspace(-1, 1, 21)
    th = np.asarray(similarity_factor(cos))
    assert th.min() >= 0 and th.max() <= 1
    assert th[0] == 0.0 and th[-1] == 1.0


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 8), st.integers(0, 10_000))
def test_waterfill_never_worse_than_corners(k, seed):
    """Property: the exact water-filling solution beats every {0,1}^K corner
    (it is a global optimum over the box)."""
    rng = np.random.default_rng(seed)
    prob = _rand_problem(rng, k)
    wf = solve_waterfill(prob)
    for _ in range(10):
        corner = rng.integers(0, 2, k).astype(float)
        assert wf.objective <= prob.objective(corner) + 1e-7


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 30), st.integers(0, 10_000))
def test_p2_objective_scale_invariance(k, seed):
    """h1/h2 with c0=0 is invariant to uniformly scaling all powers —
    the noise term is what makes absolute power matter."""
    rng = np.random.default_rng(seed)
    rho = rng.uniform(0.2, 1.0, k)
    theta = rng.uniform(0.0, 1.0, k)
    b = np.ones(k)
    p0 = build_p2(rho, theta, np.full(k, 15.0), b, smooth_l=10.0,
                  eps_bound=0.05, model_dim=8070, sigma_n2=0.0)
    p1 = build_p2(rho, theta, np.full(k, 30.0), b, smooth_l=10.0,
                  eps_bound=0.05, model_dim=8070, sigma_n2=0.0)
    beta = rng.random(k)
    assert p0.objective(beta) == pytest.approx(p1.objective(beta), rel=1e-9)
