"""Active-cohort round core: (K,) state plane + (m, d) payload plane.

Equivalence claims under test:

* ``cohort_size=K`` (every client permanently slotted) is allclose to the
  dense path for every params mode / transmit mode / storage dtype — same
  uploader sets, same per-client draws, float reduction order the only
  difference;
* the step is invariant under any permutation of the slot order: the
  (K,) scheduler state advances bit-identically and the global model is
  allclose (slots are an unordered set, not an indexing commitment) —
  hypothesis property over random permutations;
* underfull cohorts cap participation at m and stay finite;
* the sharded driver's shard-local slot layout matches the fused dense
  trajectory at m = K, and the documented refusals (m > K, m not tiling
  the shards, cohort + grouped aggregation) actually refuse.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp_compat import given, settings, st

from repro.core import ChannelConfig, SchedulerConfig
from repro.data.partition import partition_noniid
from repro.data.pipeline import build_federation
from repro.data.synthetic import make_mnist_like
from repro.fl import FLClient, FusedPAOTA, PAOTAConfig
from repro.models.mlp import init_mlp_params, mlp_loss

K = 8


@functools.lru_cache(maxsize=1)
def _world():
    x, y, _, _ = make_mnist_like(n_train=1500, n_test=10)
    parts = partition_noniid(y, n_clients=K, seed=0)
    return x, y, parts


def _clients():
    x, y, parts = _world()
    return [FLClient(d, mlp_loss, batch_size=32, lr=0.1, local_steps=5)
            for d in build_federation(x, y, parts)]


def _fused(**kw):
    return FusedPAOTA(init_mlp_params(jax.random.PRNGKey(0)), _clients(),
                      ChannelConfig(),
                      SchedulerConfig(n_clients=K, seed=1),
                      PAOTAConfig(transmit=kw.pop("transmit", "model")),
                      **kw)


# ---------------------------------------------------------------------------
# cohort_size = K == dense, all params modes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("params_mode", ["raveled", "pytree"])
@pytest.mark.parametrize("transmit", ["model", "delta"])
def test_full_cohort_matches_dense(params_mode, transmit):
    dense = _fused(params_mode=params_mode, transmit=transmit)
    coh = _fused(params_mode=params_mode, transmit=transmit, cohort_size=K)
    hd = dense.advance(6)
    hc = coh.advance(6)
    for a, b in zip(hd, hc):
        assert a["n_participants"] == b["n_participants"]
        assert a["time"] == b["time"]
        assert a["mean_staleness"] == pytest.approx(b["mean_staleness"],
                                                    abs=1e-6)
        # the slot order permutes the water-filling solver's reductions;
        # the tie-broken grid argmax (lowest index within WATERFILL_TIE_RTOL
        # of the optimum) keeps the chosen cell stable under float
        # regrouping, so only reduction-order noise remains in the
        # beta sum (formerly rel=2e-2 when near-tied cells could flip)
        assert a["varsigma"] == pytest.approx(b["varsigma"], rel=1e-3)
    np.testing.assert_allclose(dense.global_vec, coh.global_vec,
                               rtol=1e-4, atol=1e-5)


def test_full_cohort_matches_dense_bf16():
    dense = _fused(pending_dtype="bfloat16")
    coh = _fused(pending_dtype="bfloat16", cohort_size=K)
    hd = dense.advance(5)
    hc = coh.advance(5)
    assert [r["n_participants"] for r in hd] == \
        [r["n_participants"] for r in hc]
    np.testing.assert_allclose(dense.global_vec, coh.global_vec,
                               rtol=5e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# underfull cohorts
# ---------------------------------------------------------------------------

def test_underfull_cohort_caps_participation():
    m = 3
    srv = _fused(cohort_size=m)
    rows = srv.advance(10)
    assert all(r["n_participants"] <= m for r in rows)
    assert any(r["n_participants"] > 0 for r in rows)
    assert np.isfinite(srv.global_vec).all()
    # slot bookkeeping stays consistent: live slots hold distinct clients
    occ = np.asarray(srv._carry.slot_client)
    live = np.asarray(srv._carry.slot_live)
    assert occ.shape == (m,) and live.shape == (m,)
    ids = occ[live]
    assert len(set(ids.tolist())) == len(ids)
    assert ((ids >= 0) & (ids < K)).all()


def test_cohort_carry_is_m_sized():
    """The point of the refactor: payload planes shrink from (K, d) to
    (m, d) — the K x d carry stops scaling with K."""
    m = 3
    srv = _fused(cohort_size=m, transmit="delta")
    srv.advance(2)
    assert srv._carry.pending is None
    assert srv._carry.deltas.shape == (m, srv.d)
    assert srv._carry.ready.shape == (K,)


def test_cohort_size_validation():
    with pytest.raises(ValueError, match="cohort_size"):
        _fused(cohort_size=K + 1)
    with pytest.raises(ValueError, match="cohort_size"):
        _fused(cohort_size=-2)


# ---------------------------------------------------------------------------
# permutation invariance of the slot order (hypothesis property)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=1)
def _perm_fixture():
    """A mid-flight cohort carry + a non-donating one-step runner."""
    srv = _fused(cohort_size=4, donate=False)
    srv.advance(3)
    step = lambda c: srv._jit_scan(c, srv.engine._x, srv.engine._y,
                                   n_rounds=1)
    return srv, step


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 100_000))
def test_step_invariant_under_slot_permutation(seed):
    srv, step = _perm_fixture()
    carry = srv._carry
    perm = jnp.asarray(np.random.default_rng(seed).permutation(4))
    permuted = carry._replace(
        slot_client=carry.slot_client[perm],
        slot_live=carry.slot_live[perm],
        pending=jax.tree_util.tree_map(lambda l: l[perm], carry.pending),
        deltas=jax.tree_util.tree_map(lambda l: l[perm], carry.deltas))
    c1, o1 = step(carry)
    c2, o2 = step(permuted)
    # the (K,) state plane is slot-order blind: bit-identical
    np.testing.assert_array_equal(np.asarray(c1.ready), np.asarray(c2.ready))
    np.testing.assert_array_equal(np.asarray(c1.busy_lat),
                                  np.asarray(c2.busy_lat))
    np.testing.assert_array_equal(np.asarray(c1.model_round),
                                  np.asarray(c2.model_round))
    # the in-flight cohort is the same SET of clients
    s1 = set(np.asarray(c1.slot_client)[np.asarray(c1.slot_live)].tolist())
    s2 = set(np.asarray(c2.slot_client)[np.asarray(c2.slot_live)].tolist())
    assert s1 == s2
    # global model: same math, permuted reduction order (the tie-broken
    # water-filling grid argmax holds the chosen cell stable — see the
    # tolerance note in test_full_cohort_matches_dense)
    np.testing.assert_allclose(np.asarray(c1.global_vec),
                               np.asarray(c2.global_vec),
                               rtol=1e-4, atol=1e-5)
    assert float(o1["n_participants"][0]) == \
        pytest.approx(float(o2["n_participants"][0]))


# ---------------------------------------------------------------------------
# sharded driver
# ---------------------------------------------------------------------------

@pytest.mark.multidevice
def test_sharded_full_cohort_matches_fused_dense():
    from conftest import require_host_devices
    from repro.fl import ShardedPAOTA
    from repro.launch.mesh import make_cpu_mesh
    require_host_devices(2)
    sh = ShardedPAOTA(init_mlp_params(jax.random.PRNGKey(0)), _clients(),
                      ChannelConfig(), SchedulerConfig(n_clients=K, seed=1),
                      PAOTAConfig(), mesh=make_cpu_mesh(data=2, model=1),
                      cohort_size=K)
    dense = _fused()
    hs = sh.advance(6)
    hd = dense.advance(6)
    for a, b in zip(hd, hs):
        assert a["n_participants"] == b["n_participants"]
    np.testing.assert_allclose(dense.global_vec, sh.global_vec,
                               rtol=1e-4, atol=1e-5)


@pytest.mark.multidevice
def test_sharded_underfull_cohort_runs():
    from conftest import require_host_devices
    from repro.fl import ShardedPAOTA
    from repro.launch.mesh import make_cpu_mesh
    require_host_devices(2)
    sh = ShardedPAOTA(init_mlp_params(jax.random.PRNGKey(0)), _clients(),
                      ChannelConfig(), SchedulerConfig(n_clients=K, seed=1),
                      PAOTAConfig(), mesh=make_cpu_mesh(data=2, model=1),
                      cohort_size=4)
    rows = sh.advance(8)
    assert all(r["n_participants"] <= 4 for r in rows)
    assert any(r["n_participants"] > 0 for r in rows)
    assert np.isfinite(sh.global_vec).all()


@pytest.mark.multidevice
def test_sharded_cohort_refusals():
    from conftest import require_host_devices
    from repro.fl import ShardedPAOTA
    from repro.launch.mesh import make_cpu_mesh
    require_host_devices(2)
    mk = lambda **kw: ShardedPAOTA(
        init_mlp_params(jax.random.PRNGKey(0)), _clients(), ChannelConfig(),
        SchedulerConfig(n_clients=K, seed=1), PAOTAConfig(),
        mesh=make_cpu_mesh(data=2, model=1), **kw)
    # the refusal names the shard count AND the nearest valid sizes
    with pytest.raises(ValueError,
                       match=r"2 client shards.*nearest valid.*2 and 4"):
        mk(cohort_size=3)          # 3 slots cannot tile 2 shards
    with pytest.raises(NotImplementedError, match="grouped"):
        mk(cohort_size=2, group_period=2)


# ---------------------------------------------------------------------------
# compressed payloads: compression-off / identity-compression regressions
# ---------------------------------------------------------------------------

def _advance_pair(a, b, n=6):
    ha, hb = a.advance(n), b.advance(n)
    for ra, rb in zip(ha, hb):
        assert ra == rb          # full metric rows, bit-identical floats
    np.testing.assert_array_equal(np.asarray(a.global_vec),
                                  np.asarray(b.global_vec))


def test_compress_off_is_default_cohort_path():
    """``compress=None`` emits the uncompressed cohort program op-for-op:
    same history, bit-identical trajectory."""
    _advance_pair(_fused(transmit="delta", cohort_size=4),
                  _fused(transmit="delta", cohort_size=4, compress=None))


@pytest.mark.parametrize("scheme", ["topk", "randmask"])
def test_identity_compression_bit_identical(scheme):
    """s = d keeps every coordinate: the identity-compression branch
    routes through the SAME dense stats + superpose ops, and f32 error
    feedback carries exactly-zero residuals — bit-identical to the
    uncompressed cohort path."""
    _advance_pair(
        _fused(transmit="delta", cohort_size=4),
        _fused(transmit="delta", cohort_size=4, compress=scheme,
               compress_ratio=1.0))


def test_identity_compression_bf16_ef_off_bit_identical():
    """bf16 slots at s = d match the uncompressed bf16 cohort path only
    with error feedback OFF: with EF on, the residual captures the bf16
    rounding error and compensates it next round — an intended
    improvement the dense path cannot express, not a drift."""
    _advance_pair(
        _fused(transmit="delta", cohort_size=4, pending_dtype="bfloat16"),
        _fused(transmit="delta", cohort_size=4, pending_dtype="bfloat16",
               compress="topk", compress_ratio=1.0, error_feedback=False))


def test_compressed_carry_is_m_by_s():
    """The point of the compression: payload planes shrink from (m, d) to
    (m, s) + an (m, s) index plane — d leaves the carry entirely when
    error feedback is off."""
    m = 4
    srv = _fused(transmit="delta", cohort_size=m, compress="randmask",
                 compress_ratio=0.25, error_feedback=False)
    srv.advance(2)
    s = srv.compress_s
    assert s == max(1, round(srv.d * 0.25))
    assert srv._carry.pending is None
    assert srv._carry.deltas.shape == (m, s)
    assert srv._carry.slot_idx.shape == (m, s)
    assert srv._carry.slot_resid is None and srv._carry.resid_val is None
    srv_ef = _fused(transmit="delta", cohort_size=m, compress="topk",
                    compress_ratio=0.25, slot_dtype="int8")
    srv_ef.advance(2)
    assert srv_ef._carry.deltas.dtype == jnp.int8
    assert srv_ef._carry.slot_scale.shape == (m,)
    assert srv_ef._carry.slot_resid.shape == (m, srv_ef.compress_s)
    assert srv_ef._carry.resid_val.shape == (K, srv_ef.compress_s)


def test_compressed_run_is_finite_and_participates():
    for scheme, sd in [("topk", None), ("randmask", "int8")]:
        srv = _fused(transmit="delta", cohort_size=4, compress=scheme,
                     compress_ratio=0.25, slot_dtype=sd)
        rows = srv.advance(8)
        assert any(r["n_participants"] > 0 for r in rows)
        assert np.isfinite(np.asarray(srv.global_vec)).all()


def test_compress_validation():
    with pytest.raises(ValueError, match="cohort"):
        _fused(transmit="delta", compress="topk")
    with pytest.raises(ValueError, match="delta"):
        _fused(transmit="model", cohort_size=4, compress="topk")
    with pytest.raises(ValueError, match="compress"):
        _fused(transmit="delta", cohort_size=4, slot_dtype="int8")
    with pytest.raises(ValueError, match="compress_ratio"):
        _fused(transmit="delta", cohort_size=4, compress="topk",
               compress_ratio=0.0)
    with pytest.raises(ValueError, match="compress"):
        _fused(transmit="delta", cohort_size=4, compress="dct")
    with pytest.raises(NotImplementedError, match="pytree"):
        _fused(transmit="delta", cohort_size=4, compress="topk",
               params_mode="pytree")


@pytest.mark.multidevice
def test_sharded_identity_compression_bit_identical():
    from conftest import require_host_devices
    from repro.fl import ShardedPAOTA
    from repro.launch.mesh import make_cpu_mesh
    require_host_devices(2)
    mk = lambda **kw: ShardedPAOTA(
        init_mlp_params(jax.random.PRNGKey(0)), _clients(), ChannelConfig(),
        SchedulerConfig(n_clients=K, seed=1), PAOTAConfig(transmit="delta"),
        mesh=make_cpu_mesh(data=2, model=1), cohort_size=4, **kw)
    _advance_pair(mk(), mk(compress="randmask", compress_ratio=1.0))


@pytest.mark.multidevice
def test_sharded_compressed_run_is_finite():
    from conftest import require_host_devices
    from repro.fl import ShardedPAOTA
    from repro.launch.mesh import make_cpu_mesh
    require_host_devices(2)
    srv = ShardedPAOTA(
        init_mlp_params(jax.random.PRNGKey(0)), _clients(), ChannelConfig(),
        SchedulerConfig(n_clients=K, seed=1), PAOTAConfig(transmit="delta"),
        mesh=make_cpu_mesh(data=2, model=1), cohort_size=4,
        compress="topk", compress_ratio=0.25, slot_dtype="int8")
    rows = srv.advance(8)
    assert any(r["n_participants"] > 0 for r in rows)
    assert np.isfinite(np.asarray(srv.global_vec)).all()
