"""Grouped (multi-pod) aggregation: ShardedPAOTA with ``group_period=N``
on a ("pod", "data") mesh — intra-pod partial superpositions every period,
ONE cross-pod model-sized psum per N-period window.

Pinned contracts:
* N=1 is the flat sharded program round for round (the held slot is zero
  and ``partial + 0`` is exact), in both params modes;
* an all-phantom pod is bit-transparent (its partial is exactly zero);
* a zero-uploader window holds w_g bit-identically;
* advance moves in whole windows;
* the compiled scan body contains exactly one cross-pod model-sized
  all-reduce per window (``repro.launch.collectives`` over the HLO).
"""
import jax
import numpy as np
import pytest

from repro.core import ChannelConfig, SchedulerConfig
from repro.data.partition import partition_noniid
from repro.data.pipeline import build_federation
from repro.data.synthetic import make_mnist_like
from repro.fl import FLClient, PAOTAConfig, ShardedPAOTA
from repro.launch.collectives import (cross_pod_allreduce_count,
                                      iter_allreduces)
from repro.launch.mesh import make_pod_mesh
from repro.models.mlp import init_mlp_params, mlp_loss
from tests.conftest import require_host_devices

pytestmark = pytest.mark.multidevice

K = 8
# the test MLP ravels to d = 8070; the grouped psums carry d + 1 entries.
# Threshold sits above the water-filling grid (4096) and the scalar
# metrics, below the model — same role as the benchmark's 8192 default.
MODEL_SIZE_FLOOR = 4097


@pytest.fixture(scope="module")
def data():
    x, y, _, _ = make_mnist_like(n_train=2000, n_test=10)
    parts = partition_noniid(y, n_clients=K, seed=0)
    return x, y, parts


def _clients(data, n=K):
    x, y, parts = data
    feds = build_federation(x, y, [p for p in parts][:n])
    return [FLClient(d, mlp_loss, batch_size=32, lr=0.1, local_steps=5)
            for d in feds]


def _params():
    return init_mlp_params(jax.random.PRNGKey(0))


def _srv(data, mesh, n=K, sched=None, **kw):
    return ShardedPAOTA(_params(), _clients(data, n), ChannelConfig(),
                        sched or SchedulerConfig(n_clients=n, seed=1),
                        PAOTAConfig(), mesh=mesh, **kw)


@pytest.mark.parametrize("params_mode", ["raveled", "pytree"])
def test_group_period_1_is_flat(data, pod_mesh_2x4, params_mode):
    """Acceptance: group_period=1 equals the flat sharded program round
    for round (allclose <= 1e-6; the raveled mode lands bit-identical —
    the sync folds a zero held slot, and x + 0 is exact)."""
    flat = _srv(data, pod_mesh_2x4, params_mode=params_mode)
    grp = _srv(data, pod_mesh_2x4, params_mode=params_mode, group_period=1)
    assert grp.n_pod_groups == 2
    for _ in range(4):
        rf, rg = flat.advance(1)[-1], grp.advance(1)[-1]
        assert rf["n_participants"] == rg["n_participants"]
        assert rf["time"] == rg["time"]
        for key in ("mean_staleness", "beta_mean", "varsigma",
                    "p2_objective"):
            assert rf[key] == pytest.approx(rg[key], rel=1e-6, abs=1e-9)
        np.testing.assert_allclose(flat.global_vec, grp.global_vec,
                                   rtol=1e-6, atol=1e-6)
    if params_mode == "raveled":
        assert np.array_equal(flat.global_vec, grp.global_vec)


def test_grouped_window_diverges_from_flat_then_syncs(data, pod_mesh_2x4):
    """N=2 actually groups: the trajectory differs from flat (the window's
    partials land staleness-weighted at the sync), non-sync periods report
    varsigma 0 and hold w_g, and the held slot is zeroed after every
    window."""
    flat = _srv(data, pod_mesh_2x4)
    grp = _srv(data, pod_mesh_2x4, group_period=2)
    rows_f, rows_g = flat.advance(4), grp.advance(4)
    # same scheduler timeline (the clock is aggregation-driven, not sync-
    # driven), different aggregation math
    assert [r["n_participants"] for r in rows_f] == \
        [r["n_participants"] for r in rows_g]
    for j, r in enumerate(rows_g):
        if j % 2 == 0:                      # non-sync period of the window
            assert r["varsigma"] == 0.0
    assert any(r["varsigma"] > 0 for r in rows_g[1::2])
    assert not np.allclose(flat.global_vec, grp.global_vec, atol=1e-6)
    assert np.isfinite(grp.global_vec).all()
    held = np.asarray(grp._carry.held)
    assert held.shape == (2, grp.d + 1)
    assert np.all(held == 0.0)              # zeroed at the window sync


def test_all_phantom_pod_is_bit_transparent(data):
    """K=4 on the (2, 4) mesh pads pod 1 entirely with phantoms; their
    partials are exactly zero, so the grouped trajectory equals the same
    federation on a single-pod (1, 4) mesh (identical draws, the zero pod
    adding exact zeros into the sync psum)."""
    require_host_devices(8)
    two_pod = _srv(data, make_pod_mesh(pods=2, data=4), n=4,
                   sched=SchedulerConfig(n_clients=4, seed=1),
                   group_period=2)
    one_pod = _srv(data, make_pod_mesh(pods=1, data=4), n=4,
                   sched=SchedulerConfig(n_clients=4, seed=1),
                   group_period=2)
    assert (two_pod.k_pad, two_pod.n_phantom) == (8, 4)
    assert (one_pod.k_pad, one_pod.n_phantom) == (4, 0)
    rows2, rows1 = two_pod.advance(4), one_pod.advance(4)
    assert [r["n_participants"] for r in rows2] == \
        [r["n_participants"] for r in rows1]
    np.testing.assert_allclose(two_pod.global_vec, one_pod.global_vec,
                               rtol=0, atol=1e-7)


def test_phantom_padding_invariance_across_intra_pod_layout(data):
    """K=6 does not divide 2x4: the federation pads to 8 with phantoms in
    pod 1. The same K=6 on a (2, 2) mesh pads to the same 8 slots with the
    same pod membership — only the intra-pod shard layout differs, so the
    two grouped trajectories agree to float reduction order."""
    require_host_devices(8)
    wide = _srv(data, make_pod_mesh(pods=2, data=4), n=6,
                sched=SchedulerConfig(n_clients=6, seed=1), group_period=2)
    narrow = _srv(data, make_pod_mesh(pods=2, data=2), n=6,
                  sched=SchedulerConfig(n_clients=6, seed=1), group_period=2)
    assert (wide.k_pad, wide.n_phantom, wide.k_local) == (8, 2, 1)
    assert (narrow.k_pad, narrow.n_phantom, narrow.k_local) == (8, 2, 2)
    rows_w, rows_n = wide.advance(4), narrow.advance(4)
    assert [r["n_participants"] for r in rows_w] == \
        [r["n_participants"] for r in rows_n]
    np.testing.assert_allclose(wide.global_vec, narrow.global_vec,
                               rtol=1e-5, atol=1e-6)


def test_zero_uploader_window_holds_global_bit_identically(data,
                                                           pod_mesh_2x4):
    """A period too short for any client to finish: every period of the
    window (sync included) reports zero participants and w_g holds
    bit-identically — the varsigma clamp guard, per pod and globally."""
    srv = _srv(data, pod_mesh_2x4,
               sched=SchedulerConfig(n_clients=K, seed=1, delta_t=0.001),
               group_period=2)
    g0 = np.array(srv.global_vec, copy=True)
    rows = srv.advance(4)
    assert all(r["n_participants"] == 0 for r in rows)
    assert all(r["varsigma"] == 0.0 for r in rows)
    assert all(np.isinf(r["p2_objective"]) for r in rows)
    assert np.array_equal(srv.global_vec, g0)
    assert np.all(np.asarray(srv._carry.held) == 0.0)


def test_grouped_advance_requires_whole_windows(data, pod_mesh_2x4):
    srv = _srv(data, pod_mesh_2x4, group_period=2)
    with pytest.raises(ValueError, match="whole windows"):
        srv.advance(3)
    assert len(srv.advance(2)) == 2


def test_grouped_topology_validation(data, pod_mesh_2x4):
    with pytest.raises(ValueError, match="group_period"):
        _srv(data, pod_mesh_2x4, pod_axes=("pod",))
    with pytest.raises(ValueError, match="distinct client axes"):
        _srv(data, pod_mesh_2x4, group_period=2, pod_axes=("model",))
    with pytest.raises(ValueError, match="expected >= 0"):
        _srv(data, pod_mesh_2x4, group_period=-1)


def test_compiled_window_has_one_cross_pod_allreduce(data, pod_mesh_2x4):
    """Structural acceptance: the compiled scan body of an N=4 window
    contains exactly ONE cross-pod model-sized all-reduce (the sync) and
    exactly N-1 intra-pod ones (the per-period partials)."""
    srv = _srv(data, pod_mesh_2x4, group_period=4)
    hlo = srv.compiled_scan_hlo(4)
    shape = tuple(pod_mesh_2x4.shape[a] for a in pod_mesh_2x4.axis_names)
    assert cross_pod_allreduce_count(hlo, shape, (0,),
                                     min_elements=MODEL_SIZE_FLOOR) == 1
    big = [(n, g) for n, g in iter_allreduces(hlo)
           if n >= MODEL_SIZE_FLOOR]
    assert len(big) == 4                    # 3 intra-pod partials + 1 sync
    # the flat program on the same mesh crosses pods EVERY period: its
    # one-round scan body already holds a cross-pod model-sized psum
    flat_hlo = _srv(data, pod_mesh_2x4).compiled_scan_hlo(4)
    assert cross_pod_allreduce_count(flat_hlo, shape, (0,),
                                     min_elements=MODEL_SIZE_FLOOR) >= 1
