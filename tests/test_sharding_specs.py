"""Property tests for the client-stack sharding rules.

``stack_client_specs`` / ``batch_specs`` feed pjit in_shardings, which
hard-error on any sharded dim that does not divide its mesh-axis extent.
The ``pad`` fallback guard in ``repro.sharding.rules._base_spec`` exists
exactly to drop non-dividing assignments (odd vocabs, 9/14/36-head
attention on an 8-wide model axis, 8-expert MoEs on a 16-wide EP axis) —
these tests pin that guard for EVERY config in ``repro.configs`` on 1-,
2-, and 8-device meshes in both client-over-data and TP-heavy layouts.
"""
import jax
import numpy as np
import pytest
from _hyp_compat import given, settings, st

from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import client_axes_for
from repro.launch.shapes import InputShape
from repro.launch.steps import abstract_params, train_batch_struct
from repro.sharding.rules import batch_specs, stack_client_specs

# (data, model) layouts per device count: client-over-data (n, 1) plus a
# TP-heavy split that forces the divisibility fallback for odd head/vocab
# counts
LAYOUTS = [(1, 1), (2, 1), (1, 2), (8, 1), (2, 4), (1, 8)]
SHAPE = InputShape("spec_test", seq_len=128, global_batch=64, kind="train")


class _Mesh:
    """Shape-only mesh stand-in (the rules read axis_names + shape only —
    same trick as tests/test_substrates.py, so 1/2/8 'devices' need no
    backend)."""

    def __init__(self, data, model):
        self.axis_names = ("data", "model")
        self.shape = {"data": data, "model": model}
        self.size = data * model


def _assert_divisible(specs, tree, mesh, what):
    leaves_s = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    leaves_t = jax.tree_util.tree_leaves(tree)
    assert len(leaves_s) == len(leaves_t)
    for spec, leaf in zip(leaves_s, leaves_t):
        assert len(tuple(spec)) <= len(leaf.shape), (what, spec, leaf.shape)
        for i, ax in enumerate(tuple(spec)):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = int(np.prod([mesh.shape[a] for a in axes]))
            assert leaf.shape[i] % size == 0, (what, spec, leaf.shape, size)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("layout", LAYOUTS)
def test_stack_and_batch_specs_divisible(arch, layout):
    """Every config x every 1/2/8-device layout: the client-stacked param
    specs and the (K, M, B, ...) batch specs must divide exactly."""
    cfg = get_config(arch)
    mesh = _Mesh(*layout)
    client_axes = client_axes_for(cfg, mesh)
    n_client = int(np.prod([mesh.shape[a] for a in client_axes])) or 1
    k = 2 * n_client                       # client dim always shard-divisible

    tree = abstract_params(cfg, stack=k)
    specs = stack_client_specs(tree, cfg, mesh, client_axes)
    _assert_divisible(specs, tree, mesh, (arch, layout, "params"))

    batch = train_batch_struct(cfg, SHAPE, k, local_steps=3)
    bspecs = batch_specs(batch, (),
                         lead_axes=(tuple(client_axes) if client_axes
                                    else (), ()))
    _assert_divisible(bspecs, batch, mesh, (arch, layout, "batch"))


@settings(max_examples=40, deadline=None)
@given(st.sampled_from(ARCH_IDS), st.sampled_from(LAYOUTS),
       st.integers(1, 6))
def test_pad_guard_property(arch, layout, k_mult):
    """Property form: for any client-count multiple of the client axis,
    no leaf ever gets a non-dividing assignment (the `pad` guard must
    catch every case the name-based rules mis-assign)."""
    cfg = get_config(arch)
    mesh = _Mesh(*layout)
    client_axes = client_axes_for(cfg, mesh)
    n_client = int(np.prod([mesh.shape[a] for a in client_axes])) or 1
    tree = abstract_params(cfg, stack=k_mult * n_client)
    specs = stack_client_specs(tree, cfg, mesh, client_axes)
    _assert_divisible(specs, tree, mesh, (arch, layout, k_mult))
