"""Roofline machinery tests: the XLA while-loop undercount (documented
limitation that motivated the HLO parser) and the trip-count-aware parser
itself."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.analysis import (HW, cost_analysis_dict, model_flops,
                                     roofline_terms)
from repro.roofline.hlo_parse import analyze, split_computations


def _scan_matmul(n, size=128):
    def body(c, _):
        return c @ c, None
    x = jnp.ones((size, size))
    f = jax.jit(lambda x: jax.lax.scan(body, x, None, length=n)[0])
    return f.lower(x).compile()


def test_xla_cost_analysis_undercounts_scans():
    """The documented XLA limitation: while bodies counted once."""
    c1 = cost_analysis_dict(_scan_matmul(1))
    c10 = cost_analysis_dict(_scan_matmul(10))
    # 10x the work, ~1x the reported flops (up to loop-counter adds)
    assert c10["flops"] < c1["flops"] * 1.01


@pytest.mark.parametrize("n", [1, 4, 10])
def test_hlo_parser_applies_trip_counts(n):
    size = 128
    compiled = _scan_matmul(n, size)
    t = analyze(compiled.as_text())
    assert t["flops"] == pytest.approx(n * 2 * size ** 3, rel=0.01)


def test_hlo_parser_nested_scans():
    def inner(c, _):
        return c @ c, None

    def outer(c, _):
        c, _ = jax.lax.scan(inner, c, None, length=3)
        return c, None

    x = jnp.ones((64, 64))
    f = jax.jit(lambda x: jax.lax.scan(outer, x, None, length=5)[0])
    t = analyze(f.lower(x).compile().as_text())
    assert t["flops"] == pytest.approx(5 * 3 * 2 * 64 ** 3, rel=0.01)


def test_hlo_parser_counts_collectives_inside_scans():
    devs = jax.devices()
    if len(devs) < 2:
        pytest.skip("needs >1 device (dry-run env sets 512)")


def test_split_computations_roundtrip():
    compiled = _scan_matmul(2)
    comps = split_computations(compiled.as_text())
    assert any("while(" in l for lines in comps.values() for l in lines)


def test_roofline_terms_dominance():
    t = roofline_terms(1e12, 1e9, {"all-reduce": 1e6}, chips=256)
    assert t["dominant"] == "compute_s"
    assert t["compute_s"] == pytest.approx(1e12 / HW().peak_flops)
    t2 = roofline_terms(1e9, 1e12, {"all-reduce": 1e6}, chips=256)
    assert t2["dominant"] == "memory_s"
    t3 = roofline_terms(1e9, 1e9, {"all-to-all": 1e12}, chips=256)
    assert t3["dominant"] == "collective_s"


def test_model_flops_moe_uses_active_params():
    dense = model_flops(1000, 1000, 10, is_train=True)
    moe = model_flops(8000, 1000, 10, is_train=True)
    assert dense == moe == 6 * 1000 * 10
