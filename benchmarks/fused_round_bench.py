"""Fused on-device PAOTA round vs the PR-1 host loop.

The host path (``PAOTAServer.round``) makes ~8 host<->device round-trips
through numpy per aggregation period; the fused path
(``repro.fl.fused.FusedPAOTA``) runs the whole round — scheduler, eq.-25
factors, water-filling P2, channel, power cap (7), AirComp, broadcast +
local train — inside one jitted ``lax.scan`` over R rounds.

Per K in {100, 1000}:

* ``fused_round/host_k{K}``    — host-loop seconds/round (batched engine,
  steady-state after a warmup round).
* ``fused_round/fused_k{K}``   — fused seconds/round from ONE R-round scan
  (steady-state: second ``advance`` call, compile reported as setup_s).
* ``fused_round/speedup_k{K}`` — host / fused.

Both paths run the counter RNG + waterfill_jnp configuration so they
execute the same math (allclose trajectories — tests/test_fused_round.py);
the comparison is purely host orchestration vs on-device scan.

``python -m benchmarks.fused_round_bench smoke`` runs a tiny K=8, R=5 scan
(the CI fast-tier guard that keeps the fused path compiling).
"""
from __future__ import annotations

import sys
import time

import jax
import numpy as np

from benchmarks.fl_engine_bench import _make_clients
from repro.core import ChannelConfig, SchedulerConfig
from repro.fl import FusedPAOTA, PAOTAConfig, PAOTAServer
from repro.models.mlp import init_mlp_params

_ROUNDS = {8: 5, 100: 20, 1000: 10}


def _host_cfgs(k: int, seed: int = 0):
    return (SchedulerConfig(n_clients=k, seed=seed, rng="counter"),
            PAOTAConfig(rng="counter", solver="waterfill_jnp", seed=seed))


def _time_host(k: int, rounds: int, seed: int = 0):
    params = init_mlp_params(jax.random.PRNGKey(seed))
    sched, cfg = _host_cfgs(k, seed)
    t0 = time.perf_counter()
    srv = PAOTAServer(params, _make_clients(k, seed), ChannelConfig(),
                      sched, cfg)
    srv.round()                       # warmup: hits every compile path
    setup = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(rounds):
        srv.round()
    return (time.perf_counter() - t0) / rounds, setup


def _time_fused(k: int, rounds: int, seed: int = 0):
    params = init_mlp_params(jax.random.PRNGKey(seed))
    t0 = time.perf_counter()
    srv = FusedPAOTA(params, _make_clients(k, seed), ChannelConfig(),
                     SchedulerConfig(n_clients=k, seed=seed),
                     PAOTAConfig(seed=seed))
    srv.advance(rounds)               # init + scan compile + first run
    setup = time.perf_counter() - t0
    t0 = time.perf_counter()
    srv.advance(rounds)               # steady-state: one scan device call
    sec = (time.perf_counter() - t0) / rounds
    assert np.isfinite(srv.global_vec).all()
    return sec, setup


def run(ks=(100, 1000)):
    rows = []
    for k in ks:
        rounds = _ROUNDS.get(k, 10)
        host_s, host_setup = _time_host(k, rounds)
        rows.append({"name": f"fused_round/host_k{k}",
                     "us_per_call": round(host_s * 1e6, 1),
                     "derived": f"rounds_per_sec={1.0 / host_s:.3f};"
                                f"setup_s={host_setup:.2f}"})
        fused_s, fused_setup = _time_fused(k, rounds)
        rows.append({"name": f"fused_round/fused_k{k}",
                     "us_per_call": round(fused_s * 1e6, 1),
                     "derived": f"rounds_per_sec={1.0 / fused_s:.3f};"
                                f"scan_rounds={rounds};"
                                f"setup_s={fused_setup:.2f}"})
        rows.append({"name": f"fused_round/speedup_k{k}",
                     "us_per_call": 0,
                     "derived": f"{host_s / fused_s:.2f}x"})
    return rows


def main():
    smoke = "smoke" in sys.argv[1:]
    rows = run(ks=(8,) if smoke else (100, 1000))
    print("name,us_per_call,derived")
    for row in rows:
        print(f"{row['name']},{row['us_per_call']},{row['derived']}",
              flush=True)
    from benchmarks.common import write_bench_artifact
    name = "fused_round_smoke" if smoke else "fused_round"
    path = write_bench_artifact(name, rows)
    print(f"# artifact -> {path}", flush=True)


if __name__ == "__main__":
    main()
