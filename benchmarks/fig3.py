"""Fig. 3 reproduction: train-loss gap vs communication rounds for PAOTA /
Local SGD / COTAF under N0 = -174 dBm/Hz and the high-noise -74 dBm/Hz
regime (PAOTA's noise-aware power control should be the more robust one).

Emits CSV rows: name,us_per_call,derived per harness convention plus a
per-round trajectory CSV under experiments/bench/."""
from __future__ import annotations

import os
import time

from benchmarks.common import BenchSetting, OUT_DIR, build_world, run_algorithm
from repro.fl import write_csv


def run() -> list:
    rows_out = []
    traj = []
    for n0 in (-174.0, -74.0):
        s = BenchSetting.from_env(n0_dbm_hz=n0)
        clients, params, data = build_world(s)
        for algo in ("paota", "local_sgd", "cotaf"):
            t0 = time.time()
            rows = run_algorithm(algo, s, clients, params, data)
            for r in rows:
                r["n0_dbm_hz"] = n0
            traj.extend(rows)
            final = rows[-1]
            rows_out.append({
                "name": f"fig3_{algo}_n0{int(n0)}",
                "us_per_call": round((time.time() - t0) * 1e6 / s.n_rounds, 1),
                "derived": f"final_loss={final['loss']}"
                           f";final_acc={final['accuracy']}",
            })
    write_csv(os.path.join(OUT_DIR, "fig3_trajectories.csv"), traj)
    return rows_out


if __name__ == "__main__":
    for row in run():
        print(f"{row['name']},{row['us_per_call']},{row['derived']}")
