"""Federation-engine benchmark: legacy per-client loop vs batched
vmap/scan engine.

Two levels per K in {12, 100, 1000}:

* ``fl_engine/{engine}_k{K}`` — the engine alone: one full-federation
  broadcast (`engine.local_train` on all K clients, M=5 local steps
  each), steady-state after compile. This is the apples-to-apples number
  behind the speedup row: identical math, identical minibatch streams.
* ``fl_engine/server_{engine}_k{K}`` — full PAOTA server round
  (scheduler + P2 solve + AirComp on top of the engine), the end-to-end
  rounds/sec a training run sees.

The legacy engine re-jits one SGD step per client (K compiles, reported
as setup_s) and makes M host round-trips per client per broadcast; it is
measured only up to K=100 by default — at K=1000 it would spend minutes
compiling 1000 jit caches. Set REPRO_BENCH_FULL=1 to force it. The
batched engine compiles ONCE per federation; a small per-client size
ladder at K=1000 keeps the padded (K, n_max, 784) federation ~200 MB so
the round completes on CPU.
"""
from __future__ import annotations

import os
import time

import jax
import numpy as np

from repro.core import ChannelConfig, SchedulerConfig
from repro.data.partition import partition_noniid
from repro.data.pipeline import build_federation
from repro.data.synthetic import make_mnist_like
from repro.fl import FLClient, PAOTAConfig, PAOTAServer, make_engine
from repro.models.mlp import init_mlp_params, mlp_loss

_SIZES = {1000: (48, 64)}


def _make_clients(k: int, seed: int = 0):
    x, y, _, _ = make_mnist_like(n_train=min(max(100 * k, 2000), 20000),
                                 n_test=10, seed=1234)
    parts = partition_noniid(y, n_clients=k, sizes=_SIZES.get(k), seed=seed)
    fed = build_federation(x, y, parts, seed=seed)
    return [FLClient(d, mlp_loss, batch_size=32, lr=0.1, local_steps=5)
            for d in fed]


def _median_time(fn, reps: int) -> float:
    """Median of per-call wall times — robust to background-load spikes."""
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def _time_engine(kind: str, k: int, reps: int, seed: int = 0):
    """(seconds per full-federation broadcast, setup seconds). Setup is
    engine construction + first call — i.e. all compilation."""
    params = init_mlp_params(jax.random.PRNGKey(seed))
    ids = np.arange(k)
    t0 = time.perf_counter()
    eng = make_engine(_make_clients(k, seed), kind)
    eng.local_train(params, ids)
    setup = time.perf_counter() - t0
    return _median_time(lambda: eng.local_train(params, ids), reps), setup


def _time_server(kind: str, k: int, reps: int, seed: int = 0):
    params = init_mlp_params(jax.random.PRNGKey(seed))
    t0 = time.perf_counter()
    srv = PAOTAServer(params, _make_clients(k, seed), ChannelConfig(),
                      SchedulerConfig(n_clients=k, seed=seed),
                      PAOTAConfig(engine=kind, seed=seed))
    srv.round()  # warmup round (hits every remaining compile path)
    setup = time.perf_counter() - t0
    return _median_time(srv.round, reps), setup


def run():
    full = os.environ.get("REPRO_BENCH_FULL") == "1"
    rows = []
    for k in (12, 100, 1000):
        reps = 3 if k >= 1000 else 7
        per = {}
        for kind in ("legacy", "batched"):
            if kind == "legacy" and k >= 1000 and not full:
                continue  # ~1000 separate jit compiles; REPRO_BENCH_FULL=1
            sec, setup = _time_engine(kind, k, reps)
            per[kind] = sec
            rows.append({"name": f"fl_engine/{kind}_k{k}",
                         "us_per_call": round(sec * 1e6, 1),
                         "derived": f"broadcasts_per_sec={1.0 / sec:.3f};"
                                    f"setup_s={setup:.2f}"})
            ssec, ssetup = _time_server(kind, k, reps)
            rows.append({"name": f"fl_engine/server_{kind}_k{k}",
                         "us_per_call": round(ssec * 1e6, 1),
                         "derived": f"rounds_per_sec={1.0 / ssec:.3f};"
                                    f"setup_s={ssetup:.2f}"})
        if "legacy" in per and "batched" in per:
            rows.append({"name": f"fl_engine/speedup_k{k}",
                         "us_per_call": 0,
                         "derived": f"{per['legacy'] / per['batched']:.2f}x"})
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for row in run():
        print(f"{row['name']},{row['us_per_call']},{row['derived']}")
