"""Multi-pod grouped aggregation: the ("pod", "data") sharded round.

Two tiers, both in a forced-host-device subprocess (the pod mesh must
exist before jax initializes in the parent):

* ``smoke`` — 8 virtual devices as a (2, 4) pod mesh, K=16, N=2.
  Executes amortized window scans (flat vs grouped on the SAME mesh) so
  the grouped path stays compiling-and-running in CI, and pins the
  compiled collective structure: exactly ONE cross-pod model-sized
  all-reduce per N-period window (``repro.launch.collectives`` over the
  compiled HLO).
* ``full`` — 512 virtual devices as the paper-scale (2, 256) pod mesh,
  K=10000, N=4, launch.dryrun-style: lower + compile ONLY (executing
  10k-client rounds on 512 virtual devices sharing 2 physical cores is
  not a measurement of anything). Rows record lower/compile wall time
  and the same cross-pod collective count.

The model-size floor separates the d+1 grouped psums (default MLP:
8071 elements) from the water-filling grid (4096) and the combiner-merged
scalar metrics — same role as the 8192 default in ``collectives``, placed
under this model's size.

``python -m benchmarks.grouped_round_bench smoke`` writes
``BENCH_grouped_round_smoke.json`` (CI_FULL tier; gated by the >2x diff
like every other tracked artifact); ``... full`` writes
``BENCH_grouped_round.json``.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import time

MODEL_SIZE_FLOOR = 4097
_SETTINGS = {          # K -> (size ladder, batch, local steps, scan rounds)
    16: ((48, 64), 32, 5, 24),
    10000: ((16, 24), 16, 2, 8),
}
_TIERS = {             # tier -> (K, group_period, (pods, data))
    "smoke": (16, 2, (2, 4)),
    "full": (10000, 4, (2, 256)),
}


def _make_engine(k: int, seed: int = 0):
    from repro.data.partition import partition_noniid
    from repro.data.pipeline import build_federation
    from repro.data.synthetic import make_mnist_like
    from repro.fl import BatchedEngine
    from repro.models.mlp import mlp_loss
    sizes, batch, steps, _ = _SETTINGS[k]
    x, y, _, _ = make_mnist_like(n_train=min(max(20 * k, 2000), 20000),
                                 n_test=10, seed=1234)
    parts = partition_noniid(y, n_clients=k, sizes=sizes, seed=seed)
    fed = build_federation(x, y, parts, seed=seed)
    return BatchedEngine(fed, mlp_loss, batch_size=batch, lr=0.1,
                        local_steps=steps)


def _make_server(k: int, mesh, group_period: int, seed: int = 0):
    import jax
    from repro.core import ChannelConfig, SchedulerConfig
    from repro.fl import PAOTAConfig, ShardedPAOTA
    from repro.models.mlp import init_mlp_params
    params = init_mlp_params(jax.random.PRNGKey(seed))
    return ShardedPAOTA(params, _make_engine(k, seed), ChannelConfig(),
                        SchedulerConfig(n_clients=k, seed=seed),
                        PAOTAConfig(seed=seed), mesh=mesh,
                        group_period=group_period)


def _collective_rows(srv, mesh, k: int, n: int, scan_rounds: int) -> list:
    """The structural row: cross-pod / intra-pod model-sized all-reduce
    counts in the compiled scan body (one window when grouped)."""
    from repro.launch.collectives import (cross_pod_allreduce_count,
                                          iter_allreduces)
    t0 = time.perf_counter()
    hlo = srv.compiled_scan_hlo(scan_rounds)
    compile_s = time.perf_counter() - t0
    shape = tuple(mesh.shape[a] for a in mesh.axis_names)
    cross = cross_pod_allreduce_count(hlo, shape, (0,),
                                      min_elements=MODEL_SIZE_FLOOR)
    big = sum(1 for sz, _ in iter_allreduces(hlo)
              if sz >= MODEL_SIZE_FLOOR)
    assert cross == 1, (cross, big)       # the grouped contract
    assert big == n, (cross, big)         # N-1 intra-pod partials + 1 sync
    return [{"name": f"grouped_round/collectives_k{k}_n{n}"
                     f"_pods{shape[0]}",
             "us_per_call": round(compile_s * 1e6, 1),
             "derived": f"cross_pod_big_allreduce_per_window={cross};"
                        f"big_allreduce_per_window={big};"
                        f"model_size_floor={MODEL_SIZE_FLOOR};"
                        f"lower_compile_s={compile_s:.2f}"}]


def _measure_smoke() -> list:
    """8 virtual devices: run flat and grouped window scans on the same
    (2, 4) pod mesh; amortized seconds/round over the chunked scan."""
    import numpy as np
    from repro.launch.mesh import make_pod_mesh
    k, n, (pods, data) = _TIERS["smoke"]
    rounds = _SETTINGS[k][3]
    mesh = make_pod_mesh(pods=pods, data=data)
    rows = []
    secs = {}
    grouped_srv = None
    for label, period in (("flat", 0), (f"grouped_n{n}", n)):
        t0 = time.perf_counter()
        srv = _make_server(k, mesh, period)
        srv.advance(rounds)
        setup = time.perf_counter() - t0
        t0 = time.perf_counter()
        srv.advance(rounds)
        sec = (time.perf_counter() - t0) / rounds
        secs[label] = sec
        assert np.isfinite(srv.global_vec).all()
        if period:
            grouped_srv = srv
        rows.append({"name": f"grouped_round/{label}_k{k}_pods{pods}",
                     "us_per_call": round(sec * 1e6, 1),
                     "derived": f"rounds_per_sec={1.0 / sec:.3f};"
                                f"scan_rounds={rounds};"
                                f"setup_s={setup:.2f}"})
    rows.append({"name": f"grouped_round/grouped_vs_flat_k{k}",
                 "us_per_call": 0,
                 "derived": f"{secs['flat'] / secs[f'grouped_n{n}']:.2f}x"})
    rows += _collective_rows(grouped_srv, mesh, k, n, rounds)
    return rows


def _measure_full() -> list:
    """512 virtual devices, K=10000, N=4 — dryrun-style: construction +
    lower + compile of the grouped window scan, no execution."""
    from repro.launch.mesh import make_pod_mesh
    k, n, (pods, data) = _TIERS["full"]
    rounds = _SETTINGS[k][3]
    mesh = make_pod_mesh(pods=pods, data=data)
    t0 = time.perf_counter()
    srv = _make_server(k, mesh, n)
    setup = time.perf_counter() - t0
    rows = _collective_rows(srv, mesh, k, n, rounds)
    rows[0]["derived"] += (f";setup_s={setup:.2f};k_pad={srv.k_pad};"
                           f"k_local={srv.k_local};devices={mesh.size};"
                           f"dryrun=lower_compile_only")
    return rows


def run(tier: str = "full") -> list:
    """benchmarks.run entry: re-exec with the tier's forced host device
    count (jax may already be initialized single-device in the caller)."""
    _, _, (pods, data) = _TIERS[tier]
    env = dict(os.environ)
    force = f"--xla_force_host_platform_device_count={pods * data}"
    if "xla_force_host_platform_device_count" not in env.get("XLA_FLAGS", ""):
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " " + force).strip()
    with tempfile.NamedTemporaryFile("r", suffix=".json") as f:
        cmd = [sys.executable, "-m", "benchmarks.grouped_round_bench",
               "--emit", f.name, tier]
        subprocess.run(cmd, env=env, check=True,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
        return json.load(open(f.name))


def main():
    args = sys.argv[1:]
    if "--emit" in args:                     # forced-device child
        i = args.index("--emit")
        out_path, tier = args[i + 1], args[i + 2]
        rows = _measure_smoke() if tier == "smoke" else _measure_full()
        with open(out_path, "w") as f:
            json.dump(rows, f)
        return
    tier = "full" if "full" in args else "smoke"
    rows = run(tier)
    print("name,us_per_call,derived")
    for row in rows:
        print(f"{row['name']},{row['us_per_call']},{row['derived']}",
              flush=True)
    from benchmarks.common import write_bench_artifact
    k, n, (pods, data) = _TIERS[tier]
    name = "grouped_round_smoke" if tier == "smoke" else "grouped_round"
    path = write_bench_artifact(
        name, rows, extra={"k": k, "group_period": n,
                           "mesh": {"pod": pods, "data": data},
                           "forced_devices": pods * data})
    print(f"# artifact -> {path}", flush=True)


if __name__ == "__main__":
    main()
