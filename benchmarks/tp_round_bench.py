"""Intra-client TP under the sharded round: the ("pod","data","tp") mesh.

The perf headline of the TP topology: federating a model whose per-device
carry footprint drops ~1/TP because the stacked (K_local, ...) pending
and deltas planes TP-shard their model dims, while wall-clock stays in
the same regime (the round adds only small tp-spanning stats psums; the
one cross-client model-sized all-reduce now also gathers the TP blocks).

Two tiers, both in a forced-8-host-device subprocess (the mesh must
exist before jax initializes in the parent):

* ``smoke`` — the hidden-128 MLP federation (d = 118,281), K=8, executed
  across tp in {1, 2, 4} on meshes (1,2) / (1,2,2) / (1,2,4). The DATA
  extent is pinned at 2 (k_local = 4 on every rung) so the TP ladder
  scales the device pool 2 -> 4 -> 8 and the per-device carry drop is
  the TP split itself, not client resharding in disguise. Rows record
  amortized seconds/round, per-device payload-plane bytes
  (pending + deltas, ``addressable_shards[0]``), and the compiled
  collective structure (exactly ONE cross-client model-sized all-reduce,
  which spans the tp axis too).
* ``full`` — the minicpm-2b-reduced transformer client federation
  (pytree mode, name-based TP placement; every REDUCED model dim divides
  4), same tp ladder, executed. This is the acceptance artifact:
  ``BENCH_tp_round.json`` shows per-device carry bytes falling ~1/TP.

``python -m benchmarks.tp_round_bench smoke`` writes
``BENCH_tp_round_smoke.json`` (CI_FULL tier; gated by the >2x diff like
every other tracked artifact); ``... full`` writes ``BENCH_tp_round.json``.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import time

MODEL_SIZE_FLOOR = 4097     # above the 4096 water-filling grid psum
_TP_LADDER = (1, 2, 4)
_DEVICES = 8
_ROUNDS = {"smoke": 12, "full": 6}


def _clients_mlp(k: int = 8, seed: int = 0):
    from repro.data.partition import partition_noniid
    from repro.data.pipeline import build_federation
    from repro.data.synthetic import make_mnist_like
    from repro.fl import FLClient
    from repro.models.mlp import mlp_loss
    x, y, _, _ = make_mnist_like(n_train=2000, n_test=10, seed=1234)
    parts = partition_noniid(y, n_clients=k, seed=seed)
    return [FLClient(d, mlp_loss, batch_size=32, lr=0.1, local_steps=5)
            for d in build_federation(x, y, parts)]


def _clients_transformer(cfg, k: int = 8, n: int = 8, seq: int = 16):
    import numpy as np
    from repro.data.pipeline import ClientData
    from repro.fl import FLClient
    from repro.models.transformer import loss_fn
    rng = np.random.default_rng(0)

    def tloss(p, batch):
        return loss_fn(p, {"tokens": batch["x"]}, cfg)[0]

    return [FLClient(ClientData(
        rng.integers(0, cfg.vocab_size, (n, seq)).astype(np.int32),
        np.zeros(n, np.int32), i), tloss, batch_size=4, lr=0.01,
        local_steps=2) for i in range(k)]


def _make_server(tier: str, tp: int, seed: int = 0):
    import jax
    from repro.core import ChannelConfig, SchedulerConfig
    from repro.fl import PAOTAConfig, ShardedPAOTA
    from repro.launch.mesh import make_pod_mesh
    # data extent pinned: every rung keeps k_local = K/2, so per-device
    # payload bytes isolate the TP split (tp=1 uses 2 of the 8 devices)
    mesh = make_pod_mesh(pods=1, data=2, tp=tp)
    if tier == "smoke":
        from repro.models.mlp import init_mlp_params
        params = init_mlp_params(jax.random.PRNGKey(seed), hidden=128)
        clients, cfg = _clients_mlp(seed=seed), None
    else:
        from repro.configs.minicpm_2b import REDUCED as cfg
        from repro.models.transformer import init_model
        params = init_model(jax.random.PRNGKey(seed), cfg)
        clients = _clients_transformer(cfg)
    return ShardedPAOTA(params, clients, ChannelConfig(),
                        SchedulerConfig(n_clients=len(clients), seed=seed),
                        PAOTAConfig(seed=seed), mesh=mesh,
                        params_mode="pytree", model_cfg=cfg), mesh


def _payload_bytes_per_device(srv) -> int:
    """Per-device bytes of the model-plane carry (pending + deltas): the
    footprint the TP split is supposed to divide."""
    import jax
    total = 0
    for plane in (srv._carry.pending, srv._carry.deltas):
        if plane is None:
            continue
        for leaf in jax.tree_util.tree_leaves(plane):
            total += leaf.addressable_shards[0].data.nbytes
    return total


def _collective_counts(srv, mesh, rounds: int):
    from repro.launch.collectives import axis_crossing_allreduce_count
    hlo = srv.compiled_scan_hlo(rounds)
    shape = tuple(mesh.shape[a] for a in mesh.axis_names)
    names = mesh.axis_names
    client_dims = tuple(i for i, a in enumerate(names) if a != "tp")
    cross_client = axis_crossing_allreduce_count(
        hlo, shape, client_dims, min_elements=MODEL_SIZE_FLOOR)
    if "tp" in names:
        tp_dims = (names.index("tp"),)
        cross_tp = axis_crossing_allreduce_count(
            hlo, shape, tp_dims, min_elements=MODEL_SIZE_FLOOR)
        small_tp = axis_crossing_allreduce_count(
            hlo, shape, tp_dims, max_elements=MODEL_SIZE_FLOOR - 1)
    else:
        cross_tp, small_tp = 0, 0
    return cross_client, cross_tp, small_tp


def _measure(tier: str) -> list:
    import numpy as np
    rounds = _ROUNDS[tier]
    rows = []
    bytes_at = {}
    for tp in _TP_LADDER:
        t0 = time.perf_counter()
        srv, mesh = _make_server(tier, tp)
        srv.advance(rounds)
        setup = time.perf_counter() - t0
        t0 = time.perf_counter()
        srv.advance(rounds)
        sec = (time.perf_counter() - t0) / rounds
        assert np.isfinite(srv.global_vec).all()
        pdev = _payload_bytes_per_device(srv)
        bytes_at[tp] = pdev
        cross_client, cross_tp, small_tp = _collective_counts(
            srv, mesh, rounds)
        # the structural contract: ONE cross-client model-sized psum,
        # and at tp > 1 that same op spans the tp axis (gather folded in)
        assert cross_client == 1, (tp, cross_client)
        if tp > 1:
            assert cross_tp == 1, (tp, cross_tp)
        rows.append({
            "name": f"tp_round/{tier}_tp{tp}",
            "us_per_call": round(sec * 1e6, 1),
            "derived": f"rounds_per_sec={1.0 / sec:.3f};"
                       f"scan_rounds={rounds};setup_s={setup:.2f};"
                       f"payload_bytes_per_device={pdev};"
                       f"cross_client_big_allreduce={cross_client};"
                       f"tp_spanning_big_allreduce={cross_tp};"
                       f"tp_spanning_small_allreduce={small_tp};"
                       f"mesh={'x'.join(str(mesh.shape[a]) for a in mesh.axis_names)}"})
    for tp in _TP_LADDER[1:]:
        rows.append({"name": f"tp_round/{tier}_bytes_ratio_tp{tp}",
                     "us_per_call": 0,
                     "derived": f"per_device_bytes_tp1_over_tp{tp}="
                                f"{bytes_at[1] / bytes_at[tp]:.2f}x"})
    return rows


def run(tier: str = "full") -> list:
    """benchmarks.run entry: re-exec with forced host devices (jax may
    already be initialized single-device in the caller)."""
    env = dict(os.environ)
    force = f"--xla_force_host_platform_device_count={_DEVICES}"
    if "xla_force_host_platform_device_count" not in env.get("XLA_FLAGS", ""):
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " " + force).strip()
    with tempfile.NamedTemporaryFile("r", suffix=".json") as f:
        cmd = [sys.executable, "-m", "benchmarks.tp_round_bench",
               "--emit", f.name, tier]
        subprocess.run(cmd, env=env, check=True,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
        return json.load(open(f.name))


def main():
    args = sys.argv[1:]
    if "--emit" in args:                     # forced-device child
        i = args.index("--emit")
        out_path, tier = args[i + 1], args[i + 2]
        rows = _measure(tier)
        with open(out_path, "w") as f:
            json.dump(rows, f)
        return
    tier = "full" if "full" in args else "smoke"
    rows = run(tier)
    print("name,us_per_call,derived")
    for row in rows:
        print(f"{row['name']},{row['us_per_call']},{row['derived']}",
              flush=True)
    from benchmarks.common import write_bench_artifact
    name = "tp_round_smoke" if tier == "smoke" else "tp_round"
    path = write_bench_artifact(
        name, rows, extra={"tp_ladder": list(_TP_LADDER),
                           "forced_devices": _DEVICES,
                           "model": ("mlp_hidden128" if tier == "smoke"
                                     else "minicpm-2b-reduced")})
    print(f"# artifact -> {path}", flush=True)


if __name__ == "__main__":
    main()
