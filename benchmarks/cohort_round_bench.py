"""Active-cohort round benchmark: million-client state plane, (m, d)
payload plane.

What the cohort refactor buys is a carry that stops scaling as K x d:
the (K,) scheduler/scenario state plane is O(K) scalars, and model-sized
rows exist only for the m in-flight cohort slots. This module measures
that directly, at three federation scales:

* ``K = 1e3`` — the full driver path (``FusedPAOTA``, real MLP engine,
  d ~= 55k): dense vs ``cohort_size=64`` seconds/round and carry bytes —
  the apples-to-apples driver comparison;
* ``K = 1e3 / 1e5`` — a synthetic runtime-level harness (raw
  ``repro.fl.runtime`` scan with fabricated train/channel/scenario
  streams, d = 16384, m = 256): dense vs cohort where dense still fits,
  cohort alone at 1e5 (the dense carry would be ~6.5 GB — reported
  analytically in ``derived``);
* ``K = 1e6, state-plane-only`` — the acceptance run: the full scenario
  simulator (availability cycle + dropouts), scheduler advance, priority
  top-k slot refill, and AirComp over m = 256 payload rows advance 10
  aggregation periods on the 2-core CPU host. Only (m, d) payloads ever
  materialize; the dense equivalent (64 TB) is physically impossible on
  this box, which is the point.

Every row reports ``carry_bytes`` (actual, summed over the carry's
leaves) and ``dense_carry_bytes`` (what the dense layout would hold at
that K) in ``derived``.

Compressed-payload rows (``_rm16`` = randmask s/d = 1/16, ``_int8`` =
int8 slot storage) measure the next notch: the (m, d) payload plane
shrinks to (m, s) values + (m, s) indices, so at K = 1e6 the carry drops
from the PR 7 ~25.9 MB to the state plane plus a few MB of compressed
slots. The K = 1e6 headline runs error feedback OFF — the parked (K, s)
residual planes are a per-client cost that would reintroduce K-scaling.

``python -m benchmarks.cohort_round_bench smoke`` runs the synthetic
K=1e3 dense/cohort/compressed set only and writes
``BENCH_cohort_round_smoke.json`` (CI fast tier, >2x diff gate); the full
run adds the driver rows and the 1e5/1e6 scales and writes
``BENCH_cohort_round.json`` — committed under experiments/bench/.
"""
from __future__ import annotations

import os
import sys
import time

_SYNTH_D = 16384
_SYNTH_M = 256
_ROUNDS = 10


def _row(name: str, sec: float, setup: float, rounds: int,
         carry_bytes: int, dense_bytes: int) -> dict:
    return {"name": name, "us_per_call": round(sec * 1e6, 1),
            "derived": f"rounds_per_sec={1.0 / sec:.3f};"
                       f"scan_rounds={rounds};setup_s={setup:.2f};"
                       f"carry_bytes={carry_bytes};"
                       f"dense_carry_bytes={dense_bytes}"}


def _carry_bytes(carry) -> int:
    import jax
    return int(sum(l.size * l.dtype.itemsize
                   for l in jax.tree_util.tree_leaves(carry)))


def _dense_bytes(k: int, d: int) -> int:
    """Dense-layout carry footprint at (K, d): the transmit='delta' delta
    plane (K x d f32) + two model copies + the (K,) state plane."""
    return 4 * (k * d + 2 * d) + k * (1 + 4 + 4)


# ---------------------------------------------------------------------------
# synthetic runtime-level harness: the round core with fabricated streams
# ---------------------------------------------------------------------------

def _synth_scan(k: int, m: int, rounds: int = _ROUNDS, *,
                compress: str = "", ratio: float = 1.0,
                slot_dtype: str = "", error_feedback: bool = False):
    """Time the raw ``scan_rounds`` over the cohort (m >= 1) or dense
    (m = 0) carry with synthetic streams: fabricated local updates
    (g + 1e-3 noise rows keyed per round), the real counter latency /
    channel / priority draws, and the full scenario simulator
    (availability cycle + dropouts) over all K clients. ``compress``
    switches the payload plane to the (m, s) compressed form,
    s = round(d * ratio)."""
    import jax
    import jax.numpy as jnp

    from repro.core.aircomp import ChannelConfig, sample_channel_gains
    from repro.core.compress import randmask_indices
    from repro.core.power_control import p2_constants
    from repro.core.scheduler import (TAG_CHANNEL, TAG_COMPRESS, TAG_NOISE,
                                      TAG_QUANT, TAG_SCHED, ScenarioConfig,
                                      counter_latencies, round_tag_key,
                                      scenario_masks)
    from repro.fl.runtime import (RoundCfg, RoundStreams, init_cohort_carry,
                                  init_round_carry, scan_rounds)

    d = _SYNTH_D
    key = jax.random.PRNGKey(0)
    chan = ChannelConfig()
    sc = ScenarioConfig(availability="cycle", avail_period=4,
                        avail_duty=0.5, dropout_prob=0.05)
    c1, c0 = p2_constants(10.0, 0.05, k, d, chan.sigma_n2)
    s = min(d, max(1, round(d * ratio)))
    rcfg = RoundCfg(omega=3.0, c1=c1, c0=c0, p_max_watts=chan.p_max_watts,
                    sigma_n=chan.sigma_n, delta_t=8.0, transmit_delta=True,
                    cohort_size=m, compress=compress,
                    compress_s=s if compress else 0,
                    slot_dtype=((slot_dtype or "float32") if compress
                                else ""),
                    error_feedback=bool(error_feedback and compress))

    def fan(g, r, ids):
        # tag 12: clear of the scheduler's reserved draw tags (0-9)
        n = jax.random.normal(round_tag_key(key, r, 12),
                              (ids.shape[0], d), jnp.float32)
        return g[None, :] + jnp.float32(1e-3) * n

    compress_mask = quant_key = None
    if compress == "randmask" and s < d:
        compress_mask = lambda r: randmask_indices(
            round_tag_key(key, r, TAG_COMPRESS), d, s)
    if rcfg.slot_dtype == "int8":
        quant_key = lambda r: round_tag_key(key, r, TAG_QUANT)
    streams = RoundStreams(
        local_train=lambda g, x, y, r: fan(g, r, jnp.arange(k)),
        latencies=lambda r: counter_latencies(key, r, k, 5.0, 15.0),
        channel=lambda t: sample_channel_gains(
            round_tag_key(key, t, TAG_CHANNEL), k, chan),
        noise_key=lambda t: round_tag_key(key, t, TAG_NOISE),
        scenario=lambda t: scenario_masks(key, t, k, sc),
        cohort_train=lambda g, x, y, r, ids: fan(g, r, ids),
        sched_priority=lambda r: jax.random.uniform(
            round_tag_key(key, r, TAG_SCHED), (k,)),
        compress_mask=compress_mask,
        quant_key=quant_key,
    )
    g0 = jnp.zeros((d,), jnp.float32)
    x = y = jnp.zeros((1,), jnp.float32)

    t0 = time.perf_counter()
    if m:
        carry = jax.jit(lambda v: init_cohort_carry(
            v, x, y, streams=streams, k=k, m=m, pending_dtype="float32",
            keep_pending=False, rcfg=rcfg))(g0)
    else:
        carry = jax.jit(lambda v: init_round_carry(
            v, x, y, streams=streams, pending_dtype="float32",
            keep_pending=False))(g0)
    nbytes = _carry_bytes(carry)
    scan = jax.jit(lambda c: scan_rounds(c, x, y, rounds, rcfg=rcfg,
                                         streams=streams),
                   donate_argnums=(0,))
    carry, outs = jax.block_until_ready(scan(carry))    # compile + run
    setup = time.perf_counter() - t0
    t0 = time.perf_counter()
    carry, outs = jax.block_until_ready(scan(carry))    # steady state
    sec = (time.perf_counter() - t0) / rounds
    import numpy as np
    assert np.isfinite(np.asarray(carry.global_vec)).all()
    return sec, setup, nbytes


def _synth_rows(ks_cohort, with_dense_1e3: bool) -> list:
    rows = []
    if with_dense_1e3:
        sec, setup, nb = _synth_scan(1000, 0)
        rows.append(_row("cohort_round/synth_dense_k1000", sec, setup,
                         _ROUNDS, nb, _dense_bytes(1000, _SYNTH_D)))
    for k in ks_cohort:
        sec, setup, nb = _synth_scan(k, _SYNTH_M)
        rows.append(_row(f"cohort_round/synth_cohort_m{_SYNTH_M}_k{k}",
                         sec, setup, _ROUNDS, nb,
                         _dense_bytes(k, _SYNTH_D)))
    return rows


def _synth_compressed_rows(ks, *, slot_dtype: str = "",
                           error_feedback: bool = False) -> list:
    """randmask s/d = 1/16 compressed-cohort rows. EF defaults OFF: the
    parked (K, s) residual planes scale per-client, which is exactly what
    the K = 1e6 headline must not pay."""
    rows = []
    sfx = "_rm16" + (f"_{slot_dtype}" if slot_dtype else "")
    if error_feedback:
        sfx += "_ef"
    for k in ks:
        sec, setup, nb = _synth_scan(k, _SYNTH_M, compress="randmask",
                                     ratio=1.0 / 16.0,
                                     slot_dtype=slot_dtype,
                                     error_feedback=error_feedback)
        rows.append(_row(f"cohort_round/synth_cohort_m{_SYNTH_M}_k{k}{sfx}",
                         sec, setup, _ROUNDS, nb,
                         _dense_bytes(k, _SYNTH_D)))
    return rows


# ---------------------------------------------------------------------------
# driver-level rows: the real FusedPAOTA path at K = 1e3
# ---------------------------------------------------------------------------

def _driver_rows(k: int = 1000, m: int = 64) -> list:
    import jax
    import numpy as np

    from repro.core import ChannelConfig, SchedulerConfig
    from repro.data.partition import partition_noniid
    from repro.data.pipeline import build_federation
    from repro.data.synthetic import make_mnist_like
    from repro.fl import BatchedEngine, FusedPAOTA, PAOTAConfig
    from repro.models.mlp import init_mlp_params, mlp_loss

    x, y, _, _ = make_mnist_like(n_train=20000, n_test=10, seed=1234)
    parts = partition_noniid(y, n_clients=k, sizes=(16, 24), seed=0)

    def srv(cohort, **kw):
        fed = build_federation(x, y, parts, seed=0)
        eng = BatchedEngine(fed, mlp_loss, batch_size=1, lr=0.1,
                            local_steps=1)
        return FusedPAOTA(init_mlp_params(jax.random.PRNGKey(0)), eng,
                          ChannelConfig(), SchedulerConfig(n_clients=k,
                                                           seed=0),
                          PAOTAConfig(transmit="delta"),
                          cohort_size=cohort, **kw)

    rows = []
    configs = (("dense", None, {}), (f"cohort_m{m}", m, {}),
               # compressed driver row: randmask 1/16 with error feedback
               # (the driver scale can afford the (K, s) parked planes)
               (f"cohort_m{m}_rm16", m,
                {"compress": "randmask", "compress_ratio": 1.0 / 16.0}))
    for label, cohort, kw in configs:
        t0 = time.perf_counter()
        s = srv(cohort, **kw)
        s.advance(_ROUNDS)
        setup = time.perf_counter() - t0
        nb = _carry_bytes(s._carry)
        t0 = time.perf_counter()
        s.advance(_ROUNDS)
        sec = (time.perf_counter() - t0) / _ROUNDS
        assert np.isfinite(s.global_vec).all()
        rows.append(_row(f"cohort_round/fused_{label}_mlp_k{k}", sec, setup,
                         _ROUNDS, nb, _dense_bytes(k, s.d)))
    return rows


def run(smoke: bool = False) -> list:
    rows = _synth_rows((1000,), with_dense_1e3=True)
    # compressed smoke pair: f32 slots with EF on (the accuracy-preserving
    # config), int8 slots EF off (the smallest carry)
    rows += _synth_compressed_rows((1000,), error_feedback=True)
    rows += _synth_compressed_rows((1000,), slot_dtype="int8")
    if smoke:
        return rows
    rows += _driver_rows()
    # the acceptance scales: K = 1e5, then the million-client state plane
    # advancing 10 periods with only (m, d) payload rows materialized
    rows += _synth_rows((100_000, 1_000_000), with_dense_1e3=False)
    # the compressed headline: K = 1e6 at s/d = 1/16 (EF off — parked
    # residuals would reintroduce per-client payload scaling)
    rows += _synth_compressed_rows((1_000_000,))
    rows += _synth_compressed_rows((1_000_000,), slot_dtype="int8")
    return rows


def main():
    smoke = "smoke" in sys.argv[1:]
    rows = run(smoke=smoke)
    print("name,us_per_call,derived")
    for row in rows:
        print(f"{row['name']},{row['us_per_call']},{row['derived']}",
              flush=True)
    from benchmarks.common import write_bench_artifact
    name = "cohort_round_smoke" if smoke else "cohort_round"
    path = write_bench_artifact(
        name, rows, extra={"synth_d": _SYNTH_D, "synth_m": _SYNTH_M,
                           "rounds": _ROUNDS, "smoke": smoke})
    print(f"# artifact -> {path}", flush=True)


if __name__ == "__main__":
    main()
