"""Theorem-1 bound benchmark: checks A^r < 1 for the run's hyperparameters
and reports the controllable gap terms (d)+(e) before/after power control —
the quantity PAOTA's P2 optimization minimizes each round."""
from __future__ import annotations

import time

import numpy as np

from repro.core import (BoundConstants, ChannelConfig, build_p2,
                        contraction_A, gap_G, solve_p2)


def run() -> list:
    rows = []
    # contraction regime: with L=10, M=5 (Sec. IV-A) the recursion contracts
    # for small enough eta/delta/vartheta — this is the `A(t) < 1` check the
    # paper requires below Theorem 1.
    consts = BoundConstants(eta=0.002, local_steps=5, smooth_l=10.0,
                            delta=0.001, vartheta=0.5)
    a = contraction_A(consts)
    rows.append({"name": "bound_contraction_A", "us_per_call": 0,
                 "derived": f"A={a:.4f};contracts={a < 1}"})
    assert a < 1

    rng = np.random.default_rng(0)
    chan = ChannelConfig()
    k = 100
    rho = 3.0 / (rng.integers(0, 4, k) + 3.0)
    theta = rng.uniform(0.0, 1.0, k)
    b = (rng.random(k) < 0.6).astype(float)
    prob = build_p2(rho, theta, np.full(k, chan.p_max_watts), b,
                    smooth_l=10.0, eps_bound=0.05, model_dim=8070,
                    sigma_n2=chan.sigma_n2)
    t0 = time.time()
    res = solve_p2(prob, "waterfill")
    dt = (time.time() - t0) * 1e6

    # naive power choice (everyone transmits at p_max) vs optimized
    naive = prob.objective(np.ones(k) * 0.0 + 1.0)  # beta=1: pure staleness
    uniform = prob.objective(np.full(k, 0.5))
    rows.append({"name": "bound_p2_waterfill_K100",
                 "us_per_call": round(dt, 1),
                 "derived": f"obj={res.objective:.6g};naive={naive:.6g};"
                            f"uniform={uniform:.6g};"
                            f"improvement={(uniform - res.objective) / uniform:.2%}"})

    alphas = prob.power(res.beta) * b
    alphas = alphas / max(alphas.sum(), 1e-12)
    g = gap_G(consts, alphas, float((prob.power(res.beta) * b).sum()),
              model_dim=8070, sigma_n2=chan.sigma_n2)
    rows.append({"name": "bound_gap_terms", "us_per_call": 0,
                 "derived": f"d={g['d']:.4g};e={g['e']:.4g};"
                            f"total={g['total']:.4g}"})
    return rows


if __name__ == "__main__":
    for row in run():
        print(f"{row['name']},{row['us_per_call']},{row['derived']}")
