"""Roofline benchmark (deliverable g): reads the dry-run JSON records and
emits one row per (arch x shape x mesh) with the three roofline terms in
seconds, the dominant bottleneck, and the MODEL_FLOPS/HLO_FLOPs ratio.

Run ``python -m repro.launch.dryrun --all --mesh both`` first (or rely on
cached records under experiments/dryrun)."""
from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_OUT", "experiments/dryrun")


def load_records():
    recs = []
    for p in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def run() -> list:
    rows = []
    for r in load_records():
        name = f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}"
        if r.get("status") == "skipped":
            rows.append({"name": name, "us_per_call": 0,
                         "derived": f"skipped:{r.get('note', '')}"})
            continue
        if r.get("status") != "ok":
            rows.append({"name": name, "us_per_call": 0,
                         "derived": f"ERROR:{r.get('note', '')[:80]}"})
            continue
        t = r["roofline"]
        ratio = r.get("useful_flops_ratio")
        rows.append({
            "name": name,
            "us_per_call": round(max(t["compute_s"], t["memory_s"],
                                     t["collective_s"]) * 1e6, 2),
            "derived": (f"compute={t['compute_s']:.3e}s;"
                        f"memory={t['memory_s']:.3e}s;"
                        f"collective={t['collective_s']:.3e}s;"
                        f"dominant={t['dominant']};"
                        f"useful_flops={'' if ratio is None else f'{ratio:.2f}'}"),
        })
    if not rows:
        rows.append({"name": "roofline_missing", "us_per_call": 0,
                     "derived": "no dry-run records; run repro.launch.dryrun"})
    return rows


if __name__ == "__main__":
    for row in run():
        print(f"{row['name']},{row['us_per_call']},{row['derived']}")
