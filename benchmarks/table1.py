"""Table I reproduction: rounds and simulated time to reach each target
test accuracy, per algorithm. The paper's claim: PAOTA needs MORE rounds
but LESS time than ideal Local SGD (e.g. -25% time to 80%)."""
from __future__ import annotations

import os
import time

from benchmarks.common import BenchSetting, OUT_DIR, build_world, run_algorithm
from repro.fl import time_to_accuracy, write_csv

TARGETS = (0.5, 0.6, 0.7, 0.8)


def run() -> list:
    s = BenchSetting.from_env()
    clients, params, data = build_world(s)
    rows_out, table = [], []
    for algo in ("paota", "local_sgd", "cotaf"):
        t0 = time.time()
        rows = run_algorithm(algo, s, clients, params, data)
        tta = time_to_accuracy(rows, TARGETS)
        derived = []
        for tgt, (rnd, tm) in tta.items():
            table.append({"algo": algo, "target": tgt, "round": rnd,
                          "time_s": tm})
            derived.append(f"acc{int(tgt * 100)}@"
                           f"{'-' if tm is None else round(tm, 1)}s")
        rows_out.append({
            "name": f"table1_{algo}",
            "us_per_call": round((time.time() - t0) * 1e6 / s.n_rounds, 1),
            "derived": ";".join(derived),
        })
    write_csv(os.path.join(OUT_DIR, "table1.csv"), table)
    return rows_out


if __name__ == "__main__":
    for row in run():
        print(f"{row['name']},{row['us_per_call']},{row['derived']}")
