"""Beyond-paper ablations of PAOTA's power-control trade-off (eq. 25):

  * solver ablation: exact water-filling vs the paper's Dinkelbach path vs
    fixed beta corners (beta=1 staleness-only, beta=0 similarity-only,
    beta=0.5) — measures how much the P2 optimization actually buys in
    end-task accuracy, not just in the bound.
  * partitioner ablation: paper's shard partition vs Dirichlet(0.3).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import BenchSetting, build_world, run_algorithm
from repro.fl import PAOTAConfig, PAOTAServer
from repro.core import ChannelConfig, SchedulerConfig
from repro.fl.metrics import evaluate
from repro.models.mlp import mlp_apply


class _FixedBetaServer(PAOTAServer):
    def __init__(self, *args, beta: float, **kw):
        self._beta = beta
        super().__init__(*args, **kw)

    def round(self):
        import repro.fl.server as srv_mod
        from repro.core.dinkelbach import SolveResult

        orig = srv_mod.solve_p2
        beta = self._beta

        def fixed(prob, method):
            b = np.full(prob.K, beta)
            return SolveResult(beta=b, objective=prob.objective(b),
                               lam=0.0, iterations=0, inner=f"fixed{beta}")

        srv_mod.solve_p2 = fixed
        try:
            return super().round()
        finally:
            srv_mod.solve_p2 = orig


def run() -> list:
    rows = []
    s = BenchSetting.from_env(n_rounds=30)
    clients, params, data = build_world(s)
    x_tr, y_tr, x_te, y_te = data
    chan = ChannelConfig(n0_dbm_hz=-74.0)   # noisy regime: power control matters

    variants = {
        "waterfill": lambda: PAOTAServer(
            params, clients, chan, SchedulerConfig(n_clients=s.n_clients,
                                                   seed=s.seed),
            PAOTAConfig(solver="waterfill")),
        "pgd": lambda: PAOTAServer(
            params, clients, chan, SchedulerConfig(n_clients=s.n_clients,
                                                   seed=s.seed),
            PAOTAConfig(solver="pgd")),
        "beta1_staleness_only": lambda: _FixedBetaServer(
            params, clients, chan, SchedulerConfig(n_clients=s.n_clients,
                                                   seed=s.seed),
            PAOTAConfig(), beta=1.0),
        "beta0_similarity_only": lambda: _FixedBetaServer(
            params, clients, chan, SchedulerConfig(n_clients=s.n_clients,
                                                   seed=s.seed),
            PAOTAConfig(), beta=0.0),
        "beta05_fixed": lambda: _FixedBetaServer(
            params, clients, chan, SchedulerConfig(n_clients=s.n_clients,
                                                   seed=s.seed),
            PAOTAConfig(), beta=0.5),
    }
    for name, make in variants.items():
        srv = make()
        t0 = time.time()
        for _ in range(s.n_rounds):
            srv.round()
        acc = evaluate(srv.global_params(), x_te, y_te, mlp_apply)["accuracy"]
        rows.append({"name": f"ablation_{name}",
                     "us_per_call": round((time.time() - t0) * 1e6 / s.n_rounds, 1),
                     "derived": f"acc@{s.n_rounds}rounds={acc:.4f}"})

    # partitioner ablation
    from repro.data.partition import partition_dirichlet
    from repro.data.pipeline import build_federation
    from repro.fl import FLClient
    from repro.models.mlp import mlp_loss
    parts = partition_dirichlet(y_tr, n_clients=s.n_clients, alpha=0.3,
                                seed=s.seed)
    fed = build_federation(x_tr, y_tr, parts, seed=s.seed)
    dcl = [FLClient(d, mlp_loss, batch_size=s.batch_size, lr=s.lr,
                    local_steps=s.local_steps) for d in fed]
    srv = PAOTAServer(params, dcl, chan,
                      SchedulerConfig(n_clients=s.n_clients, seed=s.seed),
                      PAOTAConfig(solver="waterfill"))
    t0 = time.time()
    for _ in range(s.n_rounds):
        srv.round()
    acc = evaluate(srv.global_params(), x_te, y_te, mlp_apply)["accuracy"]
    rows.append({"name": "ablation_dirichlet_partition",
                 "us_per_call": round((time.time() - t0) * 1e6 / s.n_rounds, 1),
                 "derived": f"acc@{s.n_rounds}rounds={acc:.4f}"})
    rows.extend(run_transmit_ablation())
    return rows


if __name__ == "__main__":
    for row in run():
        print(f"{row['name']},{row['us_per_call']},{row['derived']}")


def run_transmit_ablation() -> list:
    """Model- vs delta-transmission under a channel harsh enough to break
    the paper's full-model uplink (the failure mode recorded in §Repro)."""
    rows = []
    s = BenchSetting.from_env(n_rounds=25)
    clients, params, data = build_world(s)
    _, _, x_te, y_te = data
    chan = ChannelConfig(n0_dbm_hz=-34.0)
    for mode in ("model", "delta"):
        srv = PAOTAServer(params, clients, chan,
                          SchedulerConfig(n_clients=s.n_clients, seed=s.seed),
                          PAOTAConfig(solver="waterfill", transmit=mode))
        t0 = time.time()
        for _ in range(s.n_rounds):
            srv.round()
        acc = evaluate(srv.global_params(), x_te, y_te, mlp_apply)["accuracy"]
        rows.append({"name": f"ablation_transmit_{mode}_n0-34",
                     "us_per_call": round((time.time() - t0) * 1e6 / s.n_rounds, 1),
                     "derived": f"acc@{s.n_rounds}rounds={acc:.4f}"})
    return rows
