"""Kernel micro-benchmarks: interpret-mode wall time is meaningless for TPU
perf, so the derived column reports the ROOFLINE-relevant quantities (bytes
moved, fused-pass count vs naive) plus a CPU sanity timing of the jnp
reference path at the paper's scale (K=100 clients, d=8070 MLP)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref


def _time(f, *args, n=20):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        jax.block_until_ready(f(*args))
    t0 = time.time()
    for _ in range(n):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / n * 1e6


def run() -> list:
    rows = []
    rng = np.random.default_rng(0)
    k, d = 100, 8070
    x = jnp.asarray(rng.normal(size=(k, d)).astype(np.float32))
    bp = jnp.asarray(rng.random(k).astype(np.float32))
    noise = jnp.asarray(rng.normal(size=d).astype(np.float32))

    f = jax.jit(ref.aircomp_sum_ref)
    us = _time(f, x, bp, noise)
    bytes_moved = (k * d + 2 * d) * 4
    rows.append({"name": "aircomp_sum_ref_K100_d8070",
                 "us_per_call": round(us, 1),
                 "derived": f"bytes={bytes_moved};fused_passes=1_vs_4_naive"})

    g = jnp.asarray(rng.normal(size=d).astype(np.float32))
    f2 = jax.jit(ref.cosine_partials_ref)
    us = _time(f2, x, g)
    rows.append({"name": "cosine_partials_ref_K100_d8070",
                 "us_per_call": round(us, 1),
                 "derived": f"bytes={(k * d + d) * 4};one_pass=True"})

    # PR-5 delta-plane kernels: the round's two remaining K x d sweeps.
    # round_stats fuses dots + delta/payload sq-norms + ||g||^2 (replaces
    # the 3-pass client_dots/client_sq_norms x2 composition); superpose
    # fuses b*p masking + superposition + AWGN + varsigma normalization
    # (replaces the 4-pass scale/reduce/add/normalize composition).
    f4 = jax.jit(lambda x, g: ref.round_stats_ref(x, g, x))
    us = _time(f4, x, g)
    rows.append({"name": "round_stats_ref_K100_d8070",
                 "us_per_call": round(us, 1),
                 "derived": f"bytes={(2 * k * d + d) * 4};"
                            f"fused_passes=1_vs_3_naive"})
    mask = jnp.asarray((rng.random(k) < 0.5).astype(np.float32))
    f5 = jax.jit(lambda x, bp, m, n: ref.superpose_normalize_ref(x, bp, m, n))
    us = _time(f5, x, bp, mask, noise)
    rows.append({"name": "superpose_normalize_ref_K100_d8070",
                 "us_per_call": round(us, 1),
                 "derived": f"bytes={(k * d + 2 * d) * 4};"
                            f"fused_passes=1_vs_4_naive;emits_varsigma=True"})

    q = jnp.asarray(rng.normal(size=(4, 512, 64)).astype(np.float32))
    f3 = jax.jit(lambda q: ref.swa_attention_ref(q, q, q, window=128))
    us = _time(f3, q)
    full_flops = 2 * 2 * 4 * 512 * 512 * 64
    win_flops = 2 * 2 * 4 * 512 * (128 + 64) * 64
    rows.append({"name": "swa_ref_T512_w128",
                 "us_per_call": round(us, 1),
                 "derived": f"window_flops_saving={1 - win_flops / full_flops:.0%}"})
    return rows


if __name__ == "__main__":
    for row in run():
        print(f"{row['name']},{row['us_per_call']},{row['derived']}")
