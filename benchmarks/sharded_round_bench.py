"""Mesh-sharded PAOTA round vs the single-device fused scan.

Strong scaling: same K, 1 device (``FusedPAOTA``) vs the 8-virtual-device
CPU mesh (``ShardedPAOTA`` — per-client stages parallel, AirComp/P2 as
psums). Weak scaling: the sharded K on 8 devices against the fused K/8 on
one device (per-device client load held constant; 1.0x = perfect).

Per K in {1000, 10000} (smoke: K=16):

* ``sharded_round/fused_k{K}``        — fused seconds/round, 1 device.
* ``sharded_round/sharded_k{K}_dev8`` — sharded seconds/round, 8 devices.
* ``sharded_round/strong_k{K}``       — fused / sharded at equal K.
* ``sharded_round/weak_k{K}``         — fused@K/8 / sharded@K.

Virtual CPU devices share the same 2 physical cores, so these numbers
measure the collective/orchestration overhead of the sharded program, not
real speedup — the strong ratio is the lower bound a real 8-chip mesh
starts from (see EXPERIMENTS.md §Sharded PAOTA round).

Timing protocol: the headline ``sharded_k{K}`` / ``fused_k{K}`` rows are
AMORTIZED — R rounds advance as one chunked ``lax.scan`` dispatch (the
way any real training loop drives these servers), divided by R. At smoke
scale (K=16) the per-dispatch shard_map overhead on 8 virtual devices is
~100x the per-round math, so a tiny R made the old artifact read as a
600 ms/round "regression" that was really ~24 ms of round work plus
dispatch; the smoke now scans R=24 and ALSO reports the single-round
dispatch cost as an explicit ``..._dispatch`` row so both numbers stay
tracked instead of blended.

Host-device forcing must happen before jax initializes, so ``run()``
re-execs this module in a subprocess with ``XLA_FLAGS=--xla_force_host_
platform_device_count=8`` and parses the rows back — callable from
``benchmarks.run`` no matter what the parent process already imported.

``python -m benchmarks.sharded_round_bench smoke`` runs the K=16 pairing
(the CI guard that keeps the shard_map path compiling) and writes the
``BENCH_sharded_round_smoke.json`` artifact.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import time

FORCE_FLAG = "--xla_force_host_platform_device_count=8"
_SETTINGS = {  # K -> (size ladder, batch, local steps, scan rounds)
    16: ((48, 64), 32, 5, 24),       # smoke: R large enough to amortize
    125: ((48, 64), 32, 5, 10),      # weak-scaling reference for K=1000
    1000: ((48, 64), 32, 5, 10),
    1250: ((16, 24), 16, 2, 3),      # weak-scaling reference for K=10000
    10000: ((16, 24), 16, 2, 3),
}


def _make_engine(k: int, seed: int = 0):
    from repro.data.partition import partition_noniid
    from repro.data.pipeline import build_federation
    from repro.data.synthetic import make_mnist_like
    from repro.fl import BatchedEngine
    from repro.models.mlp import mlp_loss
    sizes, batch, steps, _ = _SETTINGS[k]
    x, y, _, _ = make_mnist_like(n_train=min(max(20 * k, 2000), 20000),
                                 n_test=10, seed=1234)
    parts = partition_noniid(y, n_clients=k, sizes=sizes, seed=seed)
    fed = build_federation(x, y, parts, seed=seed)
    return BatchedEngine(fed, mlp_loss, batch_size=batch, lr=0.1,
                         local_steps=steps)


def _time_server(cls, k: int, seed: int = 0, measure_dispatch: bool = False,
                 **kw):
    """(amortized seconds/round, setup seconds, per-dispatch seconds or
    None). Amortized = one chunked R-round ``advance`` scan / R (the way a
    training loop drives the server); per-dispatch = a single-round
    ``advance(1)`` call, which at smoke scale is dominated by shard_map
    dispatch, not round math. Setup = construction + first advance
    (compile + init federation train)."""
    import jax
    import numpy as np
    from repro.core import ChannelConfig, SchedulerConfig
    from repro.fl import PAOTAConfig
    from repro.models.mlp import init_mlp_params
    rounds = _SETTINGS[k][3]
    params = init_mlp_params(jax.random.PRNGKey(seed))
    t0 = time.perf_counter()
    srv = cls(params, _make_engine(k, seed), ChannelConfig(),
              SchedulerConfig(n_clients=k, seed=seed),
              PAOTAConfig(seed=seed), **kw)
    srv.advance(rounds)
    setup = time.perf_counter() - t0
    t0 = time.perf_counter()
    srv.advance(rounds)
    sec = (time.perf_counter() - t0) / rounds
    dispatch = None
    if measure_dispatch:
        srv.advance(1)                    # compile the length-1 scan
        t0 = time.perf_counter()
        for _ in range(3):
            srv.advance(1)
        dispatch = (time.perf_counter() - t0) / 3
    assert np.isfinite(srv.global_vec).all()
    return sec, setup, dispatch


def _measure(ks, dispatch_rows: bool = False) -> list:
    """Runs INSIDE the forced-device subprocess. ``dispatch_rows`` (the
    smoke) also emits per-dispatch single-round rows next to the
    amortized chunked-scan headline."""
    import jax
    from repro.fl import FusedPAOTA, ShardedPAOTA
    from repro.launch.mesh import make_client_mesh
    n_dev = len(jax.devices())
    mesh = make_client_mesh(min(n_dev, 8))
    rows = []
    for k in ks:
        rounds = _SETTINGS[k][3]
        fused_s, fused_setup, fused_disp = _time_server(
            FusedPAOTA, k, measure_dispatch=dispatch_rows)
        rows.append({"name": f"sharded_round/fused_k{k}",
                     "us_per_call": round(fused_s * 1e6, 1),
                     "derived": f"rounds_per_sec={1.0 / fused_s:.3f};"
                                f"scan_rounds={rounds};"
                                f"setup_s={fused_setup:.2f}"})
        shard_s, shard_setup, shard_disp = _time_server(
            ShardedPAOTA, k, mesh=mesh, measure_dispatch=dispatch_rows)
        rows.append({"name": f"sharded_round/sharded_k{k}_dev{mesh.size}",
                     "us_per_call": round(shard_s * 1e6, 1),
                     "derived": f"rounds_per_sec={1.0 / shard_s:.3f};"
                                f"scan_rounds={rounds};"
                                f"setup_s={shard_setup:.2f}"})
        if dispatch_rows:
            rows.append({"name": f"sharded_round/fused_k{k}_dispatch",
                         "us_per_call": round(fused_disp * 1e6, 1),
                         "derived": "single_round_advance=1_dispatch"})
            rows.append(
                {"name": f"sharded_round/sharded_k{k}_dev{mesh.size}"
                         f"_dispatch",
                 "us_per_call": round(shard_disp * 1e6, 1),
                 "derived": f"single_round_advance=1_dispatch;"
                            f"overhead_vs_amortized="
                            f"{shard_disp / shard_s:.1f}x"})
        rows.append({"name": f"sharded_round/strong_k{k}",
                     "us_per_call": 0,
                     "derived": f"{fused_s / shard_s:.2f}x"})
        k_weak = k // mesh.size
        if k_weak in _SETTINGS:
            weak_s, _, _ = _time_server(FusedPAOTA, k_weak)
            rows.append({"name": f"sharded_round/weak_k{k}",
                         "us_per_call": 0,
                         "derived": f"{weak_s / shard_s:.2f}x_of_perfect;"
                                    f"fused_k{k_weak}_s={weak_s:.4f}"})
    return rows


def run(ks=(1000, 10000), dispatch_rows: bool = False) -> list:
    """benchmarks.run entry: re-exec with forced host devices (jax may
    already be initialized single-device in the caller)."""
    env = dict(os.environ)
    if "xla_force_host_platform_device_count" not in env.get("XLA_FLAGS", ""):
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " " + FORCE_FLAG).strip()
    with tempfile.NamedTemporaryFile("r", suffix=".json") as f:
        cmd = [sys.executable, "-m", "benchmarks.sharded_round_bench",
               "--emit", f.name] + (["--dispatch"] if dispatch_rows else []) \
            + [str(k) for k in ks]
        subprocess.run(cmd, env=env, check=True,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
        return json.load(open(f.name))


def main():
    args = sys.argv[1:]
    if "--emit" in args:                     # forced-device child
        dispatch_rows = "--dispatch" in args
        args = [a for a in args if a != "--dispatch"]
        i = args.index("--emit")
        out_path, ks = args[i + 1], tuple(int(k) for k in args[i + 2:])
        rows = _measure(ks, dispatch_rows=dispatch_rows)
        with open(out_path, "w") as f:
            json.dump(rows, f)
        return
    smoke = "smoke" in args
    ks = (16,) if smoke else (1000, 10000)
    rows = run(ks=ks, dispatch_rows=smoke)
    print("name,us_per_call,derived")
    for row in rows:
        print(f"{row['name']},{row['us_per_call']},{row['derived']}",
              flush=True)
    from benchmarks.common import write_bench_artifact
    name = "sharded_round_smoke" if smoke else "sharded_round"
    # device_count in the artifact header reflects THIS (parent) process;
    # the measurements ran in the forced-device child — record that too
    path = write_bench_artifact(name, rows,
                                extra={"ks": list(ks), "forced_devices": 8})
    print(f"# artifact -> {path}", flush=True)


if __name__ == "__main__":
    main()
