"""Fig. 4 reproduction: test accuracy vs rounds AND vs simulated wall time
(N0 = -174 dBm/Hz). The wall-time view is the paper's headline: PAOTA's
fixed delta_t rounds beat the sync baselines' straggler-bound rounds."""
from __future__ import annotations

import os
import time

from benchmarks.common import BenchSetting, OUT_DIR, build_world, run_algorithm
from repro.fl import write_csv


def run() -> list:
    s = BenchSetting.from_env()
    clients, params, data = build_world(s)
    rows_out, traj = [], []
    for algo in ("paota", "local_sgd", "cotaf"):
        t0 = time.time()
        rows = run_algorithm(algo, s, clients, params, data)
        traj.extend(rows)
        final = rows[-1]
        # accuracy at a fixed simulated-time budget (min of finals)
        rows_out.append({
            "name": f"fig4_{algo}",
            "us_per_call": round((time.time() - t0) * 1e6 / s.n_rounds, 1),
            "derived": f"final_acc={final['accuracy']}"
                       f";sim_time_s={final['time']}",
        })
    write_csv(os.path.join(OUT_DIR, "fig4_trajectories.csv"), traj)
    return rows_out


if __name__ == "__main__":
    for row in run():
        print(f"{row['name']},{row['us_per_call']},{row['derived']}")
