"""Bench differ: compare the current run's BENCH_<name>.json artifacts
against the previous PR's artifacts and fail CI on wall-clock regressions.

    python -m benchmarks.diff --baseline <dir> [--current experiments/bench]
                              [--ratio 2.0] [--min-us 1000] [names ...]

For every artifact present in BOTH directories, rows are matched by their
``name`` field and the ``us_per_call`` wall-clock compared. A row whose
current time exceeds ``ratio`` x its baseline (default 2.0 — the CI
regression bar) is a regression; the process exits nonzero if any row
regressed. Rows faster than ``--min-us`` in the baseline (default 1 ms)
are reported but never fail the run — micro-rows on shared CI cores are
dominated by scheduler noise, not code. Rows (or whole artifacts) with no
baseline entry are flagged ``new (no baseline)`` and never fail — a newly
introduced series must survive its first CI run; it becomes gated once
its artifact is committed. An artifact whose baseline was
recorded on a different backend or device count is likewise report-only:
absolute wall clocks only gate on a like-for-like environment (for
machine-speed drift, raise the bar with ``REPRO_BENCH_DIFF_RATIO``).

``scripts/ci.sh`` snapshots the committed artifacts before the benchmark
smokes regenerate them, then diffs current vs snapshot — so a perf
regression in the fused/sharded round is a red CI, not a line scrolling
away in a log. Positional ``names`` restrict the comparison to specific
artifacts (e.g. ``fused_round_smoke``).

Exit codes: 0 ok / nothing comparable, 1 regression found, 2 bad usage.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import Dict, List, Optional


def load_artifacts(dirname: str, names: Optional[List[str]] = None) -> Dict:
    """{artifact name: {"rows": {row name: us_per_call}, "env": (backend,
    device_count) or None}} for every BENCH_*.json."""
    out = {}
    for path in sorted(glob.glob(os.path.join(dirname, "BENCH_*.json"))):
        base = os.path.basename(path)[len("BENCH_"):-len(".json")]
        if names and base not in names:
            continue
        try:
            with open(path) as f:
                art = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"# skipping unreadable {path}: {e}", file=sys.stderr)
            continue
        rows = {}
        for row in art.get("rows", []):
            if "name" in row and "us_per_call" in row:
                try:
                    rows[str(row["name"])] = float(row["us_per_call"])
                except (TypeError, ValueError):
                    continue
        env = None
        if "backend" in art and "device_count" in art:
            env = (art["backend"], art["device_count"])
        if rows:
            out[base] = {"rows": rows, "env": env}
    return out


def diff_artifacts(baseline: Dict, current: Dict, ratio: float,
                   min_us: float):
    """Returns (report rows, regressions). A report row is
    (artifact, row, base_us, cur_us, factor, flag). An artifact whose
    baseline was recorded on a different backend or device count is
    reported but never failed — absolute wall clocks are only comparable
    on a like-for-like environment."""
    report, regressions = [], []
    for art, cur in sorted(current.items()):
        base = baseline.get(art)
        if not base or not base["rows"]:
            # a whole artifact with no baseline: a newly-introduced series
            # — report it so the introduction is visible, never fail it
            for name, cur_us in sorted(cur["rows"].items()):
                report.append((art, name, 0.0, cur_us, 0.0,
                               "new (no baseline)"))
            continue
        env_mismatch = (base["env"] is not None and cur["env"] is not None
                        and base["env"] != cur["env"])
        base_rows = base["rows"]
        for name, cur_us in cur["rows"].items():
            base_us = base_rows.get(name)
            if base_us is None:
                # newly-added row inside an existing artifact: first
                # introduction must not fail the differ
                report.append((art, name, 0.0, cur_us, 0.0,
                               "new (no baseline)"))
                continue
            if base_us <= 0:
                # pre-existing sentinel/ratio row (us_per_call 0) — not
                # new, not comparable: skip silently as always
                continue
            factor = cur_us / base_us
            flag = ""
            if factor > ratio:
                if env_mismatch:
                    flag = "env mismatch (backend/devices differ)"
                elif base_us >= min_us:
                    flag = "REGRESSION"
                    regressions.append((art, name, base_us, cur_us, factor))
                else:
                    flag = "noise (baseline < min-us)"
            report.append((art, name, base_us, cur_us, factor, flag))
    return report, regressions


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fail on >ratio x wall-clock regressions vs the "
                    "previous PR's BENCH_*.json artifacts")
    ap.add_argument("--baseline", required=True,
                    help="directory with the previous artifacts")
    ap.add_argument("--current", default=os.environ.get(
        "REPRO_BENCH_OUT", "experiments/bench"))
    ap.add_argument("--ratio", type=float,
                    default=float(os.environ.get("REPRO_BENCH_DIFF_RATIO",
                                                 "2.0")))
    ap.add_argument("--min-us", type=float, default=1000.0,
                    help="baseline rows faster than this never fail "
                         "(micro-row noise floor)")
    ap.add_argument("names", nargs="*",
                    help="restrict to these artifact names")
    args = ap.parse_args(argv)
    if not os.path.isdir(args.baseline):
        print(f"baseline dir {args.baseline!r} does not exist",
              file=sys.stderr)
        return 2

    baseline = load_artifacts(args.baseline, args.names or None)
    current = load_artifacts(args.current, args.names or None)
    report, regressions = diff_artifacts(baseline, current, args.ratio,
                                         args.min_us)
    if not report:
        print("# bench diff: no comparable artifact rows "
              f"(baseline {len(baseline)}, current {len(current)})")
        return 0
    print(f"# bench diff vs {args.baseline} (fail ratio {args.ratio}x, "
          f"noise floor {args.min_us}us)")
    print("artifact,row,baseline_us,current_us,factor,flag")
    for art, name, b, c, f, flag in report:
        print(f"{art},{name},{b:.1f},{c:.1f},{f:.2f},{flag}")
    if regressions:
        print(f"# {len(regressions)} regression(s) > {args.ratio}x:",
              file=sys.stderr)
        for art, name, b, c, f in regressions:
            print(f"#   {art}:{name} {b:.0f}us -> {c:.0f}us ({f:.2f}x)",
                  file=sys.stderr)
        return 1
    print("# bench diff ok")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
