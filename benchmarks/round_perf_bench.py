"""Canonical tracked round-perf series: the PAOTA delta plane.

This is the cross-PR perf trajectory for the aggregation period itself —
the (K, d) data-plane arithmetic (eq.-25 stats, water-filled powers,
AirComp superposition, carry update) that the round-stats / superpose
kernels target. The model is sized so that plane dominates: an MLP with
``REPRO_BENCH_HIDDEN`` (default 64) hidden units gives d ~= 55k, and local
training is held to ONE local SGD step on batch 1, so per-round cost is
memory traffic over the stacked (K, d) carry, not SGD compute.

Per K in {16, 1000} (smoke: K=16 only):

* ``round_perf/host_raveled_k{K}``    — host reference seconds/round
  (``PAOTAServer``, counter RNG + waterfill_jnp: the same math as the
  on-device drivers, host-Python staging).
* ``round_perf/fused_raveled_k{K}``   — ``FusedPAOTA`` seconds/round,
  steady-state, amortized over one R-round ``lax.scan`` device call
  (paper-default transmit='model': clients superpose full local models).
* ``round_perf/fused_pytree_k{K}``    — same, params carried as a pytree.
* ``round_perf/fused_{raveled,pytree}_delta_k{K}`` — transmit='delta':
  the carry IS the delta plane (no pending stack), the purest view of
  the one-pass delta-plane arithmetic this series tracks.
* ``round_perf/sharded_raveled_k{K}`` / ``round_perf/sharded_pytree_k{K}``
  — ``ShardedPAOTA`` over the forced 8-virtual-device CPU mesh
  (subprocess, same pattern as benchmarks/sharded_round_bench; virtual
  devices share the physical cores, so these track orchestration cost).

``python -m benchmarks.round_perf_bench smoke`` runs the K=16 subset and
writes ``BENCH_round_perf_smoke.json`` (the CI fast-tier guard wired into
scripts/ci.sh with the >2x diff gate); the full run writes
``BENCH_round_perf.json`` — committed under experiments/bench/ as the
tracked baseline the next PR diffs against.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import time

FORCE_FLAG = "--xla_force_host_platform_device_count=8"
_ROUNDS = {16: 20, 1000: 5}          # scan length R per federation size
_BATCH, _STEPS, _SIZES = 1, 1, (16, 24)


def _hidden() -> int:
    return int(os.environ.get("REPRO_BENCH_HIDDEN", "64"))


def _make_engine(k: int, seed: int = 0):
    from repro.data.partition import partition_noniid
    from repro.data.pipeline import build_federation
    from repro.data.synthetic import make_mnist_like
    from repro.fl import BatchedEngine
    from repro.models.mlp import mlp_loss
    x, y, _, _ = make_mnist_like(n_train=min(max(20 * k, 2000), 20000),
                                 n_test=10, seed=1234)
    parts = partition_noniid(y, n_clients=k, sizes=_SIZES, seed=seed)
    fed = build_federation(x, y, parts, seed=seed)
    return BatchedEngine(fed, mlp_loss, batch_size=_BATCH, lr=0.1,
                         local_steps=_STEPS)


def _params(seed: int = 0):
    import jax
    from repro.models.mlp import init_mlp_params
    return init_mlp_params(jax.random.PRNGKey(seed), hidden=_hidden())


def _row(name: str, sec: float, setup: float, rounds: int) -> dict:
    return {"name": name, "us_per_call": round(sec * 1e6, 1),
            "derived": f"rounds_per_sec={1.0 / sec:.3f};"
                       f"scan_rounds={rounds};setup_s={setup:.2f}"}


def _time_host(k: int, seed: int = 0):
    from repro.core import ChannelConfig, SchedulerConfig
    from repro.fl import PAOTAConfig, PAOTAServer
    rounds = _ROUNDS[k]
    t0 = time.perf_counter()
    srv = PAOTAServer(_params(seed), _make_engine(k, seed), ChannelConfig(),
                      SchedulerConfig(n_clients=k, seed=seed, rng="counter"),
                      PAOTAConfig(rng="counter", solver="waterfill_jnp",
                                  seed=seed))
    srv.round()
    setup = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(rounds):
        srv.round()
    return _row(f"round_perf/host_raveled_k{k}",
                (time.perf_counter() - t0) / rounds, setup, rounds)


def _time_driver(cls, k: int, params_mode: str, seed: int = 0,
                 transmit: str = "model", **kw):
    import numpy as np
    from repro.core import ChannelConfig, SchedulerConfig
    from repro.fl import PAOTAConfig
    rounds = _ROUNDS[k]
    t0 = time.perf_counter()
    srv = cls(_params(seed), _make_engine(k, seed), ChannelConfig(),
              SchedulerConfig(n_clients=k, seed=seed),
              PAOTAConfig(seed=seed, transmit=transmit),
              params_mode=params_mode, **kw)
    srv.advance(rounds)                 # compile + init
    setup = time.perf_counter() - t0
    t0 = time.perf_counter()
    srv.advance(rounds)                 # steady state: one scan device call
    sec = (time.perf_counter() - t0) / rounds
    assert np.isfinite(srv.global_vec).all()
    return sec, setup, rounds


def _measure_local(ks) -> list:
    """Host + fused rows on the ambient (single-device) backend."""
    from repro.fl import FusedPAOTA
    rows = []
    for k in ks:
        rows.append(_time_host(k))
        for mode in ("raveled", "pytree"):
            sec, setup, rounds = _time_driver(FusedPAOTA, k, mode)
            rows.append(_row(f"round_perf/fused_{mode}_k{k}", sec, setup,
                             rounds))
            sec, setup, rounds = _time_driver(FusedPAOTA, k, mode,
                                              transmit="delta")
            rows.append(_row(f"round_perf/fused_{mode}_delta_k{k}", sec,
                             setup, rounds))
    return rows


def _measure_sharded(ks) -> list:
    """Sharded rows — runs INSIDE the forced-device subprocess."""
    import jax
    from repro.fl import ShardedPAOTA
    from repro.launch.mesh import make_client_mesh
    mesh = make_client_mesh(min(len(jax.devices()), 8))
    rows = []
    for k in ks:
        for mode in ("raveled", "pytree"):
            sec, setup, rounds = _time_driver(ShardedPAOTA, k, mode,
                                              mesh=mesh)
            rows.append(_row(f"round_perf/sharded_{mode}_k{k}", sec, setup,
                             rounds))
    return rows


def run(ks=(16, 1000)) -> list:
    rows = _measure_local(ks)
    env = dict(os.environ)
    if "xla_force_host_platform_device_count" not in env.get("XLA_FLAGS", ""):
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " " + FORCE_FLAG).strip()
    with tempfile.NamedTemporaryFile("r", suffix=".json") as f:
        cmd = [sys.executable, "-m", "benchmarks.round_perf_bench",
               "--emit", f.name] + [str(k) for k in ks]
        subprocess.run(cmd, env=env, check=True,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
        rows += json.load(open(f.name))
    return rows


def main():
    args = sys.argv[1:]
    if "--emit" in args:                     # forced-device child
        i = args.index("--emit")
        out_path, ks = args[i + 1], tuple(int(k) for k in args[i + 2:])
        rows = _measure_sharded(ks)
        with open(out_path, "w") as f:
            json.dump(rows, f)
        return
    smoke = "smoke" in args
    ks = (16,) if smoke else (16, 1000)
    rows = run(ks=ks)
    print("name,us_per_call,derived")
    for row in rows:
        print(f"{row['name']},{row['us_per_call']},{row['derived']}",
              flush=True)
    from benchmarks.common import write_bench_artifact
    name = "round_perf_smoke" if smoke else "round_perf"
    path = write_bench_artifact(name, rows,
                                extra={"ks": list(ks), "hidden": _hidden(),
                                       "batch": _BATCH, "local_steps": _STEPS,
                                       "forced_devices_sharded": 8})
    print(f"# artifact -> {path}", flush=True)


if __name__ == "__main__":
    main()
