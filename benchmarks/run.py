"""Benchmark harness entry point — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  bound    — Theorem-1 contraction + P2 gap terms (convergence machinery)
  kernels  — aggregation/cosine/SWA kernel characteristics
  roofline — per (arch x shape x mesh) roofline terms from the dry-run
  fl_engine — legacy vs batched federation engine rounds/sec (K up to 1000)
  fused_round — host-loop vs fused lax.scan PAOTA rounds/sec (K up to 1000)
  round_perf — the canonical tracked delta-plane series: host/fused/sharded
             seconds/round at K in {16, 1000}, raveled + pytree, model +
             delta transmit (d ~= 55k MLP, 1 local step — data-plane bound)
  sharded_round — fused 1-device vs shard_map'd 8-device PAOTA rounds/sec
             (K up to 10000; runs in a subprocess with forced host devices)
  grouped_round — multi-pod grouped aggregation: K=10000 on the forced
             512-device (2, 256) pod mesh, dryrun lower+compile + the
             one-cross-pod-psum-per-window compiled-HLO collective check
  cohort_round — active-cohort (m, d) payload plane vs dense carry:
             driver + synthetic-stream rounds/sec and carry bytes at
             K in {1e3, 1e5, 1e6} (1e6 = state-plane-only acceptance run)
  tp_round — intra-client TP on the ("pod","data","tp") mesh: the
             minicpm-2b-reduced pytree federation at tp in {1, 2, 4},
             per-device carry bytes ~1/tp with ONE cross-client
             model-sized psum (compiled-HLO checked)
  fig3     — train-loss robustness vs noise (paper Fig. 3)
  fig4     — test accuracy vs rounds/time (paper Fig. 4)
  table1   — time/rounds to target accuracy (paper Table I)

Each completed module ALSO writes a machine-readable artifact —
``experiments/bench/BENCH_<module>.json`` with the rows plus backend/env
config — so perf is tracked across PRs (scripts/ci.sh smoke-checks one).

Env: REPRO_BENCH_FULL=1 for paper-scale (100 clients); default is a
CPU-friendly scaled setting with identical structure.
Select subsets: ``python -m benchmarks.run fig3 table1``
"""
from __future__ import annotations

import sys
import traceback

MODULES = ["bound", "kernels_bench", "roofline_bench", "fl_engine_bench",
           "fused_round_bench", "round_perf_bench", "sharded_round_bench",
           "grouped_round_bench", "cohort_round_bench", "tp_round_bench",
           "fig3", "fig4", "table1", "ablation"]
ALIASES = {"kernels": "kernels_bench", "roofline": "roofline_bench",
           "fl_engine": "fl_engine_bench", "engine": "fl_engine_bench",
           "fused_round": "fused_round_bench", "fused": "fused_round_bench",
           "round_perf": "round_perf_bench",
           "sharded_round": "sharded_round_bench",
           "sharded": "sharded_round_bench",
           "grouped_round": "grouped_round_bench",
           "grouped": "grouped_round_bench",
           "cohort_round": "cohort_round_bench",
           "cohort": "cohort_round_bench",
           "tp_round": "tp_round_bench",
           "tp": "tp_round_bench"}


def main() -> None:
    wanted = sys.argv[1:] or MODULES
    wanted = [ALIASES.get(w, w) for w in wanted]
    print("name,us_per_call,derived")
    failed = []
    for mod_name in wanted:
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
            rows = list(mod.run())
            for row in rows:
                print(f"{row['name']},{row['us_per_call']},{row['derived']}",
                      flush=True)
            from benchmarks.common import write_bench_artifact
            # BENCH_<name> matches what direct `python -m benchmarks.X`
            # invocation writes (the `_bench` module suffix is dropped)
            art = mod_name[:-6] if mod_name.endswith("_bench") else mod_name
            path = write_bench_artifact(art, rows)
            print(f"# artifact -> {path}", flush=True)
        except Exception:
            traceback.print_exc()
            failed.append(mod_name)
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
