"""Shared harness for the paper-reproduction benchmarks (Fig. 3/4, Table I).

Builds the federation once (synthetic MNIST-like, non-IID partition per
Section IV-A) and runs PAOTA / Local SGD / COTAF servers, recording
(round, simulated time, train loss, test accuracy) trajectories.
"""
from __future__ import annotations

import json
import os
import platform
import time
from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.core import BoundConstants, ChannelConfig, SchedulerConfig, contraction_A
from repro.data.partition import partition_noniid
from repro.data.pipeline import build_federation
from repro.data.synthetic import get_dataset
from repro.fl import (COTAFServer, FLClient, FusedPAOTA, LocalSGDServer,
                      PAOTAConfig, PAOTAServer, SyncConfig, evaluate)
from repro.models.mlp import init_mlp_params, mlp_apply, mlp_loss

OUT_DIR = os.environ.get("REPRO_BENCH_OUT", "experiments/bench")


def write_bench_artifact(name: str, rows: List[Dict],
                         extra: Optional[Dict] = None) -> str:
    """Persist one benchmark's rows as a machine-readable JSON artifact —
    ``<OUT_DIR>/BENCH_<name>.json`` — so the perf trajectory is tracked
    across PRs instead of scrolling away in CI logs.

    The payload carries the timing rows verbatim plus enough config to
    make numbers comparable run-to-run (backend, device count, the
    REPRO_BENCH_* env knobs). ``scripts/ci.sh`` smoke-checks one of these
    parses after the benchmark smokes. Returns the artifact path."""
    import jax
    os.makedirs(OUT_DIR, exist_ok=True)
    payload = {
        "name": name,
        "created_unix": time.time(),
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "python": platform.python_version(),
        "jax": jax.__version__,
        "env": {k: v for k, v in os.environ.items()
                if k.startswith("REPRO_BENCH")},
        "rows": rows,
    }
    if extra:
        payload["config"] = extra
    path = os.path.join(OUT_DIR, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    return path


@dataclass
class BenchSetting:
    n_clients: int = 40          # paper: 100 (scaled for CPU wall-time;
    n_rounds: int = 60           # REPRO_BENCH_FULL=1 restores 100)
    n_select: int = 20           # sync baselines' participants per round
    lr: float = 0.1
    local_steps: int = 5         # M
    batch_size: int = 32
    delta_t: float = 8.0
    n0_dbm_hz: float = -174.0
    eval_every: int = 2
    seed: int = 0
    solver: str = "waterfill"
    engine: str = "batched"      # batched|legacy local-training engine, or
                                 # "fused": PAOTA runs as the on-device
                                 # lax.scan round (counter RNG), or
                                 # "sharded": the same scan under shard_map
                                 # over the mesh client axis (needs a
                                 # multi-device backend; non-divisible K
                                 # pads with masked phantom clients);
                                 # baselines fall back to the batched engine
    params_mode: str = "raveled" # fused/sharded model carry: "raveled"
                                 # (flat (K, d) stack) | "pytree" (params
                                 # tree carried natively by the round core)
    pending_dtype: str = "float32"  # fused/sharded carry storage for the
                                 # (K, ...) planes: "bfloat16" halves the
                                 # working set (f32 accumulation; globals
                                 # stay f32)
    group_period: int = 0        # sharded only: grouped aggregation window
                                 # N (0 = flat; N >= 1 = intra-pod psums
                                 # every period, ONE cross-pod psum per N
                                 # periods; the trajectory advances in
                                 # whole windows)
    cohort_size: int = 0         # fused/sharded: active-cohort mode — only
                                 # m in-flight slots carry model-sized rows
                                 # (0 = dense (K, ...) planes)
    compress: str = ""           # fused/sharded + cohort: "topk"|"randmask"
                                 # sparsifies the slot payloads to (m, s),
                                 # s = round(d * compress_ratio); forces
                                 # transmit="delta" (compression targets
                                 # the small update, not the model)
    compress_ratio: float = 1.0
    error_feedback: bool = True  # compress only: per-client residual
                                 # planes re-inject what sparsification
                                 # dropped (off = plain sparsification)
    tp: int = 1                  # sharded + pytree only: intra-client
                                 # tensor-parallel extent — the mesh gains
                                 # a "tp" axis and every client replica's
                                 # stacked payload leaves TP-shard over
                                 # it (per-device carry ~1/tp; one
                                 # clients x tp psum per round)
    faults: str = ""             # fused/sharded: fault-injection spec,
                                 # comma-separated kind:value pairs parsed
                                 # by parse_faults() — e.g.
                                 # "nan:0.05,start:1" or
                                 # "byz:0.1,scale:-50,fade:0.02"
    screen: bool = False         # fused/sharded: mask non-finite uploads
                                 # out of the superposition (containment)
    screen_max_norm: float = 0.0 # screening norm fence (0 = finite-only)
    divergence_factor: float = 0.0  # post-update rollback detector
                                 # (0 = off)
    checkpoint_every: int = 0    # fused/sharded: snapshot the full round
                                 # carry every N rounds into
                                 # checkpoint_dir (0 = off)
    checkpoint_dir: str = ""
    resume: str = ""             # fused/sharded: checkpoint path to
                                 # restore before training — the resumed
                                 # run continues the killed one bit-exactly

    @classmethod
    def from_env(cls, **kw):
        s = cls(**kw)
        if os.environ.get("REPRO_BENCH_FULL") == "1":
            s.n_clients, s.n_rounds, s.n_select = 100, 120, 50
        return s


# fault-spec keys -> FaultConfig fields ("inf" flips nan_mode, not a field)
_FAULT_KEYS = {"nan": ("nan_frac", float), "inf": ("nan_frac", float),
               "byz": ("byzantine_frac", float),
               "scale": ("byzantine_scale", float),
               "fade": ("deep_fade_frac", float),
               "gain": ("deep_fade_gain", float),
               "start": ("start", int), "stop": ("stop", int),
               "pods": ("pod_blackout", None),
               "bstart": ("blackout_start", int),
               "bstop": ("blackout_stop", int)}


def parse_faults(spec: str):
    """CLI fault spec -> ``FaultConfig``: comma-separated ``kind:value``
    pairs — ``nan:0.05`` (NaN payload fraction; ``inf:`` for +Inf rows),
    ``byz:0.1`` / ``scale:-50`` (Byzantine fraction / delta scale),
    ``fade:0.02`` / ``gain:1e-4`` (deep-fade fraction / gain),
    ``start:`` / ``stop:`` (active round window), ``pods:0|2`` /
    ``bstart:`` / ``bstop:`` (pod-blackout indices and window, grouped
    sharded mode). Empty/None spec -> None (no FaultConfig at all)."""
    from repro.core.scheduler import FaultConfig
    if not spec:
        return None
    kw = {}
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        kind, _, val = part.partition(":")
        if kind not in _FAULT_KEYS:
            raise ValueError(f"unknown fault kind {kind!r} in {spec!r} "
                             f"(expected one of {sorted(_FAULT_KEYS)})")
        field, cast = _FAULT_KEYS[kind]
        if kind == "pods":
            kw[field] = tuple(int(p) for p in val.split("|") if p)
        else:
            kw[field] = cast(val)
        if kind == "inf":
            kw["nan_mode"] = "inf"
    return FaultConfig(**kw)


def build_world(s: BenchSetting):
    x_tr, y_tr, x_te, y_te = get_dataset(n_train=max(200 * s.n_clients, 4000),
                                         n_test=2000)
    parts = partition_noniid(y_tr, n_clients=s.n_clients, seed=s.seed)
    fed = build_federation(x_tr, y_tr, parts, seed=s.seed)
    clients = [FLClient(d, mlp_loss, batch_size=s.batch_size, lr=s.lr,
                        local_steps=s.local_steps) for d in fed]
    params = init_mlp_params(jax.random.PRNGKey(s.seed))
    return clients, params, (x_tr, y_tr, x_te, y_te)


def train_loss(params, x, y, n: int = 4096) -> float:
    import jax.numpy as jnp
    sel = np.random.default_rng(0).choice(len(y), size=min(n, len(y)),
                                          replace=False)
    return float(mlp_loss(params, {"x": jnp.asarray(x[sel]),
                                   "y": jnp.asarray(y[sel])}))


def run_algorithm(name: str, s: BenchSetting, clients, params, data,
                  seed_offset: int = 0) -> List[Dict]:
    x_tr, y_tr, x_te, y_te = data
    chan = ChannelConfig(n0_dbm_hz=s.n0_dbm_hz)
    sched = SchedulerConfig(n_clients=s.n_clients, delta_t=s.delta_t,
                            seed=s.seed + seed_offset)
    # "fused"/"sharded" are PAOTA-only modes; the sync baselines use the
    # batched engine under them so the comparison stays apples-to-apples
    engine = "batched" if s.engine in ("fused", "sharded") else s.engine
    fault_tol = (s.faults or s.screen or s.divergence_factor
                 or s.checkpoint_every or s.resume)
    if fault_tol and not (name == "paota"
                          and s.engine in ("fused", "sharded")):
        if name != "paota":
            return []       # fault-tolerance sweeps are PAOTA-only
        raise ValueError(
            "faults/screen/divergence/checkpoint knobs live on the "
            "fused/sharded drivers; pass engine='fused' or 'sharded'")
    if name == "paota":
        if s.engine in ("fused", "sharded"):
            # solver is passed through: the on-device drivers raise on
            # solvers they cannot run rather than silently substituting
            from repro.fl import ShardedPAOTA
            cls = ShardedPAOTA if s.engine == "sharded" else FusedPAOTA
            kw = {}
            if s.engine == "sharded" and s.group_period:
                kw["group_period"] = s.group_period
            if s.engine == "sharded" and s.tp > 1:
                # ("pod","data","tp") mesh: the tp extent comes off the
                # client axis (the server refuses raveled mode itself)
                import jax
                from repro.launch.mesh import make_pod_mesh
                kw["mesh"] = make_pod_mesh(
                    pods=1, data=max(len(jax.devices()) // s.tp, 1),
                    tp=s.tp)
            if s.cohort_size:
                kw["cohort_size"] = s.cohort_size
            transmit = "model"
            if s.compress:
                # compressed slots ride the delta transmit mode (the
                # drivers refuse otherwise)
                transmit = "delta"
                kw.update(compress=s.compress,
                          compress_ratio=s.compress_ratio,
                          error_feedback=s.error_feedback)
            if s.faults:
                kw["faults"] = parse_faults(s.faults)
            if s.screen:
                kw.update(screen=True, screen_max_norm=s.screen_max_norm)
            if s.divergence_factor:
                kw["divergence_factor"] = s.divergence_factor
            if s.checkpoint_every:
                kw.update(checkpoint_every=s.checkpoint_every,
                          checkpoint_dir=s.checkpoint_dir
                          or os.path.join(OUT_DIR, "checkpoints"))
            srv = cls(params, clients, chan, sched,
                      PAOTAConfig(solver=s.solver, seed=s.seed,
                                  transmit=transmit),
                      params_mode=s.params_mode,
                      pending_dtype=s.pending_dtype, **kw)
            if s.resume:
                done = srv.restore_checkpoint(s.resume)
                print(f"resumed {name} from {s.resume} (round {done})")
        else:
            srv = PAOTAServer(params, clients, chan, sched,
                              PAOTAConfig(solver=s.solver, seed=s.seed,
                                          engine=engine))
    elif name == "local_sgd":
        srv = LocalSGDServer(params, clients, sched,
                             SyncConfig(n_select=s.n_select, seed=s.seed,
                                        engine=engine))
    elif name == "cotaf":
        srv = COTAFServer(params, clients, sched,
                          SyncConfig(n_select=s.n_select, seed=s.seed,
                                     engine=engine), chan)
    else:
        raise ValueError(name)

    rows = []
    t0 = time.time()
    grouped = (name == "paota" and s.engine == "sharded"
               and s.group_period > 1)
    pending: List[Dict] = []
    for r in range(s.n_rounds):
        if grouped:
            # grouped aggregation advances in whole windows; buffer the
            # window's per-round rows and drain one per loop iteration
            if not pending:
                pending = list(srv.advance(s.group_period))
            info = pending.pop(0)
        else:
            info = srv.round()
        if r % s.eval_every == 0 or r == s.n_rounds - 1:
            gp = srv.global_params()
            ev = evaluate(gp, x_te, y_te, mlp_apply)
            rows.append({
                "algo": name, "round": info["round"],
                "time": round(info["time"], 2),
                "loss": round(train_loss(gp, x_tr, y_tr), 4),
                "accuracy": round(ev["accuracy"], 4),
                "test_loss": round(ev["loss"], 4),
                "wall_s": round(time.time() - t0, 1),
            })
    return rows
