"""Fault-tolerant round benchmark: what containment and checkpointing cost.

The fault-tolerance planes (ISSUE 10) are all trace-time opt-ins; this
module prices each one against the unguarded round:

* ``K = 1e3`` synthetic runtime-level rows (raw ``repro.fl.runtime`` scan,
  d = 16384, fabricated train/channel streams): the unguarded dense round
  vs ``screen=True`` on clean traffic (pure screening overhead — the ok
  mask rides the existing stats sweep, so this should be noise) vs a
  faulty run (NaN + Byzantine + deep-fade injection through the real
  ``FaultConfig`` helpers) under screening;
* ``K = 1e3`` driver rows (real ``FusedPAOTA``, MLP engine): baseline vs
  the full fault-tolerance stack (faults + screening + divergence
  rollback) vs ``checkpoint_every=5`` (two full-carry snapshots inside
  the timed 10-round window — the serialization + atomic-rename cost);
* ``K = 1e6`` cohort rows (m = 256 slots, the PR-8 state-plane scale):
  the fault stack at the scale where the (K,) fault masks are the only
  per-client cost — screening stays on the (m, d) payload plane.

Every screened row reports ``screened_per_round`` in ``derived`` so the
series also tracks that injection actually engages the screen.

``python -m benchmarks.fault_round_bench smoke`` runs the synthetic
K=1e3 trio only and writes ``BENCH_fault_round_smoke.json`` (CI fast
tier, >2x diff gate); the full run adds the driver and K=1e6 rows and
writes ``BENCH_fault_round.json``.
"""
from __future__ import annotations

import os
import sys
import tempfile
import time

_SYNTH_D = 16384
_SYNTH_M = 256
_ROUNDS = 10

# the injected storm for every faulty row: 5% NaN + 5% Byzantine uploads,
# 5% deep-fade channel outliers, live from round 1
_STORM = dict(nan_frac=0.05, byzantine_frac=0.05, deep_fade_frac=0.05,
              start=1)


def _row(name: str, sec: float, setup: float, rounds: int,
         carry_bytes: int, screened: float) -> dict:
    return {"name": name, "us_per_call": round(sec * 1e6, 1),
            "derived": f"rounds_per_sec={1.0 / sec:.3f};"
                       f"scan_rounds={rounds};setup_s={setup:.2f};"
                       f"carry_bytes={carry_bytes};"
                       f"screened_per_round={screened:.2f}"}


def _carry_bytes(carry) -> int:
    import jax
    return int(sum(l.size * l.dtype.itemsize
                   for l in jax.tree_util.tree_leaves(carry)))


# ---------------------------------------------------------------------------
# synthetic runtime-level harness: the round core with fabricated streams
# ---------------------------------------------------------------------------

def _synth_scan(k: int, m: int, rounds: int = _ROUNDS, *,
                faults=None, screen: bool = False):
    """Time the raw ``scan_rounds`` over the dense (m = 0) or cohort
    carry with synthetic streams; ``faults`` (a ``FaultConfig``) corrupts
    the fabricated local updates and channel draws through the same
    helpers the drivers use, ``screen`` arms per-row containment."""
    import jax
    import jax.numpy as jnp

    from repro.core.aircomp import ChannelConfig, sample_channel_gains
    from repro.core.power_control import p2_constants
    from repro.core.scheduler import (TAG_CHANNEL, TAG_NOISE, TAG_SCHED,
                                      ScenarioConfig, counter_latencies,
                                      fault_channel_mask,
                                      fault_payload_masks,
                                      inject_payload_faults, round_tag_key,
                                      scenario_masks)
    from repro.fl.runtime import (RoundCfg, RoundStreams, init_cohort_carry,
                                  init_round_carry, scan_rounds)

    d = _SYNTH_D
    key = jax.random.PRNGKey(0)
    chan = ChannelConfig()
    sc = ScenarioConfig(availability="cycle", avail_period=4,
                        avail_duty=0.5, dropout_prob=0.05)
    c1, c0 = p2_constants(10.0, 0.05, k, d, chan.sigma_n2)
    rcfg = RoundCfg(omega=3.0, c1=c1, c0=c0, p_max_watts=chan.p_max_watts,
                    sigma_n=chan.sigma_n, delta_t=8.0, transmit_delta=True,
                    cohort_size=m, screen=bool(screen))

    def fan(g, r, ids):
        # tag 12: clear of the scheduler's reserved draw tags (0-10)
        n = jax.random.normal(round_tag_key(key, r, 12),
                              (ids.shape[0], d), jnp.float32)
        rows = g[None, :] + jnp.float32(1e-3) * n
        if faults is not None and faults.has_payload_faults:
            nm, bm = fault_payload_masks(key, r, k, faults)
            rows = inject_payload_faults(rows, g, nm[ids], bm[ids], faults)
        return rows

    def channel(t):
        h = sample_channel_gains(round_tag_key(key, t, TAG_CHANNEL), k, chan)
        if faults is not None and faults.has_channel_faults:
            fade = fault_channel_mask(key, t, k, faults)
            h = jnp.where(fade, h * jnp.float32(faults.deep_fade_gain), h)
        return h

    streams = RoundStreams(
        local_train=lambda g, x, y, r: fan(g, r, jnp.arange(k)),
        latencies=lambda r: counter_latencies(key, r, k, 5.0, 15.0),
        channel=channel,
        noise_key=lambda t: round_tag_key(key, t, TAG_NOISE),
        scenario=lambda t: scenario_masks(key, t, k, sc),
        cohort_train=lambda g, x, y, r, ids: fan(g, r, ids),
        sched_priority=lambda r: jax.random.uniform(
            round_tag_key(key, r, TAG_SCHED), (k,)),
    )
    g0 = jnp.zeros((d,), jnp.float32)
    x = y = jnp.zeros((1,), jnp.float32)

    t0 = time.perf_counter()
    if m:
        carry = jax.jit(lambda v: init_cohort_carry(
            v, x, y, streams=streams, k=k, m=m, pending_dtype="float32",
            keep_pending=False, rcfg=rcfg))(g0)
    else:
        carry = jax.jit(lambda v: init_round_carry(
            v, x, y, streams=streams, pending_dtype="float32",
            keep_pending=False, rcfg=rcfg))(g0)
    nbytes = _carry_bytes(carry)
    scan = jax.jit(lambda c: scan_rounds(c, x, y, rounds, rcfg=rcfg,
                                         streams=streams),
                   donate_argnums=(0,))
    carry, outs = jax.block_until_ready(scan(carry))    # compile + run
    setup = time.perf_counter() - t0
    t0 = time.perf_counter()
    carry, outs = jax.block_until_ready(scan(carry))    # steady state
    sec = (time.perf_counter() - t0) / rounds
    import numpy as np
    assert np.isfinite(np.asarray(carry.global_vec)).all()
    screened = float(np.asarray(outs["n_screened"]).sum()) / rounds
    return sec, setup, nbytes, screened


def _synth_rows(k: int, m: int = 0) -> list:
    from repro.core.scheduler import FaultConfig
    sfx = f"_m{m}" if m else "_dense"
    rows = []
    for label, kw in (
            ("baseline", {}),
            ("screen", dict(screen=True)),
            ("faulty_screened", dict(faults=FaultConfig(**_STORM),
                                     screen=True))):
        sec, setup, nb, scr = _synth_scan(k, m, **kw)
        rows.append(_row(f"fault_round/synth_{label}{sfx}_k{k}", sec,
                         setup, _ROUNDS, nb, scr))
    return rows


# ---------------------------------------------------------------------------
# driver-level rows: the real FusedPAOTA path at K = 1e3
# ---------------------------------------------------------------------------

def _driver_rows(k: int = 1000) -> list:
    import jax
    import numpy as np

    from repro.core import ChannelConfig, SchedulerConfig
    from repro.core.scheduler import FaultConfig
    from repro.data.partition import partition_noniid
    from repro.data.pipeline import build_federation
    from repro.data.synthetic import make_mnist_like
    from repro.fl import BatchedEngine, FusedPAOTA, PAOTAConfig
    from repro.models.mlp import init_mlp_params, mlp_loss

    x, y, _, _ = make_mnist_like(n_train=20000, n_test=10, seed=1234)
    parts = partition_noniid(y, n_clients=k, sizes=(16, 24), seed=0)

    def srv(**kw):
        fed = build_federation(x, y, parts, seed=0)
        eng = BatchedEngine(fed, mlp_loss, batch_size=1, lr=0.1,
                            local_steps=1)
        return FusedPAOTA(init_mlp_params(jax.random.PRNGKey(0)), eng,
                          ChannelConfig(), SchedulerConfig(n_clients=k,
                                                           seed=0),
                          PAOTAConfig(transmit="delta"), **kw)

    rows = []
    with tempfile.TemporaryDirectory() as ckpt_dir:
        configs = (
            ("baseline", {}),
            ("fault_tol", dict(faults=FaultConfig(**_STORM), screen=True,
                               divergence_factor=4.0)),
            # snapshot cost: 2 full-carry checkpoints land inside the
            # timed 10-round window (serialize + fsync-free atomic rename)
            ("ckpt5", dict(checkpoint_every=5, checkpoint_dir=ckpt_dir)),
        )
        for label, kw in configs:
            t0 = time.perf_counter()
            s = srv(**kw)
            s.advance(_ROUNDS)
            setup = time.perf_counter() - t0
            nb = _carry_bytes(s._carry)
            t0 = time.perf_counter()
            s.advance(_ROUNDS)
            sec = (time.perf_counter() - t0) / _ROUNDS
            assert np.isfinite(s.global_vec).all()
            scr = sum(r["n_screened"] for r in s.history) / len(s.history)
            rows.append(_row(f"fault_round/fused_{label}_mlp_k{k}", sec,
                             setup, _ROUNDS, nb, scr))
        n_ckpt = len(os.listdir(ckpt_dir))
        assert n_ckpt >= 2, f"checkpoint_every=5 wrote {n_ckpt} snapshots"
    return rows


def run(smoke: bool = False) -> list:
    rows = _synth_rows(1000)
    if smoke:
        return rows
    rows += _driver_rows()
    # the acceptance scale: the fault stack on the million-client cohort
    # state plane — (K,) fault masks are the only per-client cost
    rows += _synth_rows(1_000_000, m=_SYNTH_M)
    return rows


def main():
    smoke = "smoke" in sys.argv[1:]
    rows = run(smoke=smoke)
    print("name,us_per_call,derived")
    for row in rows:
        print(f"{row['name']},{row['us_per_call']},{row['derived']}",
              flush=True)
    from benchmarks.common import write_bench_artifact
    name = "fault_round_smoke" if smoke else "fault_round"
    path = write_bench_artifact(
        name, rows, extra={"synth_d": _SYNTH_D, "synth_m": _SYNTH_M,
                           "rounds": _ROUNDS, "storm": _STORM,
                           "smoke": smoke})
    print(f"# artifact -> {path}", flush=True)


if __name__ == "__main__":
    main()
