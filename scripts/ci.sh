#!/usr/bin/env bash
# CI driver.
#
#   scripts/ci.sh          fast tier: everything not marked `slow` (<60s)
#   CI_FULL=1 scripts/ci.sh   full suite (nightly-style, ~4-5 min on CPU)
#   CI_BENCH=1 scripts/ci.sh  also run the engine benchmark after tests
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [ "${CI_FULL:-0}" = "1" ]; then
    python -m pytest -q
else
    python -m pytest -q -m "not slow"
fi

# fused-round smoke (1 tiny lax.scan) — keeps the on-device PAOTA path
# compiling; full numbers via `python -m benchmarks.run fused_round`
python -m benchmarks.fused_round_bench smoke

if [ "${CI_BENCH:-0}" = "1" ]; then
    python -m benchmarks.run fl_engine
fi
