#!/usr/bin/env bash
# CI driver.
#
#   scripts/ci.sh          fast tier: everything not marked `slow` (<90s)
#                          + the 8-virtual-device sharding tests
#                          + fused-round smoke with artifact check
#                          + round-perf smoke (tracked delta-plane series,
#                            K=16; >2x wall-clock regressions fail)
#                          + cohort-round smoke (dense vs active-cohort
#                            synthetic pair at K=1e3, carry-bytes tracked)
#                          + fault-round smoke (screening-overhead trio at
#                            K=1e3; the faulty row must engage the screen)
#   CI_FULL=1 scripts/ci.sh   full suite (nightly-style) + sharded
#                          benchmark smoke (8 forced devices, K=16)
#   CI_BENCH=1 scripts/ci.sh  also run the engine benchmark after tests
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [ "${CI_FULL:-0}" = "1" ]; then
    python -m pytest -q
else
    python -m pytest -q -m "not slow"
fi

# multi-device tier: the mesh-sharded round tests on the forced
# 8-virtual-device backend (tests/conftest.py sets XLA_FLAGS; they are in
# the fast tier too — this run isolates them so a sharding regression is
# unmissable in the CI log; the spec-divisibility property tests are
# device-free and stay in the ordinary tiers)
python -m pytest -q -m multidevice

# fused-round smoke (1 tiny lax.scan) — keeps the on-device PAOTA path
# compiling; full numbers via `python -m benchmarks.run fused_round`.
# The artifact is removed first so the parse check below cannot pass
# against a stale file from an earlier run. The previous PR's committed
# artifacts are snapshotted FIRST: benchmarks/diff.py compares the fresh
# run against them and fails on >2x wall-clock regressions.
BENCH_OUT="${REPRO_BENCH_OUT:-experiments/bench}"
BENCH_BASELINE="$(mktemp -d)"
cp "$BENCH_OUT"/BENCH_*.json "$BENCH_BASELINE"/ 2>/dev/null || true
rm -f "$BENCH_OUT/BENCH_fused_round_smoke.json"
python -m benchmarks.fused_round_bench smoke

# benchmark artifacts must stay machine-readable (perf tracked across PRs)
python - "$BENCH_OUT" <<'EOF'
import json, sys
art = json.load(open(f"{sys.argv[1]}/BENCH_fused_round_smoke.json"))
assert art["rows"] and all("us_per_call" in r for r in art["rows"]), art
print(f"artifact ok: {art['name']} ({len(art['rows'])} rows, "
      f"{art['device_count']} devices)")
EOF

# round-perf smoke: the canonical tracked delta-plane series (K=16 subset
# of benchmarks/round_perf_bench — host/fused inline + sharded in a forced
# 8-device subprocess). The regenerated artifact is gated by the >2x diff
# below against the committed BENCH_round_perf_smoke.json.
rm -f "$BENCH_OUT/BENCH_round_perf_smoke.json"
python -m benchmarks.round_perf_bench smoke
python - "$BENCH_OUT" <<'EOF'
import json, sys
art = json.load(open(f"{sys.argv[1]}/BENCH_round_perf_smoke.json"))
names = [r["name"] for r in art["rows"]]
assert any("fused_raveled_k16" in n for n in names), names
assert any("sharded_raveled_k16" in n for n in names), names
print(f"artifact ok: {art['name']} ({len(art['rows'])} rows)")
EOF

# cohort-round smoke: synthetic-stream dense vs active-cohort pair at
# K=1e3, plus the compressed-payload rows (randmask s/d=1/16 with error
# feedback, and int8 slot storage) — the carry-bytes shrink and the
# rounds/sec win are the tracked series. Gated by the >2x diff below.
rm -f "$BENCH_OUT/BENCH_cohort_round_smoke.json"
python -m benchmarks.cohort_round_bench smoke
python - "$BENCH_OUT" <<'EOF'
import json, sys
art = json.load(open(f"{sys.argv[1]}/BENCH_cohort_round_smoke.json"))
names = [r["name"] for r in art["rows"]]
assert any("synth_dense_k1000" in n for n in names), names
assert any("synth_cohort_" in n for n in names), names
# compressed-payload rows (randmask s/d=1/16; f32+EF and int8 variants)
assert any("_rm16" in n for n in names), names
assert any("_rm16_int8" in n for n in names), names
assert all("carry_bytes=" in r["derived"] for r in art["rows"]), art["rows"]
print(f"artifact ok: {art['name']} ({len(art['rows'])} rows)")
EOF

# fault-round smoke: unguarded vs screen-on-clean vs faulty-under-screen
# synthetic trio at K=1e3 — the screening overhead is the tracked series,
# and the faulty row must show the screen actually engaging. Gated by the
# >2x diff below.
rm -f "$BENCH_OUT/BENCH_fault_round_smoke.json"
python -m benchmarks.fault_round_bench smoke
python - "$BENCH_OUT" <<'EOF'
import json, sys
art = json.load(open(f"{sys.argv[1]}/BENCH_fault_round_smoke.json"))
names = [r["name"] for r in art["rows"]]
assert any("synth_baseline_dense_k1000" in n for n in names), names
assert any("synth_screen_dense_k1000" in n for n in names), names
faulty = [r for r in art["rows"] if "faulty_screened" in r["name"]]
assert faulty and all(
    float(r["derived"].split("screened_per_round=")[1]) > 0
    for r in faulty), faulty
print(f"artifact ok: {art['name']} ({len(art['rows'])} rows)")
EOF

if [ "${CI_FULL:-0}" = "1" ]; then
    # sharded-round smoke: K=16 over the forced 8-device mesh in a
    # subprocess (fused vs shard_map pairing + its JSON artifact)
    rm -f "$BENCH_OUT/BENCH_sharded_round_smoke.json"
    python -m benchmarks.sharded_round_bench smoke
    python - "$BENCH_OUT" <<'EOF'
import json, sys
art = json.load(open(f"{sys.argv[1]}/BENCH_sharded_round_smoke.json"))
names = [r["name"] for r in art["rows"]]
assert any("sharded_k16" in n for n in names), names
print(f"artifact ok: {art['name']} ({len(art['rows'])} rows)")
EOF

    # grouped-round smoke: K=16 on the forced 8-device (2, 4) pod mesh —
    # flat vs grouped window scans plus the compiled-HLO collective check
    # (exactly ONE cross-pod model-sized all-reduce per window)
    rm -f "$BENCH_OUT/BENCH_grouped_round_smoke.json"
    python -m benchmarks.grouped_round_bench smoke
    python - "$BENCH_OUT" <<'EOF'
import json, sys
art = json.load(open(f"{sys.argv[1]}/BENCH_grouped_round_smoke.json"))
names = [r["name"] for r in art["rows"]]
assert any("grouped_n2_k16" in n for n in names), names
assert any("cross_pod_big_allreduce_per_window=1" in r.get("derived", "")
           for r in art["rows"]), art["rows"]
print(f"artifact ok: {art['name']} ({len(art['rows'])} rows)")
EOF

    # intra-client-TP smoke: the hidden-128 MLP federation across the
    # tp in {1, 2, 4} ladder on forced (1, 2, tp) meshes — per-device
    # carry bytes must fall ~1/tp with exactly ONE cross-client
    # model-sized all-reduce at every rung
    rm -f "$BENCH_OUT/BENCH_tp_round_smoke.json"
    python -m benchmarks.tp_round_bench smoke
    python - "$BENCH_OUT" <<'EOF'
import json, sys
art = json.load(open(f"{sys.argv[1]}/BENCH_tp_round_smoke.json"))
names = [r["name"] for r in art["rows"]]
assert any("smoke_tp4" in n for n in names), names
assert any("per_device_bytes_tp1_over_tp4=4" in r.get("derived", "")
           for r in art["rows"]), art["rows"]
assert all("cross_client_big_allreduce=1" in r["derived"]
           for r in art["rows"] if "smoke_tp" in r["name"]), art["rows"]
print(f"artifact ok: {art['name']} ({len(art['rows'])} rows)")
EOF
fi

# perf trajectory gate: every artifact the smokes regenerated must stay
# within 2x of the previous PR's committed numbers (row-by-row wall clock)
python -m benchmarks.diff --baseline "$BENCH_BASELINE" --current "$BENCH_OUT"

if [ "${CI_BENCH:-0}" = "1" ]; then
    python -m benchmarks.run fl_engine
fi
